"""Per-arch smoke tests (reduced configs, one forward/train step, shapes +
no NaNs) + model-math properties."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import model as Mo
from repro.models import layers as L
from repro.models import recurrent as R
from repro.models.env import Env
from repro.configs.base import ParallelPlan, ModelConfig


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch, local_env, rng):
    """Assigned-architecture smoke: reduced config, one step, finite loss."""
    cfg = get_smoke(arch)
    params = Mo.init_params(rng, cfg, local_env)
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros(
            (B, cfg.num_vision_embeds, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.zeros((B, S // cfg.enc_downsample, cfg.d_model),
                                    jnp.float32)
    loss, metrics = Mo.lm_loss(params, batch, cfg, local_env)
    grads = jax.grad(lambda p: Mo.lm_loss(p, batch, cfg, local_env)[0])(params)
    assert jnp.isfinite(loss)
    gn = sum(jnp.sum(jnp.abs(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode_match_forward(arch, local_env, rng):
    """Greedy decode from a prefixed cache must match teacher-forced logits."""
    cfg = get_smoke(arch)
    params = Mo.init_params(rng, cfg, local_env)
    B, S = 2, 12
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "vlm":
        kw["vision_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.num_vision_embeds, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        kw["frames"] = 0.02 * jax.random.normal(
            rng, (B, S // cfg.enc_downsample, cfg.d_model), jnp.float32)
    # teacher-forced forward over S+1 tokens
    nxt = jax.random.randint(jax.random.PRNGKey(7), (B, 1), 0, cfg.vocab_size)
    full = jnp.concatenate([tokens, nxt], axis=1)
    if cfg.is_encdec:
        kw2 = dict(kw)
        kw2["frames"] = kw["frames"]
    logits_full, _, _ = Mo.forward(params, full, cfg, local_env, mode="train",
                                   **kw)
    # prefill S tokens, then decode the (S+1)-th
    _, caches, _ = Mo.forward(params, tokens, cfg, local_env, mode="prefill",
                              **kw)
    caches = Mo.grow_caches(caches, 4)  # room for decode appends
    offset = cfg.num_vision_embeds if cfg.family == "vlm" else 0
    logits_dec, _, _ = Mo.forward(params, nxt, cfg, local_env, mode="decode",
                                  caches=caches,
                                  cur_len=jnp.asarray(S + offset, jnp.int32))
    a = logits_dec[:, 0, : cfg.vocab_size].astype(jnp.float32)
    b = logits_full[:, -1, : cfg.vocab_size].astype(jnp.float32)
    tol = 0.5 if cfg.moe is not None else 0.15  # MoE: capacity-drop
    # patterns differ between a length-S and a length-(S+1) dispatch
    assert jnp.max(jnp.abs(a - b)) < tol, f"{arch}: decode != forward"


def test_gqa_equals_mha_when_kv_heads_match(local_env, rng):
    cfg = get_smoke("yi-9b")
    ks = jax.random.split(rng, 3)
    B, S, H, hd = 2, 8, 4, 16
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    o_mha = L.attention_naive(q, k, v, cfg, causal=True)
    # grouped path with kv==q heads must be identical
    o_gqa = L.attention_naive(q, k, v, cfg, causal=True)
    assert jnp.allclose(o_mha, o_gqa)


def test_rope_relative_property(rng):
    """RoPE: q_m . k_n depends only on (m - n)."""
    hd = 32
    ks = jax.random.split(rng, 2)
    q = jax.random.normal(ks[0], (1, 1, 1, hd))
    k = jax.random.normal(ks[1], (1, 1, 1, hd))
    def dot_at(m, n):
        qm = L.apply_rope(q, jnp.array([[m]]), 10000.0)
        kn = L.apply_rope(k, jnp.array([[n]]), 10000.0)
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(7, 0) - dot_at(100, 93)) < 1e-3


def test_chunked_attention_matches_naive(local_env, rng):
    cfg = get_smoke("yi-9b")
    ks = jax.random.split(rng, 3)
    B, S, H, Hkv, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    o_naive = L.attention_naive(q, k, v, cfg, causal=True)
    o_chunk = L.attention_chunked(q, k, v, cfg, local_env, causal=True,
                                  q_chunk=16, kv_chunk=16)
    assert jnp.max(jnp.abs(o_naive - o_chunk)) < 1e-3


def test_window_prefill_matches_masked_naive(local_env, rng):
    cfg = get_smoke("recurrentgemma-9b")
    ks = jax.random.split(rng, 3)
    B, S, H, hd, W = 1, 64, 4, 16, 16
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, 1, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, 1, hd), jnp.float32)
    o_naive = L.attention_naive(q, k, v, cfg, causal=True, window=W)
    o_win = L.attention_window_prefill(q, k, v, cfg, local_env, window=W,
                                       q_chunk=16)
    assert jnp.max(jnp.abs(o_naive - o_win)) < 1e-3


def test_rwkv_chunked_equals_sequential(rng):
    from repro.kernels.rwkv6.ref import wkv6_ref
    B, S, H, hd = 2, 32, 2, 8
    ks = jax.random.split(rng, 5)
    mk = lambda k: jax.random.normal(k, (B, S, H, hd), jnp.float32) * 0.5
    r, k_, v = mk(ks[0]), mk(ks[1]), mk(ks[2])
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.5 - 1)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    o_chunk, s_chunk = R.rwkv_time_mix_chunked(r, k_, v, logw, u, chunk=8)
    o_seq, s_seq = wkv6_ref(*(a.transpose(0, 2, 1, 3) for a in (r, k_, v,
                                                                logw)), u)
    assert jnp.max(jnp.abs(o_chunk - o_seq.transpose(0, 2, 1, 3))) < 1e-3
    assert jnp.max(jnp.abs(s_chunk - s_seq)) < 1e-3


def test_rglru_assoc_scan_equals_loop(rng):
    from repro.kernels.rglru.ref import rglru_ref, rglru_ref_loop
    ks = jax.random.split(rng, 2)
    a = jax.random.uniform(ks[0], (2, 33, 8), jnp.float32, 0.1, 0.99)
    b = jax.random.normal(ks[1], (2, 33, 8), jnp.float32)
    assert jnp.max(jnp.abs(rglru_ref(a, b) - rglru_ref_loop(a, b))) < 1e-4


def test_moe_capacity_and_mass(local_env, rng):
    """Kept tokens route to <= capacity slots; combine weights sum <= 1."""
    from repro.models import moe as M
    cfg = get_smoke("grok-1-314b")
    p = M.init_moe(rng, cfg, local_env)
    x = jax.random.normal(rng, (2, 16, cfg.d_model), jnp.bfloat16)
    y, aux = M.moe_layer(p, x, cfg, local_env)
    assert y.shape == x.shape
    assert jnp.isfinite(aux) and aux > 0.5  # lb loss ~1 for balanced-ish
    # gradient flows to router
    g = jax.grad(lambda pp: jnp.sum(
        M.moe_layer(pp, x, cfg, local_env)[0].astype(jnp.float32)))(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
