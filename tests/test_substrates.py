"""Optimizer, data pipeline, checkpoint substrates (+hypothesis properties)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # optional test dep: falls back to fixed deterministic examples
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint import CheckpointManager
from repro.data import MemmapCorpus, SyntheticLM
from repro.optim import (AdamWConfig, adamw_init, adamw_update, cosine_lr,
                         ef_psum_grads, init_error)
from repro.optim.compress import compress_decompress


# ---- optimizer ---------------------------------------------------------------


def _quadratic_converges(state_dtype):
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=300, state_dtype=state_dtype)
    params = {"w": jnp.full((4, 64), 5.0, jnp.float32)}
    state = adamw_init(params, cfg)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        return adamw_update(g, state, cfg)

    for _ in range(200):
        params, state = step(params, state)
    return float(jnp.max(jnp.abs(params["w"] - 1.0)))


def test_adamw_fp32_converges():
    assert _quadratic_converges("fp32") < 0.05


def test_adamw_int8_states_converge():
    """The 8-bit moment quantization must not break optimization."""
    assert _quadratic_converges("int8") < 0.1


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(cosine_lr(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(cosine_lr(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(cosine_lr(cfg, jnp.asarray(100))) == pytest.approx(0.1, .01)


def test_int8_state_memory_is_smaller():
    cfg8 = AdamWConfig(state_dtype="int8")
    params = {"w": jnp.zeros((256, 256), jnp.bfloat16)}
    s8 = adamw_init(params, cfg8)
    sz = lambda t: sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))
    s32 = adamw_init(params, AdamWConfig(state_dtype="fp32"))
    assert sz(s8["m"]) < 0.3 * sz(s32["m"])


# ---- gradient compression -----------------------------------------------------


def test_error_feedback_reduces_bias(rng):
    """With EF, the accumulated compressed sum tracks the true sum."""
    g = jax.random.normal(rng, (8, 128)) * 0.01
    err = jnp.zeros_like(g)
    acc_c, acc_t = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(50):
        dq, err = compress_decompress(g, err)
        acc_c += dq
        acc_t += g
    rel = float(jnp.linalg.norm(acc_c - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.01  # EF keeps the long-run sum unbiased


# ---- data pipeline ---------------------------------------------------------------


def test_synthetic_determinism():
    src = SyntheticLM(1000, 32, seed=3)
    a = src.batch_np(step=5, batch=8)
    b = src.batch_np(step=5, batch=8)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = src.batch_np(step=6, batch=8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert np.array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


@settings(max_examples=10, deadline=None)
@given(n_shards=st.sampled_from([1, 2, 4]), step=st.integers(0, 100))
def test_shards_are_disjoint_slices(n_shards, step):
    """Sharded draws are deterministic per (seed, step, shard) and distinct
    across shards."""
    src = SyntheticLM(5000, 16, seed=1)
    batches = [src.batch_np(step, 8, shard=s, n_shards=n_shards)
               for s in range(n_shards)]
    for i in range(n_shards):
        again = src.batch_np(step, 8, shard=i, n_shards=n_shards)
        assert np.array_equal(batches[i]["tokens"], again["tokens"])
        for j in range(i + 1, n_shards):
            assert not np.array_equal(batches[i]["tokens"],
                                      batches[j]["tokens"])


def test_memmap_corpus_roundtrip(tmp_path):
    path = str(tmp_path / "corpus.bin")
    MemmapCorpus.write(path, np.arange(10_000) % 777)
    c = MemmapCorpus(path, seq_len=32, seed=0)
    b = c.batch_np(0, 4)
    assert b["tokens"].shape == (4, 32)
    assert np.array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ---- checkpoint ---------------------------------------------------------------------


def _tree(rng):
    ks = jax.random.split(rng, 3)
    return {
        "params": {"w": jax.random.normal(ks[0], (8, 16)).astype(jnp.bfloat16),
                   "b": jax.random.normal(ks[1], (16,), jnp.float32)},
        "opt": {"step": jnp.asarray(7, jnp.int32),
                "m": (jax.random.normal(ks[2], (8, 16)),)},
    }


def test_checkpoint_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _tree(rng)
    mgr.save(7, state, {"note": "x"})
    struct = jax.eval_shape(lambda: state)
    out = mgr.restore(struct)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        assert jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32))
    assert mgr.metadata()["note"] == "x"


def test_checkpoint_retention_and_latest(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _tree(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.available_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_then_restore(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    state = _tree(rng)
    mgr.save_async(9, state)
    mgr.wait()
    assert mgr.latest_step() == 9


def test_checkpoint_rejects_tree_mismatch(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    state = _tree(rng)
    mgr.save(1, state)
    bad = {"params": state["params"]}
    with pytest.raises(ValueError):
        mgr.restore(jax.eval_shape(lambda: bad))
