"""Multi-replica serving data plane: Router + per-replica KV pools,
routing policies, the real scale-out/drain lifecycle, per-source metric
tombstoning, and the prefix-cache eviction policy (hit-count-weighted
reclaim + residency cap).

Correctness bar: per-request output is bit-identical — greedy and seeded —
across 1 vs N replicas, across both routing policies, and across mid-serve
scale-up + drain events. The fused step computes every row independently,
so WHICH replica serves a request can never change WHAT it emits; these
tests pin that property through the router."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import VirtualCluster
from repro.core.clock import ManualClock
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve import (SERVE_PLAN, BlockManager, LeastOccupancyRouting,
                         PrefixAffineRouting, ReplicaEngine, ReplicaSet,
                         RoutingPolicy, SamplingParams, ServingEngine,
                         burst_trace, make_routing_policy,
                         make_serving_engine, poisson_trace,
                         run_to_completion, sysprompt_trace)

CFG = get_smoke("paper-demo")
ENV0 = Env(mesh=None, plan=SERVE_PLAN)
PARAMS = Mo.init_params(jax.random.PRNGKey(0), CFG, ENV0)
P = 16  # prompt length used throughout
BS = 4


def _fleet(replicas=2, routing="occupancy", num_slots=2, max_gen=8, **kw):
    return ReplicaSet(CFG, PARAMS, replicas=replicas, routing=routing,
                      num_slots=num_slots, prompt_len=P, max_gen=max_gen,
                      clock=ManualClock(), **kw)


def _single(num_slots=2, max_gen=8, **kw):
    return ServingEngine(CFG, PARAMS, num_slots=num_slots, prompt_len=P,
                         max_gen=max_gen, clock=ManualClock(), **kw)


def _fresh(trace):
    return [dataclasses.replace(r, tokens=[], t_admit=None,
                                t_first_token=None, t_done=None)
            for r in trace]


def _trace(n=8, gen_len=6, rate=32.0, seed=0, sampling=None):
    return poisson_trace(n, rate, prompt_len=P, vocab_size=CFG.vocab_size,
                         gen_len=gen_len, sampling=sampling, seed=seed)


def _shared_trace(n=12, rate=48.0, sampling=None, n_prefixes=2, seed=0):
    return sysprompt_trace(n, rate, prompt_len=P, vocab_size=CFG.vocab_size,
                           prefix_len=12, gen_len=6, n_prefixes=n_prefixes,
                           sampling=sampling, seed=seed)


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------


def test_routing_registry_and_protocol():
    assert isinstance(make_routing_policy("occupancy"),
                      LeastOccupancyRouting)
    assert isinstance(make_routing_policy("prefix"), PrefixAffineRouting)
    assert isinstance(LeastOccupancyRouting(), RoutingPolicy)
    assert isinstance(PrefixAffineRouting(), RoutingPolicy)
    with pytest.raises(ValueError):
        make_routing_policy("round-robin")


def test_least_occupancy_spreads_a_burst_across_replicas():
    rs = _fleet(replicas=2, num_slots=2)
    trace = burst_trace(4, prompt_len=P, vocab_size=CFG.vocab_size,
                        gen_len=6, seed=1)
    rs.submit(trace)
    rs.step()  # all four arrive at t=0; one lane opens per replica/step
    assert sorted(len(r._inflight) for r in rs.replicas) == [1, 1]
    rs.step()  # ... so the burst spreads 2+2, never 3+1
    counts = sorted(len(r._inflight) for r in rs.replicas)
    assert counts == [2, 2], counts
    out = run_to_completion(rs, dt=0.05)
    assert sorted(out) == [0, 1, 2, 3]


def test_prefix_affine_routes_to_the_warm_replica():
    rs = _fleet(replicas=2, routing="prefix", num_slots=2, block_size=BS)
    trace = _shared_trace(n=3, n_prefixes=1, rate=1000.0)
    # warm replica-1 by hand: serve the first templated request there
    warm = rs.replicas[1]
    warm.admit(trace[0], 0.0)
    while warm.busy:
        warm.step_decode(rs.clock.now())
        rs.clock.sleep(0.05)
    assert warm.pool.probe_prefix(trace[1].prompt) > 0
    # both replicas are now idle (equal occupancy; replica-0 would win a
    # least-occupancy tie) — affinity must still route to the warm cache
    rs.submit(trace[1:])
    rs.step()
    assert not rs.replicas[0]._inflight, "cold replica stole a warm prompt"
    assert warm._inflight
    out = run_to_completion(rs, dt=0.05)
    assert sorted(out) == [0, 1, 2]  # rid 0 completed on the warm replica
    assert warm.pool.prefix_hit_rate >= 0.5  # 2 of 3 prompts hit 12/16


def test_prefix_affine_beats_occupancy_on_fleet_hit_rate():
    runs = {}
    for routing in ("prefix", "occupancy"):
        rs = _fleet(replicas=2, routing=routing, num_slots=2, block_size=BS)
        run_to_completion(rs, _shared_trace(n=12, n_prefixes=2), dt=0.05)
        runs[routing] = rs.snapshot()["prefix_hit_rate"]
    assert runs["prefix"] > runs["occupancy"], runs


# ---------------------------------------------------------------------------
# exactness: the router moves requests, never tokens
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("routing", ["occupancy", "prefix"])
def test_fleet_output_matches_single_engine_greedy(routing):
    trace = _trace(n=8)
    base = run_to_completion(_single(), _fresh(trace), dt=0.05)
    rs = _fleet(replicas=3, routing=routing)
    out = run_to_completion(rs, _fresh(trace), dt=0.05)
    assert out == base


def test_fleet_output_matches_single_engine_seeded():
    sp = SamplingParams(temperature=0.9, top_k=40, top_p=0.95, seed=7)
    trace = _trace(n=8, sampling=sp)
    base = run_to_completion(_single(), _fresh(trace), dt=0.05)
    out = run_to_completion(_fleet(replicas=3, routing="prefix"),
                            _fresh(trace), dt=0.05)
    assert out == base


def test_make_serving_engine_dispatches_on_replica_count():
    assert isinstance(make_serving_engine(CFG, PARAMS, replicas=1,
                                          num_slots=2, prompt_len=P,
                                          max_gen=8, clock=ManualClock()),
                      ServingEngine)
    rs = make_serving_engine(CFG, PARAMS, replicas=2, num_slots=2,
                             prompt_len=P, max_gen=8, clock=ManualClock())
    assert isinstance(rs, ReplicaSet) and len(rs.replicas) == 2


# ---------------------------------------------------------------------------
# scale-out / drain lifecycle
# ---------------------------------------------------------------------------


def _run_with_rescale(rs, trace, *, up_at=3, up_to=3, down_at=8,
                      down_to=1, dt=0.05):
    rs.submit(trace)
    steps = 0
    while not rs.drained() and steps < 5000:
        rs.step()
        if steps == up_at:
            rs.reconcile(up_to)
        if steps == down_at:
            rs.reconcile(down_to)
        rs.clock.sleep(dt)
        steps += 1
    assert rs.drained()
    return rs.results()


@pytest.mark.parametrize("drain_mode", ["finish", "preempt"])
def test_scale_up_and_drain_mid_serve_is_bit_identical(drain_mode):
    trace = _trace(n=12, rate=32.0)
    base = run_to_completion(_single(), _fresh(trace), dt=0.05)
    rs = _fleet(replicas=1, routing="prefix", drain_mode=drain_mode)
    out = _run_with_rescale(rs, _fresh(trace))
    assert out == base, f"{drain_mode} drain perturbed tokens"
    assert rs.replica_warmups == 2, "scale-up must spawn cold replicas"
    assert len(rs.released) >= 2, "scale-down must release drained pools"
    if drain_mode == "preempt":
        assert rs.snapshot()["preemptions"] > 0


def test_seeded_output_survives_drain_preemption():
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=11)
    trace = _trace(n=12, rate=32.0, sampling=sp)
    base = run_to_completion(_single(), _fresh(trace), dt=0.05)
    rs = _fleet(replicas=1, drain_mode="preempt")
    out = _run_with_rescale(rs, _fresh(trace))
    assert out == base


def test_draining_replica_accepts_no_new_work_and_releases_clean():
    rs = _fleet(replicas=2, num_slots=2)
    trace = burst_trace(6, prompt_len=P, vocab_size=CFG.vocab_size,
                        gen_len=6, seed=3)
    rs.submit(trace)
    rs.step()
    victim = rs.replicas[1]
    pool = victim.pool
    rs.reconcile(1)
    assert victim.draining
    assert not victim.can_accept(trace[-1])
    inflight_before = set(victim._inflight)
    out = run_to_completion(rs, dt=0.05)
    assert sorted(out) == list(range(6)), "drained requests must finish"
    # the drained replica was released with its free-list accounting back
    # to empty: no live blocks, no reservations, every usable block free
    # or cache-retained, and the device cache dropped
    assert victim.name in rs.released
    assert inflight_before, "test needs in-flight work on the victim"
    assert pool.blocks_in_use == 0
    assert pool._reserved_total == 0
    assert (len(pool._free_blocks) + len(pool._reclaim)
            == pool.usable_blocks)
    assert pool.caches is None


def test_release_raises_on_leaked_blocks():
    bm = BlockManager(CFG, ENV0, num_slots=2, prompt_len=P, max_gen=8,
                      block_size=BS)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, CFG.vocab_size, (P,), dtype=np.int32)
    slot = bm.admit(1, 8, prefilling=True, prompt=prompt)
    with pytest.raises(RuntimeError, match="occupied"):
        bm.release()
    bm.evict(slot)
    bm.release()  # clean after eviction
    assert bm.caches is None


def test_reconcile_prefers_warm_undrain_over_cold_spawn():
    rs = _fleet(replicas=2)
    rs.reconcile(1)
    draining = [r for r in rs.replicas if r.draining]
    assert len(draining) == 1
    rs.reconcile(2)  # scale back up before the drain completes
    assert not draining[0].draining, "warm replica must be un-drained"
    assert rs.replica_warmups == 0, "no cold spawn was needed"
    rs.reconcile(4)
    assert rs.replica_warmups == 2
    assert len(rs.live_replicas()) == 4


def test_router_applies_backpressure_when_fleet_is_full():
    rs = _fleet(replicas=2, num_slots=1)
    trace = burst_trace(6, prompt_len=P, vocab_size=CFG.vocab_size,
                        gen_len=8, seed=5)
    rs.submit(trace)
    rs.step()
    assert sum(len(r._inflight) for r in rs.replicas) == 2
    assert rs.pending() == 4, "over-capacity arrivals must queue"
    out = run_to_completion(rs, dt=0.05)
    assert sorted(out) == list(range(6))


# ---------------------------------------------------------------------------
# cluster integration: autoscaler plans become replica lifecycle
# ---------------------------------------------------------------------------


def test_cluster_serve_drives_fleet_lifecycle_and_tombstones():
    from repro.core import QueueDepthPolicy
    pol = QueueDepthPolicy(target_per_node=2, min_nodes=1, max_nodes=4)
    c = VirtualCluster(n_compute=1, policy=pol, cooldown_s=0.3)
    rs = ReplicaSet(CFG, PARAMS, replicas=1, routing="prefix", num_slots=2,
                    prompt_len=P, max_gen=8, clock=c.clock)
    trace = burst_trace(12, prompt_len=P, vocab_size=CFG.vocab_size,
                        gen_len=8, seed=2)
    base = run_to_completion(_single(), _fresh(trace), dt=0.05)
    fleet_sizes = []
    out = c.serve(rs, _fresh(trace), dt=0.05,
                  on_step=lambda i, s, cl: fleet_sizes.append(
                      int(s["replicas_live"])))
    assert out == base, "the cluster-driven fleet perturbed tokens"
    assert max(fleet_sizes) > 1, "burst must scale the fleet out"
    assert fleet_sizes[-1] == 1, "drained queue must scale the fleet in"
    assert rs.released, "scale-down must have released replicas"
    # released replicas' metric keys were tombstoned immediately: no
    # numeric reading under a dead source survives in the aggregates
    m = c.scaler.read_metrics(c.registry)
    for name in rs.released:
        assert not any(k.endswith(f"/{name}") for k in m), (name, m)
    # live sources still publish (per-replica namespacing works)
    live = rs.replicas[0].name
    assert any(k.endswith(f"/{live}") for k in m)
    c.shutdown()


def test_node_drain_tombstones_step_metrics_immediately():
    """A drained/removed node's registry keys must stop skewing fleet
    aggregates NOW — not at some later TTL lapse (registry KV never
    expires, so before this fix a departed straggler pinned the median
    forever)."""
    c = VirtualCluster(n_compute=3)
    nodes = c.compute_nodes()
    for i, nid in enumerate(nodes):
        c.sim.nodes[nid].agent.report_step_time(0, 0.1 * (i + 1))
    m = c.scaler.read_metrics(c.registry)
    assert len([k for k in m if k.startswith("node_step_time/")]) == 3
    c.sim.remove_nodes([nodes[2]])  # graceful drain (the slowest node)
    m = c.scaler.read_metrics(c.registry)
    times = {k: v for k, v in m.items() if k.startswith("node_step_time/")}
    assert len(times) == 2, times
    assert f"node_step_time/{nodes[2]}" not in times
    assert m["step_time"] == pytest.approx(0.15)  # median of survivors
    c.shutdown()


def test_retire_source_is_idempotent_and_scoped():
    c = VirtualCluster(n_compute=1)
    agent = c.sim.nodes[c.head_id].agent
    agent.report_serving({"tokens_per_s": 5.0}, source="replica-0")
    agent.report_serving({"tokens_per_s": 7.0}, source="replica-1")
    assert c.scaler.read_metrics(c.registry)["tokens_per_s"] == 12.0
    agent.retire_source("replica-0")
    agent.retire_source("replica-0")  # idempotent
    m = c.scaler.read_metrics(c.registry)
    assert m["tokens_per_s"] == 7.0, "only the retired source tombstones"
    c.shutdown()


def test_metrics_ttl_ages_out_killed_replica_without_drain():
    """A replica killed WITHOUT start_drain never tombstones its keys —
    its last snapshot would skew the fleet p95 forever. report_serving
    stamps metrics/<src>/__ts; with metrics_ttl_s set the autoscaler
    skips sources whose stamp went stale, so the fleet snapshot converges
    to the survivors."""
    c = VirtualCluster(n_compute=1, metrics_ttl_s=1.0)
    agent = c.sim.nodes[c.head_id].agent
    agent.report_serving({"tokens_per_s": 5.0, "latency_p95_ms": 900.0},
                         source="replica-0")
    agent.report_serving({"tokens_per_s": 7.0, "latency_p95_ms": 40.0},
                         source="replica-1")
    m = c.scaler.read_metrics(c.registry)
    assert m["tokens_per_s"] == 12.0 and m["latency_p95_ms"] == 900.0
    # replica-0 is killed (no drain, no tombstones); replica-1 lives on
    c.clock.advance(0.6)
    agent.report_serving({"tokens_per_s": 7.0, "latency_p95_ms": 40.0},
                         source="replica-1")
    m = c.scaler.read_metrics(c.registry)
    assert m["tokens_per_s"] == 12.0, "inside the TTL the ghost lingers"
    c.clock.advance(0.6)  # replica-0's stamp is now 1.2s old (> TTL)
    agent.report_serving({"tokens_per_s": 7.0, "latency_p95_ms": 40.0},
                         source="replica-1")
    m = c.scaler.read_metrics(c.registry)
    assert m["tokens_per_s"] == 7.0
    assert m["latency_p95_ms"] == 40.0, "ghost p95 no longer pins the max"
    assert not any(k.endswith("/replica-0") for k in m), m
    # the liveness stamp never leaks into the aggregates as a metric
    assert not any("__ts" in k for k in m), m
    c.shutdown()


def test_metrics_ttl_spares_plain_and_fresh_sources():
    """Sources without a __ts stamp (step_time/queue_depth publishers —
    their keys die with the node via drain tombstones) are always fresh,
    and the filter is off entirely when metrics_ttl_s is None."""
    c = VirtualCluster(n_compute=1, metrics_ttl_s=1.0)
    node = c.compute_nodes()[0]
    agent = c.sim.nodes[node].agent
    agent.report_step_time(0, 0.25)
    head = c.sim.nodes[c.head_id].agent
    head.report_serving({"tokens_per_s": 5.0}, source="replica-0")
    c.clock.advance(5.0)
    m = c.scaler.read_metrics(c.registry)
    assert m["step_time"] == pytest.approx(0.25), "no stamp == always fresh"
    assert "tokens_per_s" not in m, "stale serving source dropped"
    c.shutdown()

    c2 = VirtualCluster(n_compute=1)  # TTL disabled (default None)
    head2 = c2.sim.nodes[c2.head_id].agent
    head2.report_serving({"tokens_per_s": 5.0}, source="replica-0")
    c2.clock.advance(1e6)
    assert c2.scaler.read_metrics(c2.registry)["tokens_per_s"] == 5.0
    c2.shutdown()


# ---------------------------------------------------------------------------
# prefix-cache eviction: hit-count-weighted reclaim + residency cap
# ---------------------------------------------------------------------------


def _prompt(seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, (P,), dtype=np.int32)


def _prefill(bm, rid, prompt, gen_len=8):
    slot = bm.admit(rid, gen_len, prefilling=True, prompt=prompt)
    for pos in range(bm.cached_prefix_len(slot), P):
        bm.ensure(slot, pos)
    bm.finish_prefill(slot)
    return slot


def test_hit_weighted_reclaim_keeps_hot_blocks():
    # pool: room for two retired prompts' blocks (8) + one live request
    bm = BlockManager(CFG, ENV0, num_slots=2, prompt_len=P, max_gen=8,
                      block_size=BS, num_blocks=1 + 12)
    hot, cold = _prompt(1), _prompt(2)
    bm.evict(_prefill(bm, 0, hot))
    bm.evict(_prefill(bm, 1, cold))
    for rid in (2, 3):  # two more hits on the hot template
        bm.evict(_prefill(bm, rid, hot))
    # a big unique-prompt request must reclaim retained blocks: the COLD
    # template's, despite the hot one being older (pure LRU would evict
    # the hot blocks first — that is exactly the policy bug)
    s = bm.admit(9, 8, prefilling=True, prompt=_prompt(3))
    for pos in range(P + 7):
        bm.ensure(s, pos)
    assert bm.probe_prefix(hot) == P - 1, "hot template must survive"
    assert bm.probe_prefix(cold) < P - 1, "cold template must be reclaimed"


def test_zero_hit_reclaim_degenerates_to_lru():
    bm = BlockManager(CFG, ENV0, num_slots=2, prompt_len=P, max_gen=8,
                      block_size=BS, num_blocks=1 + 12)
    older, newer = _prompt(4), _prompt(5)
    bm.evict(_prefill(bm, 0, older))
    bm.evict(_prefill(bm, 1, newer))
    s = bm.admit(9, 8, prefilling=True, prompt=_prompt(6))
    for pos in range(P + 7):
        bm.ensure(s, pos)
    assert bm.probe_prefix(older) < P - 1, "ties must reclaim LRU-first"
    assert bm.probe_prefix(newer) == P - 1


def test_max_shared_fraction_caps_cache_residency():
    # 28 usable blocks, cap at 0.25 -> at most 7 registered blocks: one
    # tenant churning distinct templates cannot monopolize the pool
    bm = BlockManager(CFG, ENV0, num_slots=2, prompt_len=P, max_gen=8,
                      block_size=BS, num_blocks=1 + 28,
                      max_shared_fraction=0.25)
    for rid in range(5):  # 5 distinct prompts x 4 full blocks each
        bm.evict(_prefill(bm, rid, _prompt(100 + rid)))
    assert len(bm._hash_of) <= 7
    assert len(bm._reclaim) <= 7
    # capped-out registration still frees normally (no leak): the pool
    # releases clean
    bm.release()


def test_max_shared_fraction_validated():
    with pytest.raises(ValueError):
        BlockManager(CFG, ENV0, num_slots=2, prompt_len=P, max_gen=8,
                     block_size=BS, max_shared_fraction=1.5)


def test_residency_cap_engine_end_to_end():
    # the cap flows through make_kv_backend/ServingEngine and the serve
    # output is unaffected (eviction policy is a capacity policy, never a
    # correctness policy)
    trace = _shared_trace(n=8)
    base = run_to_completion(_single(block_size=BS), _fresh(trace), dt=0.05)
    eng = _single(block_size=BS, max_shared_fraction=0.25)
    out = run_to_completion(eng, _fresh(trace), dt=0.05)
    assert out == base
    cap = int(0.25 * eng.pool.usable_blocks)
    assert len(eng.pool._hash_of) <= cap


# ---------------------------------------------------------------------------
# fleet metrics rollup
# ---------------------------------------------------------------------------


def test_fleet_hit_rate_is_count_weighted_not_a_mean_of_ratios():
    """Affine routing concentrates a template on one replica — idle
    replicas reporting a 0.0 ratio must not drag the fleet hit rate down
    in proportion to how well the routing works."""
    rs = _fleet(replicas=3, routing="prefix", num_slots=2, block_size=BS)
    # single template at a sequential rate: after the cold miss, every
    # request hits on ONE replica; the other two never see traffic
    run_to_completion(rs, _shared_trace(n=6, n_prefixes=1, rate=2.0),
                      dt=0.05)
    hits = sum(r.pool.prefix_hit_tokens for r in rs.replicas)
    lookups = sum(r.pool.prefix_lookup_tokens for r in rs.replicas)
    fleet = rs.snapshot()["prefix_hit_rate"]
    assert fleet == pytest.approx(hits / lookups)
    # at least one replica never saw traffic; its 0.0 ratio must not be
    # averaged in (the served traffic hits at ~0.5-0.6, so a mean over 3
    # replicas would sit far below the true rate)
    ratios = [r.pool.prefix_hit_rate for r in rs.replicas]
    assert 0.0 in ratios, "test needs an idle replica"
    assert fleet > sum(ratios) / len(ratios)
    assert fleet >= 0.5


def test_fleet_snapshot_rolls_up_and_stays_monotonic_across_release():
    rs = _fleet(replicas=2, num_slots=2, drain_mode="preempt")
    trace = _trace(n=10, rate=32.0)
    rs.submit(trace)
    for _ in range(6):
        rs.step()
        rs.clock.sleep(0.05)
    rs.reconcile(1)  # preempt-drain one replica mid-serve
    pre = rs.snapshot()["preemptions"]
    assert pre > 0
    while not rs.drained():
        rs.step()
        rs.clock.sleep(0.05)
    snap = rs.snapshot()
    assert snap["preemptions"] >= pre, \
        "released replicas' counters must stay absorbed in fleet totals"
    assert snap["replicas_live"] == 1.0
    assert rs.completed_count == 10
    srcs = rs.metric_sources()
    assert "router" in srcs and "queue_depth" in srcs["router"]
    for name, m in srcs.items():
        if name != "router":
            assert "queue_depth" not in m, \
                "replica sources must not multiply the router's depth"
