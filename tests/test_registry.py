"""Registry semantics: Consul-analogue behaviors the paper relies on."""
import pytest

from repro.core.clock import ManualClock
from repro.core.membership import HPC_SERVICE
from repro.core.registry import (RegistryError, ReplicatedRegistry,
                                 ServiceRegistry)


def mk(n=1):
    clock = ManualClock()
    reg = (ServiceRegistry(clock) if n == 1
           else ReplicatedRegistry(n, clock))
    return clock, reg


def test_register_and_catalog():
    clock, reg = mk()
    reg.register(HPC_SERVICE, "n1", "simnet://n1", ttl=2.0,
                 meta={"n_devices": "4"})
    reg.register(HPC_SERVICE, "n2", "simnet://n2", ttl=2.0)
    cat = reg.catalog(HPC_SERVICE)
    assert [e.node_id for e in cat] == ["n1", "n2"]
    assert cat[0].meta["n_devices"] == "4"


def test_ttl_expiry_reaps_silent_node():
    clock, reg = mk()
    reg.register(HPC_SERVICE, "n1", "a", ttl=2.0)
    reg.register(HPC_SERVICE, "n2", "a", ttl=2.0)
    clock.advance(1.5)
    reg.heartbeat(HPC_SERVICE, "n1")  # n2 goes silent
    clock.advance(1.0)
    reaped = reg.sweep()
    assert [e.node_id for e in reaped] == ["n2"]
    assert [e.node_id for e in reg.catalog(HPC_SERVICE)] == ["n1"]


def test_heartbeat_after_dereg_returns_false():
    _, reg = mk()
    reg.register(HPC_SERVICE, "n1", "a")
    reg.deregister(HPC_SERVICE, "n1")
    assert reg.heartbeat(HPC_SERVICE, "n1") is False


def test_index_monotonic_and_kv_versioning():
    _, reg = mk()
    i1 = reg.kv_put("k", "v1")
    i2 = reg.kv_put("k", "v2")
    assert i2 > i1
    assert reg.kv_get("k").value == "v2"
    assert reg.kv_get("k").modify_index == i2


def test_replicated_write_survives_minority_failure():
    clock, reg = mk(3)
    reg.register(HPC_SERVICE, "n1", "a")
    reg.replicas[2].alive = False  # one follower down: quorum still 2/3
    reg.register(HPC_SERVICE, "n2", "a")
    assert len(reg.catalog(HPC_SERVICE)) == 2


def test_leader_failover_preserves_state():
    clock, reg = mk(3)
    reg.register(HPC_SERVICE, "n1", "a")
    reg.kv_put("key", "val")
    reg.kill_leader()
    with pytest.raises(RegistryError):
        reg.register(HPC_SERVICE, "n2", "a")
    new_leader = reg.failover()
    assert new_leader != "consul-0"
    assert reg.kv_get("key").value == "val"
    reg.register(HPC_SERVICE, "n2", "a")  # writes work again
    assert len(reg.catalog(HPC_SERVICE)) == 2


def test_no_quorum_blocks_writes():
    clock, reg = mk(3)
    reg.replicas[1].alive = False
    reg.replicas[2].alive = False
    with pytest.raises(RegistryError):
        reg.register(HPC_SERVICE, "n1", "a")


def test_revived_replica_catches_up():
    clock, reg = mk(3)
    reg.replicas[2].alive = False
    reg.kv_put("k", "v")
    reg.revive(2)
    assert reg.replicas[2].kv_get("k").value == "v"
