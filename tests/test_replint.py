"""replint: each rule fires on its positive fixture, stays silent on the
negative, honors reasoned suppressions — and the repo's own src/ tree
lints clean (the self-run that makes the CI gate meaningful). The engine
itself (suppression grammar, unused-suppression notes, JSON report, CLI
exit codes) is covered alongside.

The fixture corpus lives in tests/fixtures/replint/ and is scanned as
ONE corpus (protocol and schema rules are corpus-wide); assertions
filter by (rule, file) so a positive for one rule may legitimately trip
another.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis.core import run_lint
from repro.analysis.rules import ALL_RULES

HERE = Path(__file__).parent
FIXTURES = HERE / "fixtures" / "replint"
REPO = HERE.parent

# rule id -> fixture subdir (serve/ exercises the path-scoped rules)
RULE_DIRS = {"R001": "serve", "R002": "serve", "R003": "serve",
             "R004": "any", "R005": "serve", "R006": "any"}

_RESULT = run_lint([str(FIXTURES)])


def _in_file(rule, fname, *, suppressed=None):
    out = [f for f in _RESULT.findings
           if f.rule == rule and f.path.endswith(fname)]
    if suppressed is not None:
        out = [f for f in out if f.suppressed == suppressed]
    return out


def test_registry_covers_all_six_rules():
    assert sorted(cls.id for cls in ALL_RULES) == sorted(RULE_DIRS)


def test_every_rule_fires_on_its_positive_fixture():
    for rule, d in RULE_DIRS.items():
        hits = _in_file(rule, f"{d}/r{rule[1:]}_pos.py",
                        suppressed=False)
        assert hits, f"{rule} produced no finding on its positive fixture"


def test_every_rule_is_silent_on_its_negative_fixture():
    for rule, d in RULE_DIRS.items():
        hits = _in_file(rule, f"{d}/r{rule[1:]}_neg.py")
        assert not hits, f"{rule} false-positived on its negative " \
                         f"fixture: {[f.format() for f in hits]}"


def test_suppressed_fixtures_are_suppressed_with_reasons():
    for rule, d in RULE_DIRS.items():
        hits = _in_file(rule, f"{d}/r{rule[1:]}_sup.py")
        assert hits, f"{rule} never fired on its suppressed fixture"
        assert all(f.suppressed and f.reason for f in hits), \
            f"{rule} suppression lost its reason: " \
            f"{[f.format() for f in hits]}"


def test_r004_distinguishes_missing_method_from_renamed_param():
    msgs = [f.message for f in _in_file("R004", "any/r004_pos.py")]
    assert any("missing" in m and "victim" in m for m in msgs)
    assert any("positional arg" in m and "`queue`" in m for m in msgs)


def test_suppression_without_reason_is_an_engine_finding(tmp_path):
    f = tmp_path / "bad.py"
    f.write_text("import time\nx = time.time()  # replint: ignore[R001]\n")
    res = run_lint([str(f)])
    assert any(fi.rule == "R000" and "no reason" in fi.message
               for fi in res.unsuppressed)


def test_directive_in_a_string_is_not_a_suppression(tmp_path):
    f = tmp_path / "doc.py"
    f.write_text('GRAMMAR = "# replint: ignore[R001] -- why"\n')
    res = run_lint([str(f)])
    assert not res.findings and not res.unused_suppressions


def test_unused_suppression_is_noted(tmp_path):
    f = tmp_path / "stale.py"
    f.write_text("# replint: ignore[R002] -- nothing here fires R002\n"
                 "x = 1\n")
    res = run_lint([str(f)])
    assert res.unused_suppressions
    assert "R002" in res.unused_suppressions[0][2]


def test_syntax_error_is_an_engine_finding(tmp_path):
    f = tmp_path / "broken.py"
    f.write_text("def oops(:\n")
    res = run_lint([str(f)])
    assert any(fi.rule == "R000" and "syntax error" in fi.message
               for fi in res.unsuppressed)


def test_json_report_round_trips():
    doc = json.loads(_RESULT.format_json())
    assert doc["files_scanned"] == _RESULT.files_scanned
    assert doc["unsuppressed"] == len(_RESULT.unsuppressed)
    assert all({"rule", "path", "line", "message"} <= set(f)
               for f in doc["findings"])


def test_self_run_src_is_clean():
    """The contract the CI step enforces: zero unsuppressed findings over
    the repo's own source tree."""
    res = run_lint([str(REPO / "src")])
    assert not res.unsuppressed, "\n".join(
        f.format() for f in res.unsuppressed)
    # and no stale suppressions rotting into blind spots
    assert not res.unused_suppressions, res.unused_suppressions


def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        capture_output=True, text=True, env=env, cwd=str(REPO))


def test_cli_exit_codes_and_json():
    dirty = _cli(str(FIXTURES), "--rules", "R002", "--format", "json")
    assert dirty.returncode == 1
    assert json.loads(dirty.stdout)["unsuppressed"] > 0
    clean = _cli("src")
    assert clean.returncode == 0, clean.stdout + clean.stderr
    assert "0 finding(s)" in clean.stdout
