"""Rollout subsystem: seeded fan-out reproducibility (bit-identical
across replica and slot counts), follow_up seed lineage and arrival
ordering, scorers, DPO preference training (loss decreases), the
generate -> score -> train loop publishing phase metrics through the
registry to the autoscaler, the multi-turn re-entrant trace hitting the
prefix cache — plus the satellite serve-layer surfaces that ride along:
variable-length prompts through chunked prefill and swap-aware admission
(a swapped victim's planned re-admission is never starved behind fresh
arrivals).
"""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import QueueDepthPolicy, VirtualCluster
from repro.core.clock import ManualClock
from repro.core.image import ClusterImage
from repro.models import model as Mo
from repro.models.env import Env
from repro.optim.adamw import AdamWConfig
from repro.rollout import (KeywordScorer, LengthScorer, LogprobScorer,
                           PreferenceTrainer, Rollout, RolloutEngine,
                           RolloutLoop, build_pairs, pack_pair_batch,
                           pack_sequences, rollout_signature)
from repro.serve import (SERVE_PLAN, EDFPolicy, Request, SamplingParams,
                         ServingEngine, make_kv_backend,
                         make_scheduler_policy, make_serving_engine,
                         run_to_completion)

CFG = get_smoke("paper-demo")
ENV0 = Env(mesh=None, plan=SERVE_PLAN)
PARAMS = Mo.init_params(jax.random.PRNGKey(0), CFG, ENV0)
BASE, GEN = 12, 6
SP = SamplingParams(temperature=0.7, seed=3)


def _prompts(n=2, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=(BASE,), dtype=np.int32)
            for _ in range(n)]


def _engine(replicas=1, slots=4, turns=1, **kw):
    return make_serving_engine(
        CFG, PARAMS, replicas=replicas, routing="prefix", num_slots=slots,
        prompt_len=BASE + (turns - 1) * GEN, max_gen=GEN, kv="paged",
        block_size=4, prefix_cache=True,
        policy=make_scheduler_policy("fifo"), clock=ManualClock(), **kw)


def _rollout_engine(engine, n_samples=3):
    return RolloutEngine(engine, n_samples=n_samples, gen_len=GEN,
                         sampling=SP)


# ---------------------------------------------------------------------------
# seed derivation and fan-out
# ---------------------------------------------------------------------------


def test_requests_for_is_deterministic_with_distinct_seeds():
    ro = RolloutEngine(None, n_samples=4, gen_len=GEN, sampling=SP)
    prompts = _prompts(3)
    a = ro.requests_for(prompts)
    b = ro.requests_for(prompts)
    assert len(a) == 12
    assert [r.rid for r in a] == list(range(12))
    seeds = [r.sampling.seed for r in a]
    assert len(set(seeds)) == len(seeds), "per-rollout seeds must be distinct"
    assert all(r.sampling.seed == SP.derive(r.rid).seed for r in a)
    # pure function of the inputs: the verify path regenerates the trace
    assert [(r.rid, r.sampling.seed, r.arrival_t) for r in a] == \
        [(r.rid, r.sampling.seed, r.arrival_t) for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))


def test_follow_up_seed_lineage_and_ordering():
    req = Request(rid=5, prompt=np.arange(BASE, dtype=np.int32),
                  gen_len=GEN, arrival_t=0.0, sampling=SP.derive(5))
    with pytest.raises(ValueError):
        req.follow_up(rid=99)  # still in flight
    req.tokens = [7, 8, 9]
    req.t_done = 1.25
    child = req.follow_up([1, 2], rid=99)
    assert child.rid == 99 and child.turn == 1
    assert child.arrival_t == 1.25  # ordering: arrives at completion
    assert np.array_equal(
        child.prompt, np.concatenate([req.prompt, [7, 8, 9], [1, 2]]))
    # lineage derives through the turn, not the child rid: a pure
    # function of the opening request's params
    assert child.sampling.seed == SP.derive(5).derive_turn(1).seed
    # disjoint from every turn-0 rid derivation in a realistic range
    turn0 = {SP.derive(rid).seed for rid in range(10_000)}
    assert child.sampling.seed not in turn0
    grand = child
    grand.tokens, grand.t_done = [4], 2.5
    gc = grand.follow_up(rid=123, gap_s=0.5)
    assert gc.turn == 2 and gc.arrival_t == 3.0
    assert gc.sampling.seed == SP.derive(5).derive_turn(1).derive_turn(2).seed


# ---------------------------------------------------------------------------
# reproducibility: the acceptance bar
# ---------------------------------------------------------------------------


def test_rollouts_bit_identical_across_replicas_and_slots():
    """Seeded rollouts are a pure function of (params, prompt, seed):
    fleet size and slot count must not show in a single token — including
    multi-turn, where follow_up arrival times depend on fleet
    scheduling."""
    prompts = _prompts(2)
    sigs = []
    for replicas, slots in ((2, 4), (1, 2), (1, 3)):
        eng = _engine(replicas=replicas, slots=slots, turns=2)
        ro = _rollout_engine(eng)
        sigs.append(rollout_signature(
            ro.generate(prompts, dt=0.05, turns=2)))
    assert sigs[0] == sigs[1] == sigs[2]
    assert len(sigs[0]) == 2 * 3 * 2  # prompts x samples x turns


def test_generate_counts_and_coordinates():
    prompts = _prompts(2)
    ro = _rollout_engine(_engine(turns=2))
    ros = ro.generate(prompts, dt=0.05, turns=2)
    assert len(ros) == 12
    assert ro.last_tokens == sum(len(r.tokens) for r in ros) == 12 * GEN
    coords = {(r.prompt_id, r.sample_idx, r.turn) for r in ros}
    assert coords == {(p, k, t) for p in range(2) for k in range(3)
                      for t in range(2)}
    for r in ros:
        # turn-1 contexts grew by the parent completion
        assert len(r.prompt) == BASE + r.turn * GEN
        assert r.seed == (SP.derive(r.prompt_id * 3 + r.sample_idx)
                          .derive_turn(r.turn).seed if r.turn else
                          SP.derive(r.rid).seed)


def test_multiturn_trace_hits_prefix_cache():
    """Follow-up turns re-enter with grown shared prefixes — the prefix
    cache must dedup them (sibling fan-out shares the base prompt; a
    lineage's turn t shares base + t-1 completions)."""
    eng = _engine(slots=2, turns=3, kv_blocks=120)
    ro = _rollout_engine(eng, n_samples=4)
    ro.generate(_prompts(2), dt=0.05, turns=3)
    snap = eng.snapshot()
    assert snap["prefix_hit_rate"] > 0.3, snap["prefix_hit_rate"]


# ---------------------------------------------------------------------------
# scorers
# ---------------------------------------------------------------------------


def _mk_rollouts(rewards_by_tokens):
    out = []
    for i, toks in enumerate(rewards_by_tokens):
        out.append(Rollout(prompt_id=i // 2, sample_idx=i % 2, rid=i,
                           turn=0, prompt=np.arange(4, dtype=np.int32),
                           tokens=tuple(toks), seed=i))
    return out


def test_length_and_keyword_scorers():
    ros = _mk_rollouts([[1, 2, 3], [1, 2, 3, 4, 5, 6], [9, 9], [1, 9]])
    ls = LengthScorer(target=3)
    assert ls.score(ros) == [0.0, -1.0, -1 / 3, -1 / 3]
    ks = KeywordScorer(keywords=(9,))
    assert ks.score(ros) == [0.0, 0.0, 1.0, 0.5]


def test_logprob_scorer_is_deterministic_and_finite():
    ros = _mk_rollouts([[1, 2, 3], [4, 5], [6, 7, 8, 9]])
    sc = LogprobScorer(CFG, PARAMS)
    a, b = sc.score(ros), sc.score(ros)
    assert a == b
    assert all(math.isfinite(x) and x < 0.0 for x in a)


# ---------------------------------------------------------------------------
# preference pairs and the DPO update
# ---------------------------------------------------------------------------


def test_build_pairs_skips_ties_and_orders_by_reward():
    ros = _mk_rollouts([[1], [2], [3], [4]])
    ros[0].reward, ros[1].reward = 1.0, -1.0  # prompt 0: clear pair
    ros[2].reward = ros[3].reward = 0.5       # prompt 1: tie, no signal
    pairs = build_pairs(ros)
    assert len(pairs) == 1
    chosen, rejected = pairs[0]
    assert chosen.rid == 0 and rejected.rid == 1


def test_pack_sequences_masks_completion_positions():
    ros = _mk_rollouts([[5, 6], [7]])
    toks, mask = pack_sequences(ros)
    assert toks.shape == (2, 6) and mask.shape == (2, 5)
    # prompt is arange(4): completion labels sit at positions 3..3+len-1
    assert mask[0].tolist() == [0, 0, 0, 1, 1]
    assert mask[1].tolist() == [0, 0, 0, 1, 0]
    assert toks[0].tolist() == [0, 1, 2, 3, 5, 6]


def test_pack_pair_batch_pads_to_fixed_shape():
    ros = _mk_rollouts([[1], [2], [3, 4], [5, 6]])
    ros[0].reward, ros[1].reward = 1.0, 0.0
    ros[2].reward, ros[3].reward = 0.0, 1.0
    batch = pack_pair_batch(build_pairs(ros), pad_pairs=4, pad_len=9)
    assert batch["chosen"].shape == (4, 9)
    assert batch["chosen_mask"].shape == (4, 8)
    assert batch["pair_mask"].tolist() == [1.0, 1.0, 0.0, 0.0]


def test_dpo_loss_decreases_and_prefers_chosen():
    rng = np.random.default_rng(0)
    ros = []
    for pid in range(3):
        prompt = rng.integers(0, CFG.vocab_size, (BASE,), dtype=np.int32)
        for k in range(2):
            toks = tuple(int(t) for t in
                         rng.integers(0, CFG.vocab_size, (GEN,)))
            ros.append(Rollout(prompt_id=pid, sample_idx=k,
                               rid=pid * 2 + k, turn=0, prompt=prompt,
                               tokens=toks, seed=0, reward=float(k)))
    trainer = PreferenceTrainer(
        CFG, PARAMS, beta=0.5,
        opt=AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=32,
                        weight_decay=0.0))
    m = trainer.train(build_pairs(ros), steps=6)
    assert m["pairs_per_round"] == 3.0
    assert m["train_loss"] < m["train_loss_first"], m
    assert m["dpo_margin"] > 0.0, "chosen must gain probability mass"
    assert m["train_loss_first"] == pytest.approx(math.log(2.0), abs=1e-4)
    # no pairs is a no-op round, not an error
    assert PreferenceTrainer(CFG, PARAMS).train([])["pairs_per_round"] == 0.0


# ---------------------------------------------------------------------------
# the loop: phase metrics flow to the autoscaler
# ---------------------------------------------------------------------------


def test_loop_round_publishes_phase_metrics_to_registry():
    image = ClusterImage.build(f"{CFG.name}-ro", CFG, SERVE_PLAN, "serve")
    cluster = VirtualCluster(
        n_compute=1, image=image,
        policy=QueueDepthPolicy(target_per_node=2, max_nodes=3))
    eng = make_serving_engine(
        CFG, PARAMS, replicas=1, routing="prefix", num_slots=4,
        prompt_len=BASE, max_gen=GEN, kv="paged", block_size=4,
        prefix_cache=True, policy=make_scheduler_policy("fifo"),
        clock=cluster.clock)
    ro = RolloutEngine(eng, n_samples=3, gen_len=GEN, sampling=SP)
    trainer = PreferenceTrainer(
        CFG, PARAMS, opt=AdamWConfig(lr=1e-3, warmup_steps=0,
                                     total_steps=8, weight_decay=0.0))
    loop = RolloutLoop(
        cluster, ro,
        KeywordScorer(keywords=tuple(range(CFG.vocab_size // 4))),
        trainer, prompts=_prompts(2), dt=0.05, train_steps=2)
    phase = loop.round()
    assert phase["rollout_tokens"] == 6 * GEN
    assert phase["pairs_per_round"] >= 1.0
    # ... through the registry KV into the very metrics dict the scaling
    # policies decide on
    ms = cluster.scaler.read_metrics(cluster.registry)
    for key in ("rollout_tokens", "reward_mean", "pairs_per_round",
                "train_loss"):
        assert ms.get(key) == pytest.approx(phase[key], abs=1e-4), \
            (key, ms.get(key))
    # training actually moved the serving params (round 2 is on-policy)
    before = jax.tree_util.tree_leaves(PARAMS)[0]
    after = jax.tree_util.tree_leaves(eng.params)[0]
    assert not np.array_equal(np.asarray(before), np.asarray(after))
    loop.retire()
    ms2 = cluster.scaler.read_metrics(cluster.registry)
    assert "rollout_tokens" not in ms2, "retired source must tombstone"
    cluster.shutdown()


# ---------------------------------------------------------------------------
# satellite: variable-length prompts through chunked prefill
# ---------------------------------------------------------------------------


def test_shorter_prompts_admit_and_match_exact_length_engine():
    """A chunk-prefill engine accepts any prompt length up to its budget;
    the emitted tokens must match an engine whose budget equals the
    prompt exactly (the fp path is per-token either way)."""
    rng = np.random.default_rng(7)
    short = rng.integers(0, CFG.vocab_size, (BASE,), dtype=np.int32)
    big = ServingEngine(CFG, PARAMS, num_slots=2, prompt_len=BASE + GEN,
                        max_gen=GEN, clock=ManualClock())
    out_big = run_to_completion(
        big, [Request(rid=0, prompt=short, gen_len=GEN)], dt=0.05)
    exact = ServingEngine(CFG, PARAMS, num_slots=2, prompt_len=BASE,
                          max_gen=GEN, clock=ManualClock())
    out_exact = run_to_completion(
        exact, [Request(rid=0, prompt=short, gen_len=GEN)], dt=0.05)
    assert out_big == out_exact
    # over-budget prompts still refuse admission
    too_long = rng.integers(0, CFG.vocab_size, (2 * BASE + GEN,),
                            dtype=np.int32)
    with pytest.raises(ValueError):
        big.submit([Request(rid=1, prompt=too_long, gen_len=GEN)])


# ---------------------------------------------------------------------------
# satellite: swap-aware admission (planned re-admission, no starvation)
# ---------------------------------------------------------------------------

_VICTIM_SP = SamplingParams(temperature=0.9, top_k=40, top_p=0.95, seed=7)


def _swap_req(rid, prompt_len=16, gen_len=6, **kw):
    rng = np.random.default_rng(100 + rid)
    return Request(rid=rid,
                   prompt=rng.integers(0, CFG.vocab_size, (prompt_len,),
                                       dtype=np.int32),
                   gen_len=gen_len, **kw)


def test_swapped_victim_is_not_starved_behind_fresh_arrivals():
    """The regression the planned-resume admission fixes: an EDF engine
    swaps a deadline-free victim out for an urgent arrival, then a
    stream of fresh tight-deadline requests keeps the slot contended.
    Opportunistic can_resume probes would let every fresh arrival jump
    the victim (EDF prefers their deadlines) until the stream ends;
    plan_resume takes a standing reservation, so the victim re-admits
    ahead of the fresh tail instead of dead last."""
    eng = ServingEngine(CFG, PARAMS, num_slots=1, prompt_len=16, max_gen=8,
                        policy=EDFPolicy(preemptive=True, min_slack_s=1.0),
                        swap=True, clock=ManualClock())
    victim = _swap_req(0, gen_len=8, sampling=_VICTIM_SP)
    urgent = _swap_req(1, gen_len=2, arrival_t=0.12, deadline_s=0.4)
    fresh = [_swap_req(rid, gen_len=2, arrival_t=0.12 + 0.05 * i,
                       deadline_s=2.0)
             for i, rid in enumerate(range(2, 8))]
    reqs = [victim, urgent] + fresh
    out = run_to_completion(eng, reqs, dt=0.05)
    assert len(out) == len(reqs)
    assert eng.metrics.preemptions >= 1
    assert eng.metrics.recomputed_tokens == 0, \
        "victim must resume from the swap tier, not restart"
    last_fresh_done = max(r.t_done for r in fresh)
    assert victim.t_done < last_fresh_done, \
        (f"victim finished at {victim.t_done} after the whole fresh "
         f"stream ({last_fresh_done}) — starved")
    # the victim's output survived the round trip bit-identically
    solo = run_to_completion(
        ServingEngine(CFG, PARAMS, num_slots=1, prompt_len=16, max_gen=8,
                      clock=ManualClock()),
        [_swap_req(0, gen_len=8, sampling=_VICTIM_SP)], dt=0.05)
    assert out[0] == solo[0]


def test_plan_resume_reserves_and_swap_in_consumes():
    """Backend contract: plan_resume takes a standing reservation that
    shrinks free_unreserved (fresh admissions queue behind it), peers
    sharing the host pool cannot plan or resume a planned rid, swap_in
    consumes the plan, and cancel_resume_plans releases it."""
    from repro.serve.blocks import HostSwapPool
    host = HostSwapPool(None)
    mk = lambda: make_kv_backend("paged", CFG, ENV0, num_slots=2,
                                 prompt_len=16, max_gen=8, swap=True,
                                 swap_pool=host)
    a, b = mk(), mk()
    slot = a.admit(0, 8)
    a.ensure(slot, 15)  # allocate the prompt's blocks
    assert a.swap_out(slot)
    free0 = a.free_unreserved
    assert a.plan_resume(0)
    assert a.free_unreserved < free0, "plan must hold a reservation"
    assert a.plan_resume(0), "planning is idempotent"
    # the plan is fleet-exclusive: the peer can neither plan nor resume
    assert b.has_swapped(0) and not b.plan_resume(0)
    assert not b.can_resume(0)
    assert a.can_resume(0)
    s2 = a.swap_in(0)
    assert a.free_unreserved <= free0  # plan consumed, blocks live again
    a.evict(s2)
    assert a.free_unreserved == free0
    # cancel path: plan then release the reservation without resuming
    s3 = a.admit(1, 8)
    a.ensure(s3, 15)
    assert a.swap_out(s3)
    assert a.plan_resume(1)
    a.cancel_resume_plans()
    assert a.free_unreserved == free0
    assert b.plan_resume(1), "released plans are up for grabs by peers"
    b.cancel_resume_plans()
    a.drop_swapped(1)
    a.release()
    b.release()
