"""METRIC_SCHEMA holds the stringly-typed metrics plane together: the
declared key set (serve/metrics.py) must exactly cover what the
autoscaler's aggregation tables fold, what ServingMetrics publishes, and
what retire_source tombstones — a key missing from any hop is a silent
no-op on the reading side. replint R005 checks the names statically;
these tests drive the actual plane end to end."""
from repro.core import VirtualCluster
from repro.core.autoscaler import (SERVING_MAX_METRICS, SERVING_MEAN_METRICS,
                                   SERVING_SUM_METRICS)
from repro.rollout.loop import PHASE_METRICS
from repro.serve.metrics import METRIC_SCHEMA, ServingMetrics

# aggregated by dedicated read_metrics code paths rather than the tables:
# queue_depth sums plain per-node publishers too, step_time is the median
# of the training plane's report_step_time values
TABLE_EXEMPT = {"queue_depth", "step_time"}

BACKEND_KEYS = {"kv_block_occupancy", "prefix_hit_rate",
                "kv_shared_occupancy", "swapped_blocks", "swap_out_bytes",
                "swap_in_bytes", "kv_quant_divergence"}


def test_aggregation_tables_partition_the_schema():
    tables = (set(SERVING_MAX_METRICS), set(SERVING_SUM_METRICS),
              set(SERVING_MEAN_METRICS))
    for i, a in enumerate(tables):
        for b in tables[i + 1:]:
            assert not (a & b), f"key folded twice: {a & b}"
    folded = set().union(*tables)
    assert folded | TABLE_EXEMPT == METRIC_SCHEMA, (
        "schema and aggregation tables diverged: "
        f"untabled={METRIC_SCHEMA - folded - TABLE_EXEMPT}, "
        f"unscheduled={folded - METRIC_SCHEMA}")


def test_declared_publisher_key_sets_are_schema_members():
    assert set(PHASE_METRICS) <= METRIC_SCHEMA
    assert BACKEND_KEYS <= METRIC_SCHEMA


def test_snapshot_publishes_only_schema_keys():
    sm = ServingMetrics(window_s=10.0)
    sm.record_tokens(1.0, 8)
    sm.record_spec(4, 3, 4)
    sm.record_prefill_tokens(16)
    sm.record_prefill_tokens(4, recompute=True)
    snap = sm.snapshot(2.0, queue_depth=3, slot_occupancy=0.5,
                       **{k: 0.25 for k in BACKEND_KEYS})
    assert set(snap) <= METRIC_SCHEMA, set(snap) - METRIC_SCHEMA


def test_rollup_and_tombstone_cover_the_same_keys():
    """Publish every schema key through report_serving: read_metrics must
    produce a fleet aggregate for each, and retire_source must tombstone
    each — the same set, no stragglers on either path."""
    published = {k: 1.0 for k in sorted(METRIC_SCHEMA - {"step_time"})}
    c = VirtualCluster(n_compute=1)
    try:
        agent = c.sim.nodes[c.head_id].agent
        agent.report_serving(dict(published), source="replica-0")
        m = c.scaler.read_metrics(c.registry)
        missing = set(published) - set(m)
        assert not missing, f"published but never aggregated: {missing}"

        agent.retire_source("replica-0")
        kv = c.registry.kv_prefix("metrics/replica-0/")
        tombstoned = {key.split("/", 2)[2]
                      for key, entry in kv.items() if not entry.value}
        assert set(published) <= tombstoned, \
            set(published) - tombstoned
        m = c.scaler.read_metrics(c.registry)
        left = {k for k in m if k.startswith("node_") and
                k.endswith("/replica-0")}
        assert not left, f"keys survived retirement: {left}"
    finally:
        c.shutdown()
