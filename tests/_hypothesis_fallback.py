"""Tiny deterministic stand-in for hypothesis (optional test dep).

When hypothesis isn't installed, property tests import these shims and run
each property over a small fixed set of examples drawn deterministically
from the declared strategies — no shrinking, no randomization, but every
suite collects and every property gets exercised from a clean checkout
(`pip install -r requirements.txt` brings in the real thing).

Usage (in test modules):

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, examples):
        self.examples = list(examples)


def sampled_from(xs):
    return _Strategy(xs)


def booleans():
    return _Strategy([False, True])


def integers(min_value=0, max_value=100):
    mid = (min_value + max_value) // 2
    return _Strategy(sorted({min_value, mid, max_value}))


def floats(min_value=0.0, max_value=1.0):
    mid = (min_value + max_value) / 2.0
    return _Strategy(sorted({min_value, mid, max_value}))


def lists(elements: _Strategy, min_size=0, max_size=10):
    rnd = random.Random(0)
    out = []
    for n in sorted({min_size, (min_size + max_size) // 2, max_size}):
        out.append([rnd.choice(elements.examples) for _ in range(n)])
    return _Strategy(out)


class _St:
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    lists = staticmethod(lists)


st = _St()


def given(**strategies):
    """Run the property once per example row; row i takes example
    i % len(examples) from each strategy (cycled), so every strategy's
    examples all appear at least once."""
    def deco(fn):
        def runner():
            n = max(len(s.examples) for s in strategies.values())
            for i in range(n):
                kwargs = {name: s.examples[i % len(s.examples)]
                          for name, s in strategies.items()}
                fn(**kwargs)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner
    return deco


def settings(**_kwargs):
    def deco(fn):
        return fn
    return deco
