"""Tiered KV: the int8 quantized paged backend (bounded-divergence
contract, slot-placement invariance, byte footprint) and host swap-out
preemption (bit-identical resume with recomputed_tokens == 0, restart
fallback when the host budget is exhausted, fleet-shared pool across a
drain, leak-checked detach), plus the satellite surfaces that ride along:
the adaptive speculative draft depth and the load_score capacity
tiebreak for heterogeneous fleets.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.clock import ManualClock
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve import (SERVE_PLAN, AdaptiveSpecK, EDFPolicy, HostSwapPool,
                         QuantBlockManager, ReplicaEngine, ReplicaSet,
                         Request, SamplingParams, ServingEngine,
                         make_kv_backend, poisson_trace, run_to_completion)

CFG = get_smoke("paper-demo")
ENV0 = Env(mesh=None, plan=SERVE_PLAN)
PARAMS = Mo.init_params(jax.random.PRNGKey(0), CFG, ENV0)
P = 16  # prompt length used throughout


def _engine(num_slots=2, max_gen=8, clock=None, **kw):
    return ServingEngine(CFG, PARAMS, num_slots=num_slots, prompt_len=P,
                         max_gen=max_gen, clock=clock or ManualClock(), **kw)


def _req(rid, gen_len=6, arrival_t=0.0, seed=0, sampling=None, **kw):
    rng = np.random.default_rng(seed + 100 * rid)
    return Request(rid=rid,
                   prompt=rng.integers(0, CFG.vocab_size, (P,),
                                       dtype=np.int32),
                   gen_len=gen_len, arrival_t=arrival_t,
                   sampling=sampling or SamplingParams(), **kw)


def _trace(n=8, gen_len=6, rate=32.0, seed=0, sampling=None):
    return poisson_trace(n, rate, prompt_len=P, vocab_size=CFG.vocab_size,
                         gen_len=gen_len, sampling=sampling, seed=seed)


def _fresh(trace):
    return [dataclasses.replace(r, tokens=[], t_admit=None,
                                t_first_token=None, t_done=None,
                                restarts=0)
            for r in trace]


def _pool_nbytes(pool):
    return sum(leaf.nbytes
               for leaf in jax.tree_util.tree_leaves(pool.caches))


# ---------------------------------------------------------------------------
# quantized paged backend
# ---------------------------------------------------------------------------


def test_quant_backend_registry_and_describe():
    pool = make_kv_backend("quant", CFG, ENV0, num_slots=2,
                           prompt_len=P, max_gen=8)
    try:
        assert isinstance(pool, QuantBlockManager) and pool.kind == "quant"
        assert "int8" in pool.describe()
        assert 0.0 < pool.metrics()["kv_quant_divergence"] < 0.05
    finally:
        pool.release()
    with pytest.raises(ValueError):
        make_kv_backend("fp4", CFG, ENV0, num_slots=2,
                        prompt_len=P, max_gen=8)


def test_quant_serves_with_bounded_divergence():
    """The int8 backend trades bit-exactness for capacity: outputs may
    drift from the fp paged engine, but on short greedy horizons almost
    every stream still matches, every request runs to its full length,
    and the calibrated divergence metric stays inside the documented
    bound (docs/serving.md, "Tiered KV")."""
    trace = _trace(n=8, gen_len=8)
    fp = run_to_completion(_engine(kv="paged"), _fresh(trace), dt=0.05)
    eng = _engine(kv="quant")
    out = run_to_completion(eng, _fresh(trace), dt=0.05)
    assert sorted(out) == sorted(fp)
    assert all(len(out[r]) == 8 for r in out)
    same = sum(out[r] == fp[r] for r in out)
    assert same >= len(out) - 2, \
        f"quant diverged on {len(out) - same}/{len(out)} greedy streams"
    assert eng.snapshot()["kv_quant_divergence"] < 0.05


def test_quant_output_is_slot_placement_invariant():
    """Self-consistency replaces the fp oracle: the same trace through
    quant engines with different slot counts (different lane packing,
    different physical block placement) must be bit-identical. This is
    the --verify contract for --kv quant."""
    trace = _trace(n=8, gen_len=8)
    a = run_to_completion(_engine(num_slots=4, kv="quant"),
                          _fresh(trace), dt=0.05)
    b = run_to_completion(_engine(num_slots=2, kv="quant"),
                          _fresh(trace), dt=0.05)
    assert a == b


def test_quant_halves_kv_bytes_per_block():
    """At an equal block count the int8 pool + f32 scales must cost
    (hd + 4) / (2 * hd) of the bf16 pool's bytes — the capacity headroom
    the tiered bench turns into admitted concurrency."""
    hd = CFG.head_dim
    fp = make_kv_backend("paged", CFG, ENV0, num_slots=2,
                        prompt_len=P, max_gen=8, kv_blocks=16)
    qt = make_kv_backend("quant", CFG, ENV0, num_slots=2,
                        prompt_len=P, max_gen=8, kv_blocks=16)
    try:
        ratio = _pool_nbytes(qt) / _pool_nbytes(fp)
        assert abs(ratio - (hd + 4) / (2 * hd)) < 0.02, ratio
    finally:
        fp.release()
        qt.release()


# ---------------------------------------------------------------------------
# host swap-out preemption
# ---------------------------------------------------------------------------

# EDF setup from test_serving_v2: a deadline-free runner is preempted for
# an urgent arrival. With swap on, the victim's blocks ride out the
# eviction on the host tier and it resumes without recompute.
_VICTIM_SP = SamplingParams(temperature=0.9, top_k=40, top_p=0.95, seed=7)


def _preempt_run(**engine_kw):
    eng = _engine(num_slots=1,
                  policy=EDFPolicy(preemptive=True, min_slack_s=1.0),
                  **engine_kw)
    out = run_to_completion(
        eng,
        [_req(0, gen_len=8, sampling=_VICTIM_SP),
         _req(1, gen_len=2, arrival_t=0.12, deadline_s=0.4)], dt=0.05)
    return eng, out


@pytest.mark.parametrize("kv", ["paged", "quant"])
def test_swap_preemption_resumes_without_recompute(kv):
    solo = run_to_completion(
        _engine(num_slots=1, kv=kv),
        [_req(0, gen_len=8, sampling=_VICTIM_SP)], dt=0.05)
    restart, out_r = _preempt_run(kv=kv, swap=False)
    swap, out_s = _preempt_run(kv=kv, swap=True)
    for eng, out in ((restart, out_r), (swap, out_s)):
        assert eng.metrics.preemptions >= 1
        assert out[0] == solo[0], "victim stream must survive preemption"
    # the restart path pays the prompt + generated prefix again ...
    assert restart.metrics.recomputed_tokens > 0
    assert restart.pool.metrics().get("swapped_blocks", 0.0) == 0.0
    # ... the swap path pays nothing: blocks round-trip through the host
    assert swap.metrics.recomputed_tokens == 0
    pm = swap.pool.metrics()
    assert pm["swapped_blocks"] > 0
    assert pm["swap_out_bytes"] == pm["swap_in_bytes"] > 0
    snap = swap.snapshot()
    assert snap["recomputed_tokens"] == 0.0


def test_swap_budget_exhaustion_falls_back_to_restart():
    """A zero-block host budget can never store a victim: swap_out
    declines and the engine keeps its correctness via the restart path
    (same output, recompute billed) instead of deadlocking."""
    eng, out = _preempt_run(swap=True, swap_budget_blocks=0)
    solo = run_to_completion(
        _engine(num_slots=1),
        [_req(0, gen_len=8, sampling=_VICTIM_SP)], dt=0.05)
    assert out[0] == solo[0]
    assert eng.metrics.recomputed_tokens > 0, "budget 0 must restart"
    assert eng.pool.metrics()["swapped_blocks"] == 0.0


def test_fleet_drain_preempt_with_swap_migrates_requests():
    """drain_mode="preempt" + swap: victims swap out of the draining
    replica and restore onto a surviving one through the fleet-shared
    host pool — outputs stay bit-identical to an undrained single engine
    and the fleet rollup reports zero recomputed tokens."""
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=11)
    trace = _trace(n=12, rate=32.0, sampling=sp)
    base = run_to_completion(_engine(num_slots=2), _fresh(trace), dt=0.05)
    rs = ReplicaSet(CFG, PARAMS, replicas=2, routing="occupancy",
                    num_slots=2, prompt_len=P, max_gen=8,
                    clock=ManualClock(), drain_mode="preempt", swap=True)
    rs.submit(_fresh(trace))
    steps = 0
    while not rs.drained() and steps < 5000:
        rs.step()
        if steps == 6:
            rs.reconcile(1)  # preempt-drain one replica mid-serve
        rs.clock.sleep(0.05)
        steps += 1
    assert rs.drained()
    assert rs.results() == base
    snap = rs.snapshot()
    assert snap["recomputed_tokens"] == 0.0, \
        "swap drain must not recompute anything"
    if snap["preemptions"] > 0:  # drain caught in-flight work
        assert snap["swapped_blocks"] > 0
        assert snap["swap_in_bytes"] == snap["swap_out_bytes"] > 0


def test_host_pool_budget_and_leak_check():
    pool = HostSwapPool(budget_blocks=4)
    assert pool.can_store(4) and not pool.can_store(5)
    with pytest.raises(ValueError):
        HostSwapPool(budget_blocks=-1)
    # a backend that releases while requests are still swapped out leaks
    host = HostSwapPool()
    backend = make_kv_backend("paged", CFG, ENV0, num_slots=1,
                              prompt_len=P, max_gen=8,
                              swap=True, swap_pool=host)
    slot = backend.admit(0, 8)
    backend.ensure(slot, P - 1)  # allocate the prompt's blocks
    assert backend.swap_out(slot)
    assert backend.has_swapped(0) and host.blocks_resident > 0
    with pytest.raises(RuntimeError, match="leaked"):
        backend.release()  # a stranded swap record is a leak
    host.drop(0)
    assert host.blocks_resident == 0
    # a drop through the backend surface detaches clean
    host2 = HostSwapPool()
    b2 = make_kv_backend("paged", CFG, ENV0, num_slots=1,
                         prompt_len=P, max_gen=8, swap=True, swap_pool=host2)
    slot = b2.admit(1, 8)
    b2.ensure(slot, P - 1)
    assert b2.swap_out(slot)
    b2.drop_swapped(1)
    b2.release()


# ---------------------------------------------------------------------------
# satellite: adaptive speculative depth
# ---------------------------------------------------------------------------


def test_adaptive_spec_k_converges_both_ways():
    ctl = AdaptiveSpecK(cap=4)
    assert ctl.k(0) == 4  # optimistic start
    for _ in range(8):  # rejected drafts: multiplicative decrease to floor
        ctl.update(0, proposed=ctl.k(0), accepted=0)
    assert ctl.k(0) == 1
    for _ in range(8):  # clean acceptance: additive recovery to cap
        ctl.update(0, proposed=ctl.k(0), accepted=ctl.k(0))
    assert ctl.k(0) == 4
    ctl.update(1, proposed=4, accepted=2)  # half kept: hold
    assert ctl.k(1) == 4
    ctl.retire(0)
    assert ctl.k(0) == 4  # state dies with the request


def test_spec_k_auto_engine_is_bit_exact_and_adapts():
    """--spec-k auto must keep the lossless speculative contract (same
    tokens as spec off) while per-request depths actually move: a random
    prompt gives the ngram drafter near-zero acceptance, so depths decay
    from the cap."""
    trace = _trace(n=6, gen_len=8)
    base = run_to_completion(_engine(), _fresh(trace), dt=0.05)
    eng = _engine(spec="ngram", spec_k="auto")
    ctl = eng.replica._spec_ctl
    assert ctl is not None and eng.spec_k == 4
    seen = {}
    eng.submit(_fresh(trace))
    while not eng.drained():
        eng.step()
        seen.update(ctl._k)
        eng.clock.sleep(0.05)
    assert eng.results() == base, "adaptive depth broke spec exactness"
    assert seen and min(seen.values()) < 4, \
        "rejected ngram drafts must shrink some request's depth"
    assert not ctl._k, "retired requests must leave no depth state"


# ---------------------------------------------------------------------------
# satellite: load_score capacity tiebreak
# ---------------------------------------------------------------------------


def test_load_score_breaks_occupancy_ties_by_free_capacity():
    """Two empty replicas with unequal kv_blocks tie on occupancy (0.0)
    and in-flight count; the router must prefer the one with more
    absolute free blocks, not fall back to list order."""
    mk = lambda blocks: ReplicaEngine(  # noqa: E731
        CFG, PARAMS, num_slots=2, prompt_len=P, max_gen=8,
        kv_blocks=blocks, clock=ManualClock())
    small, big = mk(12), mk(48)
    try:
        assert big.load_score() < small.load_score()
        # and per-backend free_capacity is what feeds the tiebreak
        assert big.pool.free_capacity > small.pool.free_capacity
        picked = min([small, big], key=lambda r: r.load_score())
        assert picked is big
        # slot backend exposes the same surface
        slot = ReplicaEngine(CFG, PARAMS, num_slots=3, prompt_len=P,
                             max_gen=8, kv="slot", clock=ManualClock())
        try:
            assert slot.pool.free_capacity == 3
        finally:
            slot.pool.release()
    finally:
        small.pool.release()
        big.pool.release()


# ---------------------------------------------------------------------------
# satellite: recomputed_tokens split out of prefill_tokens
# ---------------------------------------------------------------------------


def test_restart_recompute_billed_separately_from_prefill():
    """A restart victim's second prefill lands in recomputed_tokens:
    prefill_tokens counts each admitted prompt exactly once, so
    tokens-per-second derived from it is no longer inflated by
    preemption churn."""
    eng, out = _preempt_run(swap=False)
    assert eng.metrics.preemptions >= 1
    m = eng.metrics
    assert m.prefill_tokens == 2 * P, "each request billed once"
    # prompt + any generated prefix the victim had to replay
    assert m.recomputed_tokens >= P
    snap = eng.snapshot()
    assert snap["recomputed_tokens"] == float(m.recomputed_tokens)
