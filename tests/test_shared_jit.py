"""Compile-once regression: the data-plane callables replint R002 chased
into the shared_jit registry (the DPO step, the scorer's completion
log-probs, the slot pool's evict) actually memoize — two instances with
the same frozen config hold the SAME jitted object, so a fleet of N
replicas traces once, and a different config gets its own entry."""
import jax

from repro.configs import get_smoke
from repro.models import model as Mo
from repro.models.env import Env
from repro.rollout import LogprobScorer, PreferenceTrainer
from repro.serve import SERVE_PLAN, make_kv_backend
from repro.serve.kv import shared_jit

CFG = get_smoke("paper-demo")
ENV = Env(mesh=None, plan=SERVE_PLAN)
PARAMS = Mo.init_params(jax.random.PRNGKey(0), CFG, ENV)


def test_shared_jit_memoizes_on_key_and_splits_on_key():
    fn_a = shared_jit(("t_memo", 1), lambda: (lambda x: x + 1))
    fn_b = shared_jit(("t_memo", 1), lambda: (lambda x: x * 2))
    fn_c = shared_jit(("t_memo", 2), lambda: (lambda x: x + 1))
    assert fn_a is fn_b  # second builder never even runs
    assert fn_a is not fn_c


def test_unhashable_key_falls_back_to_a_private_jit():
    fn_a = shared_jit(("t_unhash", [1]), lambda: (lambda x: x))
    fn_b = shared_jit(("t_unhash", [1]), lambda: (lambda x: x))
    assert fn_a is not fn_b


def test_logprob_scorers_share_one_completion_logprob_trace():
    a = LogprobScorer(CFG, PARAMS)
    b = LogprobScorer(CFG, PARAMS)
    assert a._lp is b._lp


def test_preference_trainers_share_one_dpo_step_per_config():
    a = PreferenceTrainer(CFG, PARAMS)
    b = PreferenceTrainer(CFG, PARAMS)
    assert a._step is b._step
    c = PreferenceTrainer(CFG, PARAMS, beta=0.25)  # objective differs
    assert c._step is not a._step


def test_slot_pools_share_insert_evict_and_decode_steps():
    kw = dict(num_slots=2, prompt_len=8, max_gen=4)
    a = make_kv_backend("slot", CFG, ENV, **kw)
    b = make_kv_backend("slot", CFG, ENV, **kw)
    assert a._evict is b._evict
    assert a._insert is b._insert
    assert all(a._decode[s] is b._decode[s] for s in (False, True))
