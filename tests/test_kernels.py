"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # optional test dep: falls back to fixed deterministic examples
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.paged_decode.ops import (paged_flash_decode,
                                            paged_flash_decode_quant,
                                            paged_gather_decode,
                                            paged_gather_decode_quant,
                                            quantize_kv)
from repro.kernels.paged_decode.ref import (paged_decode_quant_ref,
                                            paged_decode_ref)
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_ref_loop
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref


def _mk(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,Hq,Hkv,hd,causal,window,bq,bk",
    [
        (1, 128, 4, 4, 32, True, 0, 64, 64),
        (2, 256, 4, 2, 64, True, 0, 128, 128),
        (1, 256, 8, 1, 64, True, 64, 64, 64),  # MQA + sliding window
        (2, 128, 4, 4, 128, False, 0, 128, 128),  # bidirectional
        (1, 192, 6, 2, 32, True, 0, 64, 64),  # non-pow2 seq
    ])
def test_flash_attention_sweep(dtype, B, S, Hq, Hkv, hd, causal, window,
                               bq, bk, rng):
    ks = jax.random.split(rng, 3)
    q = _mk(ks[0], (B, S, Hq, hd), dtype)
    k = _mk(ks[1], (B, S, Hkv, hd), dtype)
    v = _mk(ks[2], (B, S, Hkv, hd), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        n_kv_heads=Hkv, block_q=bq, block_k=bk,
                        interpret=True)
    r = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=causal, window=window)
    r = r.transpose(0, 2, 1, 3).reshape(B, S, Hq * hd)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    assert float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                 - r.astype(jnp.float32)))) < tol


@settings(max_examples=8, deadline=None)
@given(
    S=st.sampled_from([64, 128, 256]),
    Hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    causal=st.booleans(),
)
def test_flash_attention_property(S, Hkv, g, causal):
    rng = jax.random.PRNGKey(S * 31 + Hkv * 7 + g)
    ks = jax.random.split(rng, 3)
    Hq, hd, B = Hkv * g, 32, 1
    q = _mk(ks[0], (B, S, Hq, hd), jnp.float32)
    k = _mk(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = _mk(ks[2], (B, S, Hkv, hd), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, n_kv_heads=Hkv,
                        block_q=64, block_k=64, interpret=True)
    r = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=causal)
    r = r.transpose(0, 2, 1, 3).reshape(B, S, Hq * hd)
    assert float(jnp.max(jnp.abs(o - r))) < 2e-3


@pytest.mark.parametrize("cur", [0, 17, 255, 511])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(cur, dtype, rng):
    B, Hq, Hkv, S, hd = 2, 8, 2, 512, 64
    ks = jax.random.split(rng, 3)
    q = _mk(ks[0], (B, Hq, hd), dtype)
    k = _mk(ks[1], (B, Hkv, S, hd), dtype)
    v = _mk(ks[2], (B, Hkv, S, hd), dtype)
    o = flash_decode(q, k, v, cur, block_k=128, interpret=True)
    r = decode_ref(q, k, v, cur)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    assert float(jnp.max(jnp.abs(o.astype(jnp.float32) - r))) < tol


def test_flash_decode_merge_identity(rng):
    """Merging two half-cache partials == attention over the full cache."""
    from repro.kernels.flash_decode.kernel import flash_decode_kernel
    B, Hq, Hkv, S, hd = 1, 4, 2, 256, 32
    ks = jax.random.split(rng, 3)
    q = _mk(ks[0], (B, Hq, hd), jnp.float32)
    k = _mk(ks[1], (B, Hkv, S, hd), jnp.float32)
    v = _mk(ks[2], (B, Hkv, S, hd), jnp.float32)
    o1, m1, l1 = flash_decode_kernel(q, k[:, :, :128], v[:, :, :128], 127,
                                     block_k=64, interpret=True)
    o2, m2, l2 = flash_decode_kernel(q, k[:, :, 128:], v[:, :, 128:], 127,
                                     block_k=64, interpret=True)
    mg = jnp.maximum(m1, m2)
    w1, w2 = l1 * jnp.exp(m1 - mg), l2 * jnp.exp(m2 - mg)
    merged = (o1 * w1 + o2 * w2) / (w1 + w2)
    ref = decode_ref(q, k, v, 255)
    assert float(jnp.max(jnp.abs(merged - ref))) < 1e-3


def _paged_setup(rng, B, Hq, Hkv, hd, bs, MB, dtype, extra_blocks=3):
    """Random pool + per-row tables drawing *disjoint, shuffled* physical
    blocks (block 0 reserved as the null block, like serve/blocks.py)."""
    NB = 1 + B * MB + extra_blocks
    ks = jax.random.split(rng, 3)
    q = _mk(ks[0], (B, Hq, hd), dtype)
    kp = _mk(ks[1], (NB, Hkv, bs, hd), dtype)
    vp = _mk(ks[2], (NB, Hkv, bs, hd), dtype)
    ids = np.random.default_rng(int(jax.random.randint(rng, (), 0, 1 << 30))
                                ).permutation(np.arange(1, NB))
    tables = jnp.asarray(ids[:B * MB].reshape(B, MB), jnp.int32)
    return q, kp, vp, tables


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,hd,bs,MB", [
    (2, 4, 2, 32, 16, 4),
    (3, 8, 8, 64, 8, 3),   # MHA
    (1, 4, 1, 128, 32, 2),  # MQA, wide blocks
])
def test_paged_decode_kernel_sweep(B, Hq, Hkv, hd, bs, MB, dtype, rng):
    q, kp, vp, tables = _paged_setup(rng, B, Hq, Hkv, hd, bs, MB, dtype)
    lengths = jnp.asarray([(i * 7) % (MB * bs) for i in range(B)], jnp.int32)
    o = paged_flash_decode(q, kp, vp, tables, lengths, interpret=True)
    r = paged_decode_ref(q, kp, vp, tables, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    assert float(jnp.max(jnp.abs(o - r))) < tol
    # the XLA gather fallback agrees too (it's what CPU serving runs)
    g = paged_gather_decode(q, kp, vp, tables, lengths)
    assert float(jnp.max(jnp.abs(g - r))) < tol


def test_paged_decode_masks_fully_and_partially(rng):
    B, Hq, Hkv, hd, bs, MB = 3, 4, 2, 32, 16, 3
    q, kp, vp, tables = _paged_setup(rng, B, Hq, Hkv, hd, bs, MB,
                                     jnp.float32)
    lengths = jnp.asarray([-1, 0, MB * bs - 1], jnp.int32)
    o = paged_flash_decode(q, kp, vp, tables, lengths, interpret=True)
    r = paged_decode_ref(q, kp, vp, tables, lengths)
    assert float(jnp.max(jnp.abs(o[0]))) == 0.0, "masked row must be zero"
    assert float(jnp.max(jnp.abs(o - r))) < 2e-3


def test_paged_decode_matches_contiguous_cache(rng):
    """Paging a contiguous cache into shuffled physical blocks must not
    change the attention output (table order == logical order)."""
    B, Hq, Hkv, hd, bs, MB = 2, 8, 2, 64, 16, 4
    S = MB * bs
    ks = jax.random.split(rng, 3)
    q = _mk(ks[0], (B, Hq, hd), jnp.float32)
    k = _mk(ks[1], (B, Hkv, S, hd), jnp.float32)
    v = _mk(ks[2], (B, Hkv, S, hd), jnp.float32)
    NB = 1 + B * MB
    perm = np.random.default_rng(0).permutation(np.arange(1, NB))
    tables = jnp.asarray(perm.reshape(B, MB), jnp.int32)
    kp = jnp.zeros((NB, Hkv, bs, hd), jnp.float32)
    vp = jnp.zeros((NB, Hkv, bs, hd), jnp.float32)
    for b in range(B):
        for j in range(MB):
            blk = slice(j * bs, (j + 1) * bs)
            kp = kp.at[tables[b, j]].set(k[b, :, blk])
            vp = vp.at[tables[b, j]].set(v[b, :, blk])
    cur = 37
    o = paged_flash_decode(q, kp, vp, tables,
                           jnp.full((B,), cur, jnp.int32), interpret=True)
    r = decode_ref(q, k, v, cur)
    assert float(jnp.max(jnp.abs(o - r))) < 2e-3


def _quantize_pool(kp, vp):
    """int8 + per-(block, head, token) scale over the head dim."""
    kq, ks = quantize_kv(kp)
    vq, vs = quantize_kv(vp)
    return kq, vq, ks, vs


@pytest.mark.parametrize("B,Hq,Hkv,hd,bs,MB", [
    (2, 4, 2, 32, 16, 4),
    (3, 8, 8, 64, 8, 3),   # MHA
    (1, 4, 1, 128, 32, 2),  # MQA, wide blocks
])
def test_paged_decode_quant_kernel_sweep(B, Hq, Hkv, hd, bs, MB, rng):
    """The quant kernel (fused in-register dequant, scales via scalar
    prefetch) must match the dequantize-everything reference exactly-ish,
    and the whole int8 scheme must stay within the divergence bound of
    the fp pool it quantized."""
    q, kp, vp, tables = _paged_setup(rng, B, Hq, Hkv, hd, bs, MB,
                                     jnp.float32)
    kq, vq, ks, vs = _quantize_pool(kp, vp)
    lengths = jnp.asarray([(i * 7) % (MB * bs) for i in range(B)], jnp.int32)
    o = paged_flash_decode_quant(q, kq, vq, ks, vs, tables, lengths,
                                 interpret=True)
    r = paged_decode_quant_ref(q, kq, vq, ks, vs, tables, lengths)
    assert float(jnp.max(jnp.abs(o - r))) < 2e-3
    # the XLA gather fallback agrees too (it's what CPU serving runs)
    g = paged_gather_decode_quant(q, kq, vq, ks, vs, tables, lengths)
    assert float(jnp.max(jnp.abs(g - r))) < 2e-3
    # bounded divergence vs the fp pool: int8-over-head-dim keeps the
    # attention output within a small relative RMS of the unquantized one
    fp = paged_decode_ref(q, kp, vp, tables, lengths)
    rmse = float(jnp.sqrt(jnp.mean((o - fp) ** 2)
                          / jnp.maximum(jnp.mean(fp ** 2), 1e-12)))
    assert rmse < 0.05, f"quant divergence {rmse} out of bound"


def test_paged_decode_quant_masks_fully_and_partially(rng):
    B, Hq, Hkv, hd, bs, MB = 3, 4, 2, 32, 16, 3
    q, kp, vp, tables = _paged_setup(rng, B, Hq, Hkv, hd, bs, MB,
                                     jnp.float32)
    kq, vq, ks, vs = _quantize_pool(kp, vp)
    lengths = jnp.asarray([-1, 0, MB * bs - 1], jnp.int32)
    o = paged_flash_decode_quant(q, kq, vq, ks, vs, tables, lengths,
                                 interpret=True)
    r = paged_decode_quant_ref(q, kq, vq, ks, vs, tables, lengths)
    assert float(jnp.max(jnp.abs(o[0]))) == 0.0, "masked row must be zero"
    assert float(jnp.max(jnp.abs(o - r))) < 2e-3


@settings(max_examples=8, deadline=None)
@given(Hkv=st.sampled_from([1, 2]), g=st.sampled_from([1, 2, 4]),
       bs=st.sampled_from([4, 8, 16]), seed=st.integers(0, 1 << 16))
def test_paged_gather_fallback_property(Hkv, g, bs, seed):
    """Property sweep of the XLA gather fallback against the reference on
    adversarial tables: a fully-masked null-block row (all-zero table),
    a mid-truncate row (suffix entries back at the null block), and rows
    at arbitrary partial depths — the block-table states serving actually
    produces around admission, truncate, and retirement."""
    rng = np.random.default_rng(seed)
    B, MB, hd = 4, 3, 32
    Hq = Hkv * g
    NB = 1 + B * MB + 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _mk(ks[0], (B, Hq, hd), jnp.float32)
    kp = _mk(ks[1], (NB, Hkv, bs, hd), jnp.float32)
    vp = _mk(ks[2], (NB, Hkv, bs, hd), jnp.float32)
    perm = list(rng.permutation(np.arange(1, NB)))
    tables = np.zeros((B, MB), np.int32)
    lengths = np.zeros((B,), np.int32)
    lengths[0] = -1  # masked row: null table, no valid positions
    tables[1, 0] = perm.pop()  # truncated back to one block
    lengths[1] = int(rng.integers(0, bs))
    for b in (2, 3):
        for j in range(MB):
            tables[b, j] = perm.pop()
        lengths[b] = int(rng.integers(0, MB * bs))
    t, L = jnp.asarray(tables), jnp.asarray(lengths)
    out = paged_gather_decode(q, kp, vp, t, L)
    ref = paged_decode_ref(q, kp, vp, t, L)
    assert float(jnp.max(jnp.abs(out[0]))) == 0.0
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-3


def test_paged_gather_truncate_regrow_invariance(rng):
    """Speculative rollback then regrowth rewrites a row's table suffix
    onto different physical blocks. Same logical KV -> bit-identical
    output, even with the freed blocks poisoned: the gather path must
    depend only on (table, pool content at named blocks, length)."""
    B, Hq, Hkv, hd, bs, MB = 1, 4, 2, 32, 8, 4
    S = MB * bs
    ks = jax.random.split(rng, 3)
    q = _mk(ks[0], (B, Hq, hd), jnp.float32)
    k = _mk(ks[1], (B, Hkv, S, hd), jnp.float32)
    v = _mk(ks[2], (B, Hkv, S, hd), jnp.float32)
    NB = 1 + 2 * MB  # room for the original AND the regrown suffix
    kp = jnp.zeros((NB, Hkv, bs, hd), jnp.float32)
    vp = jnp.zeros((NB, Hkv, bs, hd), jnp.float32)
    first = np.arange(1, MB + 1)
    for j, bid in enumerate(first):
        blk = slice(j * bs, (j + 1) * bs)
        kp = kp.at[bid].set(k[0, :, blk])
        vp = vp.at[bid].set(v[0, :, blk])
    tables = jnp.asarray(first[None, :], jnp.int32)
    cur = S - 1
    out1 = paged_gather_decode(q, kp, vp, tables,
                               jnp.asarray([cur], jnp.int32))
    # truncate the last 2 blocks, regrow onto fresh physical ids with the
    # same logical KV, and poison the old blocks with garbage
    keep = MB - 2
    regrown = np.arange(MB + 1, MB + 3)
    for j, bid in enumerate(regrown, start=keep):
        blk = slice(j * bs, (j + 1) * bs)
        kp = kp.at[bid].set(k[0, :, blk])
        vp = vp.at[bid].set(v[0, :, blk])
    for bid in first[keep:]:
        kp = kp.at[bid].set(1e6)
        vp = vp.at[bid].set(-1e6)
    tables2 = jnp.asarray(
        np.concatenate([first[:keep], regrown])[None, :], jnp.int32)
    out2 = paged_gather_decode(q, kp, vp, tables2,
                               jnp.asarray([cur], jnp.int32))
    assert jnp.array_equal(out1, out2), \
        "physical block placement leaked into the attention output"


@pytest.mark.parametrize("B,S,W,bt,bw", [
    (2, 128, 64, 32, 64), (1, 256, 128, 64, 128), (2, 64, 256, 16, 128)])
def test_rglru_kernel_sweep(B, S, W, bt, bw, rng):
    ks = jax.random.split(rng, 3)
    a = jax.random.uniform(ks[0], (B, S, W), jnp.float32, 0.05, 0.999)
    b = jax.random.normal(ks[1], (B, S, W), jnp.float32)
    h0 = jax.random.normal(ks[2], (B, W), jnp.float32)
    hk = rglru_scan(a, b, h0, block_t=bt, block_w=bw, interpret=True)
    hr = rglru_ref_loop(a, b, h0)
    assert float(jnp.max(jnp.abs(hk - hr))) < 2e-3


@settings(max_examples=6, deadline=None)
@given(decay=st.floats(0.01, 6.0), S=st.sampled_from([64, 128]))
def test_rglru_kernel_extreme_decay_property(decay, S):
    """Stability under strong decay (the log-space clip must not blow up)."""
    rng = jax.random.PRNGKey(int(decay * 1000) + S)
    ks = jax.random.split(rng, 2)
    a = jnp.exp(-decay * jax.random.uniform(ks[0], (1, S, 64), minval=0.5,
                                            maxval=1.0))
    b = jax.random.normal(ks[1], (1, S, 64), jnp.float32)
    hk = rglru_scan(a, b, None, block_t=32, block_w=64, interpret=True)
    hr = rglru_ref_loop(a, b, None)
    assert bool(jnp.all(jnp.isfinite(hk)))
    assert float(jnp.max(jnp.abs(hk - hr))) < 2e-3


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (2, 128, 2, 32, 32), (1, 64, 4, 64, 16), (1, 96, 1, 16, 32)])
def test_wkv6_kernel_sweep(B, S, H, hd, chunk, rng):
    ks = jax.random.split(rng, 5)
    mk = lambda k: jax.random.normal(k, (B, S, H, hd), jnp.float32) * 0.5
    r, k_, v = mk(ks[0]), mk(ks[1]), mk(ks[2])
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.5 - 1.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    o, sf = wkv6(r, k_, v, logw, u, chunk=chunk, interpret=True)
    orf, sr = wkv6_ref(*(a.transpose(0, 2, 1, 3) for a in (r, k_, v, logw)),
                       u)
    assert float(jnp.max(jnp.abs(o - orf.transpose(0, 2, 1, 3)))) < 2e-3
    assert float(jnp.max(jnp.abs(sf - sr))) < 2e-3


# ---------------------------------------------------------------------------
# sampling: fused top-k/top-p mask (bisection kernel vs sort-based oracle)
# ---------------------------------------------------------------------------

from repro.kernels.sampling.ops import topk_topp_mask  # noqa: E402
from repro.kernels.sampling.ref import NEG_INF, topk_topp_mask_ref  # noqa: E402


@pytest.mark.parametrize("T,V", [(4, 128), (3, 200), (1, 512)])
def test_sampling_mask_kernel_matches_oracle(T, V, rng):
    """The bisection kernel (interpret mode) must produce the oracle's
    keep-set: same survivors, same NEG_INF drops — including a non-128
    vocab that exercises the lane padding."""
    ks = jax.random.split(rng, 2)
    logits = jax.random.normal(ks[0], (T, V), jnp.float32) * 3.0
    top_k = jnp.asarray([0, 5, 1, 40][:T], jnp.int32)
    top_p = jnp.asarray([1.0, 0.9, 0.5, 0.25][:T], jnp.float32)
    got = topk_topp_mask(logits, top_k, top_p, impl="interpret")
    want = topk_topp_mask_ref(logits, top_k, top_p)
    keep_g, keep_w = got > NEG_INF / 2, want > NEG_INF / 2
    assert bool(jnp.all(keep_g == keep_w)), "keep-sets differ"
    assert bool(jnp.all(jnp.where(keep_w, got == want, True))), \
        "kept logits must pass through unchanged"


def test_sampling_mask_semantics(rng):
    """Unit semantics on a hand-checkable distribution."""
    logits = jnp.log(jnp.asarray([[0.4, 0.3, 0.2, 0.05, 0.05]],
                                 jnp.float32))
    # top_k=2 keeps exactly the two largest
    out = topk_topp_mask_ref(logits, jnp.asarray([2]), jnp.asarray([1.0]))
    assert [bool(b) for b in (out[0] > NEG_INF / 2)] == \
        [True, True, False, False, False]
    # top_p=0.65 needs {0.4, 0.3} (cumsum crosses at the second token;
    # 0.65 sits safely between 0.4 and 0.7 so fp roundoff can't flip it)
    out = topk_topp_mask_ref(logits, jnp.asarray([0]), jnp.asarray([0.65]))
    assert [bool(b) for b in (out[0] > NEG_INF / 2)] == \
        [True, True, False, False, False]
    # disabled filters keep everything
    out = topk_topp_mask_ref(logits, jnp.asarray([0]), jnp.asarray([1.0]))
    assert bool(jnp.all(out[0] > NEG_INF / 2))
    # the argmax always survives even the harshest settings
    out = topk_topp_mask_ref(logits, jnp.asarray([1]), jnp.asarray([1e-3]))
    assert [bool(b) for b in (out[0] > NEG_INF / 2)] == \
        [True, False, False, False, False]


def test_sampling_mask_kernel_tie_values(rng):
    """Value ties at the top-k boundary are all kept (both impls)."""
    logits = jnp.asarray([[1.0, 2.0, 2.0, 0.0, -1.0] + [-9.0] * 123],
                         jnp.float32)
    for impl in ("xla", "interpret"):
        out = topk_topp_mask(logits, jnp.asarray([2]), jnp.asarray([1.0]),
                             impl=impl)
        keep = out[0] > NEG_INF / 2
        assert [bool(b) for b in keep[:5]] == [False, True, True, False,
                                               False], impl
