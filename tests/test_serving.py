"""Continuous-batching serving layer: paged KV (BlockManager), slot pool,
scheduler, chunked prefill, metrics, and the closed serving -> metrics ->
autoscaler loop. Everything runs on a ManualClock — arrival replay, latency
percentiles, and scaling decisions are fully deterministic.

Correctness bar: greedy output token-for-token equal to a one-shot uniform
batch. Engines that prefill in one full-sequence call (kv="slot", and paged
with prefill_chunk=0) are held to the batched-prefill serve_batch baseline;
chunked prefill is held to the streamed-prefill one-shot baseline (same
math, same fp association — a full prefill reduces attention in GEMM order,
which can flip near-tie argmaxes; see docs/serving.md)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import LatencyPolicy, QueueDepthPolicy, VirtualCluster
from repro.core.clock import ManualClock
from repro.launch.serve import serve_batch
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve import (SERVE_PLAN, BlockManager, Request, RequestQueue,
                         ServingEngine, SlotPool, burst_trace, percentile,
                         poisson_trace, run_to_completion)

CFG = get_smoke("paper-demo")
ENV0 = Env(mesh=None, plan=SERVE_PLAN)
PARAMS = Mo.init_params(jax.random.PRNGKey(0), CFG, ENV0)
P = 16  # prompt length used throughout


def _engine(num_slots=2, max_gen=8, clock=None, **kw):
    return ServingEngine(CFG, PARAMS, num_slots=num_slots, prompt_len=P,
                         max_gen=max_gen, clock=clock or ManualClock(), **kw)


def _trace(n, gen_len=4, arrival_t=0.0, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, (P,),
                                        dtype=np.int32),
                    gen_len=gen_len, arrival_t=arrival_t) for i in range(n)]


def _baseline(trace, gen, streamed=False):
    prompts = jnp.asarray(np.stack([r.prompt for r in trace]))
    return np.asarray(serve_batch(None, CFG, PARAMS, prompts, gen,
                                  SERVE_PLAN, streamed_prefill=streamed))


# ---------------------------------------------------------------------------
# queue + traces
# ---------------------------------------------------------------------------


def test_queue_gates_on_arrival_time():
    q = RequestQueue(_trace(2, arrival_t=1.0))
    assert q.pop_ready(0.5) is None and q.depth(0.5) == 0
    assert len(q) == 2
    r = q.pop_ready(1.0)
    assert r is not None and q.depth(1.0) == 1


def test_queue_peek_does_not_pop():
    q = RequestQueue(_trace(2))
    r = q.peek_ready(0.0)
    assert r is not None and r.rid == 0 and len(q) == 2
    assert q.pop_ready(0.0).rid == 0


def test_poisson_trace_is_deterministic_and_sorted():
    a = poisson_trace(10, 5.0, prompt_len=P, vocab_size=CFG.vocab_size,
                      gen_len=4, gen_len_max=8, seed=3)
    b = poisson_trace(10, 5.0, prompt_len=P, vocab_size=CFG.vocab_size,
                      gen_len=4, gen_len_max=8, seed=3)
    assert [r.arrival_t for r in a] == [r.arrival_t for r in b]
    assert all(x.arrival_t <= y.arrival_t for x, y in zip(a, a[1:]))
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    assert all(4 <= r.gen_len <= 8 for r in a)


def test_snapshot_omits_latency_keys_until_data_exists():
    """No completions in the window -> no latency keys published. A 0ms
    placeholder would read as excellent latency and make LatencyPolicy
    scale down mid-flight; its no-data branch keys off the absence."""
    clock = ManualClock()
    eng = _engine(num_slots=1, clock=clock)
    eng.submit(_trace(1, gen_len=4))
    snap = eng.step()  # admitted, first token emitted, nothing completed
    assert "latency_p95_ms" not in snap and "latency_p50_ms" not in snap
    assert "ttft_p95_ms" in snap  # first token did land
    assert snap["tokens_per_s"] > 0
    pol = LatencyPolicy(target_p95_ms=100.0, min_nodes=1, max_nodes=4)

    class V:
        compute = (1, 2, 3)

    m = dict(snap)
    assert pol.decide(V, m).target == 3, "no latency data -> hold, not shrink"


# ---------------------------------------------------------------------------
# BlockManager: allocation, free list, reservations
# ---------------------------------------------------------------------------


def test_block_manager_reserves_and_allocates_on_demand():
    bm = BlockManager(CFG, ENV0, num_slots=2, prompt_len=P, max_gen=8,
                      block_size=8)
    need = bm.blocks_for(8)  # kv span = 16+8-1 = 23 -> 3 blocks of 8
    assert need == 3
    assert bm.can_admit(8)
    slot = bm.admit(7, 8)
    assert bm.blocks_in_use == 0, "admit reserves, ensure allocates"
    assert bm.free_unreserved == bm.usable_blocks - need
    bm.ensure(slot, P - 1)  # prompt blocks
    assert bm.blocks_in_use == 2
    assert 0 not in bm.table[slot, :2], "null block must never be allocated"
    bm.ensure(slot, P)  # first decode token crosses into block 3
    assert bm.blocks_in_use == 3
    info = bm.info(slot)
    assert info.reserved == 0
    bm.evict(slot)
    assert bm.blocks_in_use == 0 and bm.free_unreserved == bm.usable_blocks
    assert np.all(bm.table[slot] == 0)


def test_block_manager_exhaustion_gates_admission():
    # pool sized for exactly one request (+null)
    bm = BlockManager(CFG, ENV0, num_slots=4, prompt_len=P, max_gen=8,
                      block_size=8, num_blocks=1 + 3)
    s0 = bm.admit(0, 8)
    assert bm.free_slot_count == 3
    assert not bm.can_admit(8), "blocks exhausted though slots are free"
    bm.evict(s0)
    assert bm.can_admit(8)


def test_block_manager_recycles_blocks_across_requests():
    bm = BlockManager(CFG, ENV0, num_slots=1, prompt_len=P, max_gen=8,
                      block_size=8, num_blocks=4)
    s = bm.admit(0, 8)
    bm.ensure(s, P + 6)
    first = set(bm.table[s][bm.table[s] > 0])
    bm.evict(s)
    s2 = bm.admit(1, 8)
    bm.ensure(s2, P + 6)
    second = set(bm.table[s2][bm.table[s2] > 0])
    assert first == second, "freed blocks must be reused (O(1) free list)"


def test_slot_pool_acquire_is_free_list_backed():
    pool = SlotPool(CFG, ENV0, num_slots=3, prompt_len=P, max_gen=4)
    a, b = pool.acquire_slot(), pool.acquire_slot()
    assert {a, b} == {0, 1} and pool.free_slot_count == 1
    lg, caches = jax.jit(lambda p, t: Mo.forward(
        p, t, CFG, ENV0, mode="prefill")[:2])(
            PARAMS, jnp.zeros((1, P), jnp.int32))
    pool.insert(a, 0, caches, 2)
    pool.evict(a)
    assert pool.free_slot_count == 2
    assert pool.acquire_slot() == 2, "FIFO free list"


# ---------------------------------------------------------------------------
# slot admission / eviction lifecycle (paged default: chunked prefill lanes
# admit one request per step; classic paths admit every free slot at once)
# ---------------------------------------------------------------------------


def test_admission_and_eviction_lifecycle():
    clock = ManualClock()
    eng = _engine(num_slots=2, clock=clock)
    eng.submit(_trace(3, gen_len=3))
    eng.step()  # request 0 rides the prefill lanes
    eng.step()  # request 0 decodes, request 1 prefills
    assert eng.pool.free_slot_count == 0
    assert eng.pool.occupancy == 1.0
    assert eng.queue.depth(clock.now()) == 1
    rids = {eng.pool.rid_of(s) for s in eng.pool.occupied_slots()}
    assert rids == {0, 1}
    # drive to completion: finished slots free up and request 2 is admitted
    for _ in range(16):
        clock.advance(0.05)
        eng.step()
        if eng.drained():
            break
    assert eng.drained()
    assert sorted(eng.results()) == [0, 1, 2]
    assert sorted(eng.pool.free_slots()) == [0, 1]
    assert eng.pool.blocks_in_use == 0
    # every request produced exactly gen_len tokens
    assert all(len(t) == 3 for t in eng.results().values())


def test_admitting_mid_decode_does_not_disturb_running_requests():
    """The continuous-batching property: a request joining the batch (its
    prompt chunks riding the lane rows) leaves already-running slots'
    tokens unchanged (same as a solo run)."""
    tr = _trace(2, gen_len=6, seed=7)
    tr[1].arrival_t = 0.12  # joins while request 0 is mid-decode
    solo = _engine(num_slots=1, clock=ManualClock())
    out_solo = run_to_completion(solo, [_trace(2, gen_len=6, seed=7)[0]],
                                 dt=0.05)
    eng = _engine(num_slots=2, clock=ManualClock())
    out = run_to_completion(eng, tr, dt=0.05)
    assert out[0] == out_solo[0]


def test_evicted_slot_is_zeroed_when_requested():
    eng = _engine(num_slots=2)
    eng.submit(_trace(1, gen_len=2))
    run_to_completion(eng, dt=0.05)
    # re-point: evict with zeroing and check the KV blocks actually zero
    lg, caches = eng._prefill(PARAMS, {"tokens": jnp.asarray(
        _trace(1)[0].prompt)[None]})
    eng.pool.insert(0, 99, caches, 4)
    slot = eng.pool.read_slot(0)
    assert any(float(jnp.abs(l).sum()) > 0 for l in jax.tree.leaves(slot))
    eng.pool.evict(0, zero=True)
    slot = eng.pool.read_slot(0)
    assert all(float(jnp.abs(l).sum()) == 0 for l in jax.tree.leaves(slot))
    assert eng.pool.rid_of(0) == -1


def test_gen_len_one_request_completes_at_admission():
    eng = _engine(num_slots=1)
    out = run_to_completion(eng, _trace(1, gen_len=1), dt=0.05)
    assert len(out[0]) == 1
    assert eng.pool.free_slots() == [0]


def test_slot_pool_still_rejects_sliding_window_archs():
    """The slot pool cannot grow a prompt-sized ring cache to the pool ring
    without breaking slot=pos%w alignment; it must refuse up front. The
    paged engine allocates window-sized block tables instead — see
    test_paged_serves_sliding_window_arch."""
    cfg = get_smoke("recurrentgemma-9b")
    with pytest.raises(ValueError, match="local"):
        ServingEngine(cfg, {}, num_slots=1, prompt_len=8, max_gen=4,
                      kv="slot")


def test_engine_rejects_mis_sized_requests():
    eng = _engine(num_slots=1, max_gen=4)
    bad_prompt = Request(rid=0, prompt=np.zeros((P + 1,), np.int32), gen_len=2)
    with pytest.raises(ValueError):
        eng.submit([bad_prompt])
    bad_gen = Request(rid=1, prompt=np.zeros((P,), np.int32), gen_len=9)
    with pytest.raises(ValueError):
        eng.submit([bad_gen])


# ---------------------------------------------------------------------------
# correctness: continuous batching == one-shot (every KV layout)
# ---------------------------------------------------------------------------


def test_paged_chunked_tokens_match_streamed_one_shot():
    """The default engine (paged KV + chunked prefill) under staggered
    admissions and mixed depths must emit token-for-token what the
    streamed-prefill one-shot uniform batch emits."""
    gen = 8
    trace = poisson_trace(6, 12.0, prompt_len=P, vocab_size=CFG.vocab_size,
                          gen_len=gen, seed=11)
    eng = _engine(num_slots=2, max_gen=gen)
    assert eng.kv == "paged" and eng.prefill_chunk == P
    out = run_to_completion(eng, trace, dt=0.05)
    base = _baseline(trace, gen, streamed=True)
    for r in trace:
        assert np.array_equal(base[r.rid], np.array(out[r.rid])), r.rid


def test_paged_classic_tokens_match_one_shot():
    """Paged KV with classic (batch-1 prefill + block insert) admission is
    bitwise the same computation as the slot pool: it must match the
    batched-prefill baseline exactly."""
    gen = 8
    trace = poisson_trace(6, 12.0, prompt_len=P, vocab_size=CFG.vocab_size,
                          gen_len=gen, seed=11)
    eng = _engine(num_slots=2, max_gen=gen, prefill_chunk=0)
    out = run_to_completion(eng, trace, dt=0.05)
    base = _baseline(trace, gen)
    for r in trace:
        assert np.array_equal(base[r.rid], np.array(out[r.rid])), r.rid


def test_paged_matches_slot_pool_token_for_token():
    """The paged block-table data plane must reproduce the PR-1 slot pool's
    output exactly on the same trace (mid-serve admissions + evictions)."""
    gen = 8
    mk = lambda: poisson_trace(5, 10.0, prompt_len=P,
                               vocab_size=CFG.vocab_size, gen_len=2,
                               gen_len_max=gen, seed=5)
    out_slot = run_to_completion(_engine(num_slots=2, max_gen=gen, kv="slot"),
                                 mk(), dt=0.05)
    out_paged = run_to_completion(
        _engine(num_slots=2, max_gen=gen, prefill_chunk=0), mk(), dt=0.05)
    assert out_slot == out_paged


def test_mixed_gen_lengths_match_one_shot_prefix():
    gen_max = 8
    trace = poisson_trace(5, 10.0, prompt_len=P, vocab_size=CFG.vocab_size,
                          gen_len=2, gen_len_max=gen_max, seed=5)
    eng = _engine(num_slots=3, max_gen=gen_max)
    out = run_to_completion(eng, trace, dt=0.05)
    base = _baseline(trace, gen_max, streamed=True)
    for r in trace:
        assert np.array_equal(base[r.rid][:r.gen_len], np.array(out[r.rid]))


def test_chunk_size_does_not_change_tokens():
    """Prompt chunk boundaries (including ones that straddle KV blocks) are
    a scheduling detail — every chunk size must emit identical tokens."""
    gen = 6
    trace = lambda: poisson_trace(3, 10.0, prompt_len=P,
                                  vocab_size=CFG.vocab_size, gen_len=gen,
                                  seed=2)
    outs = [run_to_completion(
        _engine(num_slots=2, max_gen=gen, prefill_chunk=c, block_size=8),
        trace(), dt=0.05) for c in (P, 8, 5)]
    assert outs[0] == outs[1] == outs[2]


def test_paged_serves_sliding_window_arch():
    """recurrentgemma-style archs (rglru state + 'local' window blocks):
    the BlockManager allocates window-sized ring tables at admission, so
    they serve token-exact — both ring regimes (prompt >= window and
    prompt < window)."""
    cfg = get_smoke("recurrentgemma-9b")  # local_window = 16
    params = Mo.init_params(jax.random.PRNGKey(1), cfg, ENV0)
    for prompt_len, gen in ((24, 6), (8, 10)):
        rng = np.random.default_rng(4)
        trace = [Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, (prompt_len,), dtype=np.int32), gen_len=gen)
            for i in range(3)]
        eng = ServingEngine(cfg, params, num_slots=2, prompt_len=prompt_len,
                            max_gen=gen, block_size=8, clock=ManualClock())
        assert eng.prefill_chunk == 0, "recurrent state => classic admission"
        assert not eng.pool.has_global and eng.pool.has_local
        out = run_to_completion(eng, trace, dt=0.05)
        prompts = jnp.asarray(np.stack([r.prompt for r in trace]))
        base = np.asarray(serve_batch(None, cfg, params, prompts, gen,
                                      SERVE_PLAN))
        for r in trace:
            assert np.array_equal(base[r.rid], np.array(out[r.rid])), \
                (prompt_len, r.rid)


def test_block_exhaustion_applies_queue_backpressure():
    """A pool with blocks for only ~2 requests but 4 slots must defer
    admissions (queue backpressure) instead of overcommitting — and still
    drain with token-exact output once blocks recycle."""
    gen = 8
    need = 3  # blocks_for(8) at block_size=8: ceil(23/8)
    eng = _engine(num_slots=4, max_gen=gen, block_size=8,
                  kv_blocks=1 + 2 * need)
    trace = burst_trace(4, prompt_len=P, vocab_size=CFG.vocab_size,
                        gen_len=gen, seed=9)
    starved, peak = [], []

    def on_step(i, snap):
        starved.append(eng.pool.free_slot_count > 0
                       and eng.queue.depth(eng.clock.now()) > 0
                       and not eng.pool.can_admit(gen))
        peak.append(len(eng.pool.occupied_slots()))

    out = run_to_completion(eng, trace, dt=0.05, on_step=on_step)
    assert any(starved), "block exhaustion never gated admission"
    assert max(peak) <= 2, "reservation must cap concurrency at the pool"
    base = _baseline(trace, gen, streamed=True)
    for r in trace:
        assert np.array_equal(base[r.rid], np.array(out[r.rid])), r.rid
    assert eng.pool.blocks_in_use == 0


def test_chunked_prefill_rejected_for_recurrent_archs():
    cfg = get_smoke("recurrentgemma-9b")
    with pytest.raises(ValueError, match="chunked prefill"):
        ServingEngine(cfg, {}, num_slots=1, prompt_len=8, max_gen=4,
                      prefill_chunk=4)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_metrics_snapshot_and_deadlines():
    clock = ManualClock()
    eng = _engine(num_slots=1, clock=clock)
    tr = _trace(2, gen_len=3)
    tr[1].deadline_s = 0.01  # will queue behind request 0 -> miss
    run_to_completion(eng, tr, dt=0.1)
    snap = eng.snapshot()
    assert snap["queue_depth"] == 0.0
    assert snap["deadline_misses"] == 1.0
    assert snap["latency_p95_ms"] >= snap["latency_p50_ms"] > 0
    assert eng.metrics.total_tokens == 6
    lat = [r.latency_s for r in eng.completed]
    assert all(l is not None and l > 0 for l in lat)


def test_paged_engine_publishes_block_occupancy():
    clock = ManualClock()
    eng = _engine(num_slots=2, clock=clock, block_size=8)
    eng.submit(_trace(1, gen_len=4))
    snap = eng.step()
    assert 0.0 < snap["kv_block_occupancy"] <= 1.0
    run_to_completion(eng, dt=0.05)
    assert eng.snapshot()["kv_block_occupancy"] == 0.0
    # slot engines don't fake the signal
    slot_eng = _engine(num_slots=1, kv="slot")
    assert "kv_block_occupancy" not in slot_eng.snapshot()


# ---------------------------------------------------------------------------
# the closed loop: serve -> metrics -> policy -> cluster size
# ---------------------------------------------------------------------------


def _serve_cluster(policy, n=1, cooldown=0.3):
    c = VirtualCluster(n_compute=n, policy=policy, cooldown_s=cooldown)
    eng = ServingEngine(CFG, PARAMS, num_slots=2, prompt_len=P, max_gen=8,
                        clock=c.clock)
    return c, eng


def test_queue_depth_policy_scales_up_and_back_down_mid_serve():
    pol = QueueDepthPolicy(target_per_node=2, min_nodes=1, max_nodes=4)
    c, eng = _serve_cluster(pol)
    trace = burst_trace(10, prompt_len=P, vocab_size=CFG.vocab_size,
                        gen_len=8, seed=2)
    sizes = []

    def on_step(i, snap, cl):
        sizes.append(len(cl.current_view().compute))

    out = c.serve(eng, trace, dt=lambda nn: 0.05 / max(nn, 1),
                  on_step=on_step)
    assert sorted(out) == list(range(10))
    assert max(sizes) > 1, "burst backlog must trigger scale-up"
    assert sizes[-1] == 1, "drained queue must scale back to min_nodes"
    # the policy was never replaced mid-serve
    assert c.scaler.policy is pol
    c.shutdown()


def test_latency_policy_scales_on_p95():
    pol = LatencyPolicy(target_p95_ms=150.0, min_nodes=1, max_nodes=4)
    c, eng = _serve_cluster(pol)
    trace = burst_trace(8, prompt_len=P, vocab_size=CFG.vocab_size,
                        gen_len=8, seed=4)
    sizes = []
    c.serve(eng, trace, dt=lambda nn: 0.05 / max(nn, 1),
            on_step=lambda i, s, cl: sizes.append(
                len(cl.current_view().compute)))
    assert max(sizes) > 1, "p95 over target must trigger scale-up"
    c.shutdown()


def test_latency_policy_decisions():
    pol = LatencyPolicy(target_p95_ms=100.0, min_nodes=1, max_nodes=4)

    class V:
        compute = (1, 2)

    assert pol.decide(V, {}).target == 1  # no data, nothing in flight: idle
    # no latency data but work queued or slots busy -> hold, don't shrink
    assert pol.decide(V, {"queue_depth": 3.0}).target == 2
    assert pol.decide(V, {"slot_occupancy": 0.5}).target == 2
    # paged engines report committed blocks — also a hold signal
    assert pol.decide(V, {"kv_block_occupancy": 0.5}).target == 2
    assert pol.decide(V, {"latency_p95_ms": 500.0}).target == 3
    assert pol.decide(V, {"latency_p95_ms": 10.0,
                          "queue_depth": 0.0}).target == 1
    # low latency but a backlog: keep capacity
    assert pol.decide(V, {"latency_p95_ms": 10.0,
                          "queue_depth": 5.0}).target == 2


def test_serving_metrics_flow_into_scaler_aggregation():
    c = VirtualCluster(n_compute=1)
    agent = c.sim.nodes[c.head_id].agent
    agent.report_serving({"latency_p95_ms": 120.0, "tokens_per_s": 50.0,
                          "queue_depth": 3.0, "slot_occupancy": 0.5,
                          "kv_block_occupancy": 0.8})
    c.sim.nodes[c.compute_nodes()[0]].agent.report_serving(
        {"latency_p95_ms": 80.0, "tokens_per_s": 30.0, "queue_depth": 1.0,
         "slot_occupancy": 1.0, "kv_block_occupancy": 0.4})
    m = c.scaler.read_metrics(c.registry)
    assert m["latency_p95_ms"] == 120.0  # worst node
    assert m["tokens_per_s"] == 80.0  # summed
    assert m["queue_depth"] == 4.0  # summed
    assert m["slot_occupancy"] == pytest.approx(0.75)  # averaged
    assert m["kv_block_occupancy"] == pytest.approx(0.6)  # averaged
    c.shutdown()


def test_stale_serving_metrics_are_tombstoned():
    """A metric the snapshot stops reporting (its window lapsed) must stop
    reaching the policy — otherwise a burst-era p95 pins the cluster at
    max_nodes long after the burst drained."""
    c = VirtualCluster(n_compute=1)
    agent = c.sim.nodes[c.head_id].agent
    agent.report_serving({"latency_p95_ms": 900.0, "queue_depth": 5.0})
    assert c.scaler.read_metrics(c.registry)["latency_p95_ms"] == 900.0
    # next snapshot omits latency (no completions in window)
    agent.report_serving({"queue_depth": 0.0})
    m = c.scaler.read_metrics(c.registry)
    assert "latency_p95_ms" not in m
    assert m["queue_depth"] == 0.0
    c.shutdown()
