"""Speculative decoding on the fused step (ISSUE-6 tentpole).

Drafter level: NgramDrafter prompt-lookup proposals (full-continuation
preference, novel-suffix skip), make_drafter dispatch, arch/config gates.

Engine level: speculative serving must be BIT-IDENTICAL to non-speculative
— greedy and seeded, slot and paged backends, across arbitrary
accept/reject boundaries (an oracle drafter forces them) and mixed-depth
busy batches. The fused verify step re-derives each position's token from
its own fold_in(seed, position) key, so acceptance-by-token-match IS the
rejection-sampling residual; these tests pin that equivalence end to end.

KV level: BlockManager.truncate rolls rejected draft positions back —
free-list/refcount integrity, reservation re-credit, and the shared-prefix
guard (truncate never reaches COW/prefix-cache blocks).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.clock import ManualClock
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve import (SERVE_PLAN, BlockManager, Drafter, ModelDrafter,
                         NgramDrafter, Request, SamplingParams,
                         ServingEngine, make_drafter, poisson_trace,
                         repetitive_trace, run_to_completion)
from repro.serve.slots import SlotPool

CFG = get_smoke("paper-demo")
ENV0 = Env(mesh=None, plan=SERVE_PLAN)
PARAMS = Mo.init_params(jax.random.PRNGKey(0), CFG, ENV0)
P = 16
BS = 4

SAMPLED = SamplingParams(temperature=0.9, top_k=40, top_p=0.95, seed=7)


def _engine(spec=None, spec_k=4, num_slots=3, max_gen=8, kv="paged", **kw):
    return ServingEngine(CFG, PARAMS, num_slots=num_slots, prompt_len=P,
                         max_gen=max_gen, kv=kv, block_size=BS,
                         spec=spec, spec_k=spec_k, clock=ManualClock(),
                         **kw)


def _rep_trace(n=8, gen_len=6, sampling=None, seed=0):
    """Tiled-motif prompts — the trace family ngram drafting feeds on."""
    return repetitive_trace(n, 48.0, prompt_len=P,
                            vocab_size=CFG.vocab_size, gen_len=gen_len,
                            sampling=sampling, seed=seed)


def _mix_trace(n=8, gen_len=6, sampling=None, seed=0):
    """Random prompts, staggered arrivals — mixed-depth busy batches."""
    return poisson_trace(n, 48.0, prompt_len=P, vocab_size=CFG.vocab_size,
                         gen_len=gen_len, sampling=sampling, seed=seed)


def _req(hist_prompt, tokens=(), k_gen=8):
    r = Request(rid=0, prompt=np.asarray(hist_prompt, np.int32),
                gen_len=k_gen, arrival_t=0.0)
    r.tokens = list(tokens)
    return r


# ---------------------------------------------------------------------------
# NgramDrafter: prompt-lookup proposals
# ---------------------------------------------------------------------------


def test_ngram_proposes_continuation_of_most_recent_match():
    d = NgramDrafter(max_n=3)
    # trailing [1,2,3] matched at position 0; continuation is [4,1,2]
    assert d.propose(_req([1, 2, 3, 4, 1, 2, 3]), 3) == [4, 1, 2]


def test_ngram_prefers_match_that_supplies_all_k_tokens():
    d = NgramDrafter(max_n=3)
    # constant run: the MOST RECENT trailing-3-gram match is the run's own
    # tail (continuation truncated to 1 token) — the drafter must keep
    # scanning for an occurrence that yields a full k-token continuation
    assert d.propose(_req([7] * 12), 4) == [7, 7, 7, 7]


def test_ngram_falls_back_to_longest_partial_continuation():
    d = NgramDrafter(max_n=3)
    # only match of [7,7,7] with any continuation sits 1 from the end
    out = d.propose(_req([7, 7, 7, 7]), 4)
    assert out == [7]


def test_ngram_skips_novel_suffix():
    d = NgramDrafter(max_n=3)
    assert d.propose(_req([1, 2, 3, 4, 5, 6, 7, 8]), 4) == []


def test_ngram_reads_generated_tokens_not_just_prompt():
    d = NgramDrafter(max_n=3)
    # the repeating motif only exists once generated tokens are appended
    assert d.propose(_req([9, 1, 2, 3], tokens=[5, 1, 2, 3]), 2) == [5, 1]


def test_make_drafter_dispatch():
    kw = dict(num_slots=2, prompt_len=P, max_gen=8, spec_k=4)
    assert make_drafter(None, CFG, ENV0, **kw) is None
    assert make_drafter("off", CFG, ENV0, **kw) is None
    d = make_drafter("ngram", CFG, ENV0, **kw)
    assert isinstance(d, NgramDrafter) and isinstance(d, Drafter)
    assert isinstance(make_drafter("model", CFG, ENV0, **kw), ModelDrafter)
    with pytest.raises(ValueError):
        make_drafter("medusa", CFG, ENV0, **kw)


def test_spec_k_must_be_positive():
    with pytest.raises(ValueError, match="spec_k"):
        _engine(spec="ngram", spec_k=0)


def test_spec_gated_off_non_attention_archs():
    # the verify rows need per-row independent attention math; recurrent
    # state is sequential — construction must refuse, not silently corrupt
    cfg = get_smoke("rwkv6-1.6b")
    params = Mo.init_params(jax.random.PRNGKey(0), cfg, ENV0)
    with pytest.raises(ValueError, match="speculat"):
        ServingEngine(cfg, params, num_slots=2, prompt_len=P, max_gen=8,
                      spec="ngram", clock=ManualClock())


# ---------------------------------------------------------------------------
# bit-exactness: spec == non-spec, token for token
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv", ["slot", "paged"])
def test_ngram_spec_bit_identical_greedy(kv):
    base = run_to_completion(_engine(kv=kv), _rep_trace(), dt=0.05)
    spec = run_to_completion(_engine(kv=kv, spec="ngram"), _rep_trace(),
                             dt=0.05)
    assert spec == base


@pytest.mark.parametrize("kv", ["slot", "paged"])
def test_ngram_spec_bit_identical_seeded(kv):
    base = run_to_completion(_engine(kv=kv),
                             _rep_trace(sampling=SAMPLED), dt=0.05)
    spec = run_to_completion(_engine(kv=kv, spec="ngram"),
                             _rep_trace(sampling=SAMPLED), dt=0.05)
    assert spec == base


class _OracleDrafter(Drafter):
    """Forces arbitrary accept/reject boundaries: knows the expected
    output (a prior non-spec run) and proposes j correct tokens followed
    by garbage, j drawn fresh per call from a seeded RNG — so every
    boundary 0..k is exercised, including all-reject and all-accept."""

    name = "oracle"

    def __init__(self, expected, vocab):
        self.expected = expected
        self.vocab = vocab
        self.rng = np.random.default_rng(0)

    def propose(self, req, k):
        fut = self.expected[req.rid][len(req.tokens):]
        j = int(self.rng.integers(0, k + 1))
        out = list(fut[:j])
        while len(out) < k:
            nxt = fut[len(out)] if len(out) < len(fut) else 0
            out.append((nxt + 1) % self.vocab)  # guaranteed wrong
        return out


@pytest.mark.parametrize("sampling", [None, SAMPLED],
                         ids=["greedy", "seeded"])
def test_forced_boundaries_stay_bit_identical(sampling):
    base = run_to_completion(_engine(), _mix_trace(sampling=sampling),
                             dt=0.05)
    oracle = _OracleDrafter(base, CFG.vocab_size)
    eng = _engine(spec=oracle)
    out = run_to_completion(eng, _mix_trace(sampling=sampling), dt=0.05)
    assert out == base
    snap = eng.snapshot()
    # boundaries were genuinely mixed: some accepts happened, not all
    assert snap["accepted_per_step"] > 1.0
    assert 0.0 < snap["spec_acceptance_rate"] < 1.0


def test_model_drafter_bit_identical_greedy():
    base = run_to_completion(_engine(num_slots=2), _mix_trace(n=4),
                             dt=0.05)
    spec = run_to_completion(_engine(num_slots=2, spec="model", spec_k=2),
                             _mix_trace(n=4), dt=0.05)
    assert spec == base


def test_spec_composes_with_prefix_cache():
    rng = np.random.default_rng(3)
    pre = rng.integers(0, CFG.vocab_size, (12,), dtype=np.int32)

    def trace():
        out = []
        for i in range(6):
            tail = np.full((P - 12,), int(pre[i % 12]), np.int32)
            out.append(Request(rid=i, prompt=np.concatenate([pre, tail]),
                               gen_len=6, arrival_t=0.05 * i,
                               sampling=SAMPLED.derive(i)))
        return out

    base = run_to_completion(_engine(prefix_cache=True), trace(), dt=0.05)
    spec = run_to_completion(_engine(prefix_cache=True, spec="ngram"),
                             trace(), dt=0.05)
    assert spec == base


def test_spec_metrics_only_when_speculating():
    eng = _engine(spec="ngram")
    out = run_to_completion(eng, _rep_trace(), dt=0.05)
    snap = eng.snapshot()
    assert snap["accepted_per_step"] >= 1.0  # floor: never below 1 token
    assert snap["spec_acceptance_rate"] > 0.0
    assert sum(len(t) for t in out.values()) > eng.decode_steps

    plain = _engine()
    run_to_completion(plain, _rep_trace(), dt=0.05)
    snap = plain.snapshot()
    assert "accepted_per_step" not in snap
    assert "spec_acceptance_rate" not in snap


# ---------------------------------------------------------------------------
# KVBackend.truncate: rejected-draft rollback
# ---------------------------------------------------------------------------


def _bm(num_slots=3, max_gen=8, **kw):
    return BlockManager(CFG, ENV0, num_slots=num_slots, prompt_len=P,
                        max_gen=max_gen, block_size=BS, **kw)


def _prompt(seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, (P,), dtype=np.int32)


def _prefill(bm, rid, prompt, gen_len=8):
    slot = bm.admit(rid, gen_len, prefilling=True, prompt=prompt)
    for pos in range(bm.cached_prefix_len(slot), P):
        bm.ensure(slot, pos)
    bm.finish_prefill(slot)
    return slot


def test_truncate_releases_blocks_and_recredits_reservation():
    bm = _bm()
    slot = _prefill(bm, 0, _prompt())
    for pos in range(P, P + 6):  # grow into gen blocks 4 and 5
        bm.ensure(slot, pos)
    s = bm.info(slot)
    assert s.alloc_g == 6
    used, res = bm.blocks_in_use, s.reserved
    bm.truncate(slot, P + 1)  # keep ceil(17/4)=5 blocks
    assert bm.info(slot).alloc_g == 5
    assert bm.blocks_in_use == used - 1
    assert bm.info(slot).reserved == res + 1  # rejection costs nothing


def test_truncate_within_boundary_block_is_free():
    bm = _bm()
    slot = _prefill(bm, 0, _prompt())
    bm.ensure(slot, P)  # one gen block, positions 16..19
    used = bm.blocks_in_use
    bm.truncate(slot, P + 1)  # junk at 17..19 stays inside the kept block
    assert bm.blocks_in_use == used
    assert bm.info(slot).alloc_g == 5


def test_truncate_then_regrow_round_trips():
    bm = _bm()
    slot = _prefill(bm, 0, _prompt())
    for _ in range(3):  # speculate, reject, re-speculate
        for pos in range(P, P + 6):
            bm.ensure(slot, pos)
        bm.truncate(slot, P)
    assert bm.info(slot).alloc_g == 4
    bm.evict(slot)  # leak check: every block back / retained, no double free
    assert bm.blocks_in_use == 0


def test_truncate_never_reaches_shared_prefix_blocks():
    bm = _bm()
    p = _prompt()
    _prefill(bm, 0, p)  # registers the prompt's blocks in the prefix cache
    slot2 = bm.admit(1, 8, prefilling=True, prompt=p)
    # shared admission: all but the last position served from the cache
    # (the final prompt token is recomputed to emit the first output)
    assert bm.cached_prefix_len(slot2) == P - 1
    for pos in range(bm.cached_prefix_len(slot2), P):
        bm.ensure(slot2, pos)
    bm.finish_prefill(slot2)
    shared = bm.info(slot2).shared_g
    assert shared >= 1  # prefix blocks really are attached by refcount
    bm.truncate(slot2, shared * BS)  # keep == shared_g: legal no-op
    with pytest.raises(AssertionError, match="shared prefix"):
        bm.truncate(slot2, (shared - 1) * BS)  # would free a prefix block


def test_slot_pool_truncate_is_a_noop():
    pool = SlotPool(CFG, ENV0, num_slots=2, prompt_len=P, max_gen=8)
    pool.truncate(0, P + 3)  # contiguous cache: depth masking handles it
