"""End-to-end behaviour of the paper's system (Yu & Huang 2015):

  §III-A image encapsulation -> §III-C discovery/hostfile -> §IV 16-rank
  SPMD job -> auto-scaling -> (future-work items) failure + stragglers.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ParallelPlan, ShapeConfig
from repro.configs.paper_demo import CLUSTER
from repro.core import ClusterImage, VirtualCluster
from repro.core.elastic import ElasticTrainer


def test_paper_figure_sequence(tmp_path):
    """The paper's demo, end to end: 1 head + 2 compute (Fig. 4/6),
    auto-registration (Fig. 7), hostfile, 16-domain SPMD job (Fig. 8),
    then the §IV scale-out claim."""
    cfg = get_smoke("paper-demo")
    plan = ParallelPlan(fsdp=False, remat="full", attn_impl="naive")
    image = ClusterImage.build("mpi-computenode", cfg, plan, "train")
    assert "FROM repro:base" in image.dockerfile()  # Fig. 2 analogue

    c = VirtualCluster(n_compute=CLUSTER.n_compute_nodes, image=image)
    # Fig. 6/7: all containers registered, catalog healthy
    assert len(c.compute_nodes()) == 2
    assert c.verify_images()
    hf = c.hostfile
    assert hf.count("compute") >= 2 and "head000" in hf

    # Fig. 8: a 16-domain job over the rendered mesh (laplace-like stencil)
    def mpi_job(mesh):
        n = CLUSTER.mpi_ranks
        x = jnp.linspace(0, 1, n * 8).reshape(n, 8)

        @jax.jit
        def halo_step(x):
            up = jnp.roll(x, 1, axis=0)
            dn = jnp.roll(x, -1, axis=0)
            return 0.25 * (2 * x + up + dn)

        for _ in range(4):
            x = halo_step(x)
        return np.asarray(x)

    out = c.submit(mpi_job)
    assert out.shape == (16, 8) and np.isfinite(out).all()

    # §IV: power up more machines -> containers auto-join -> cluster grows
    c.scale_to(4)
    assert len(c.compute_nodes()) == 4

    # beyond-paper: the running training job survives the scale event
    shape = ShapeConfig("t", 16, 4, "train")
    t = ElasticTrainer(c.template, cfg, shape, str(tmp_path), plan=plan,
                       ckpt_every=4)
    t.run_steps(3)
    c.scale_to(2)
    t.run_steps(2)
    assert t.step == 5 and t.stats.steps_lost == 0
    c.shutdown()
