"""The paper's behaviors end-to-end: auto-registration -> rendered hostfile
-> mesh; auto-scaling; failure handling; stragglers (hypothesis properties
included; without hypothesis the churn property runs on fixed examples)."""
import jax
import jax.numpy as jnp
import pytest

try:  # optional test dep: falls back to fixed deterministic examples
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.core import (ClusterImage, QueueDepthPolicy, StragglerPolicy,
                        TargetSizePolicy, VirtualCluster)
from repro.core.membership import HPC_SERVICE
from repro.core.template import HOSTFILE_KEY
from repro.configs import get_smoke
from repro.configs.base import ParallelPlan


def test_hostfile_renders_live_set():
    c = VirtualCluster(n_compute=2)
    hf = c.hostfile
    assert "compute001" in hf and "compute002" in hf and "head000" in hf
    # published to KV like consul-template writing the file (paper Fig. 5)
    assert c.registry.kv_get(HOSTFILE_KEY).value == hf
    c.shutdown()


def test_scale_up_auto_joins_and_rerenders():
    c = VirtualCluster(n_compute=2)
    e0 = c.rendering.epoch
    c.scale_to(4)
    r = c.rendering
    assert r.epoch > e0
    assert len(r.view.compute) == 4
    assert all(f"compute00{i}" in r.hostfile for i in (1, 2, 3, 4))
    c.shutdown()


def test_crash_is_reaped_by_ttl_and_view_shrinks():
    c = VirtualCluster(n_compute=3, ttl=2.0)
    victims = c.compute_nodes()
    c.crash_node(victims[-1])
    c.pump(dt=3.0)  # TTL lapses
    assert len(c.compute_nodes()) == 2
    assert victims[-1] not in c.hostfile
    c.shutdown()


def test_partition_acts_like_failure_then_rejoin():
    c = VirtualCluster(n_compute=2, ttl=2.0)
    n = c.compute_nodes()[0]
    c.sim.partition(n)
    c.pump(dt=3.0)
    assert n not in c.compute_nodes()
    c.sim.heal(n)
    c.sim.nodes[n].agent.start()  # re-register after partition heals
    c.pump()
    assert n in c.compute_nodes()
    c.shutdown()


def test_straggler_policy_replaces_slow_node():
    c = VirtualCluster(n_compute=3, policy=StragglerPolicy(factor=2.0))
    slow = c.compute_nodes()[1]
    c.sim.make_straggler(slow, bias_s=5.0)
    c.sim.report_step_times(step=1, base_s=1.0)
    c.pump(autoscale=True)
    nodes = c.compute_nodes()
    assert slow not in nodes, "straggler drained"
    assert len(nodes) == 3, "replaced, not shrunk"
    c.shutdown()


def test_mpirun_analogue_runs_spmd_on_rendered_mesh():
    c = VirtualCluster(n_compute=2)

    def job(mesh):
        # the paper's Fig. 8: an SPMD reduction over the rendered mesh
        x = jnp.arange(16.0)
        return float(jax.jit(lambda v: v.sum())(x))

    assert c.submit(job) == 120.0
    c.shutdown()


def test_image_skew_detection():
    cfg = get_smoke("yi-9b")
    plan = ParallelPlan()
    img = ClusterImage.build("t", cfg, plan, "train")
    c = VirtualCluster(n_compute=2, image=img)
    assert c.verify_images()
    # a node advertising a different digest is version skew (paper §I)
    c.registry.register(HPC_SERVICE, "rogue", "simnet://rogue",
                        meta={"image": "sha256:deadbeef", "n_devices": "1"})
    assert not c.verify_images()
    c.shutdown()


def test_scale_to_retargets_default_policy_for_autoscale_pumps():
    """With the implicit TargetSizePolicy, an autoscale pump after
    scale_to must hold the operator's size, not revert to the
    constructor pin (the straggler-healing pattern in the examples)."""
    c = VirtualCluster(n_compute=2)
    c.scale_to(4)
    assert len(c.compute_nodes()) == 4
    c.pump(autoscale=True)
    assert len(c.compute_nodes()) == 4, "pump reverted the operator resize"
    c.shutdown()


def test_scale_to_does_not_replace_installed_policy():
    """Operator scale_to is a one-shot plan; the configured autoscaling
    policy must survive it (regression: scale_to used to pin
    TargetSizePolicy permanently, disabling autoscaling)."""
    pol = QueueDepthPolicy(target_per_node=2, min_nodes=1, max_nodes=8)
    c = VirtualCluster(n_compute=1, policy=pol)
    c.scale_to(3)
    assert len(c.compute_nodes()) == 3
    assert c.scaler.policy is pol, "scale_to must not overwrite the policy"
    # the still-installed policy keeps reconciling from metrics
    c.registry.kv_put("metrics/head000/queue_depth", "8")
    c.pump(autoscale=True)
    assert len(c.compute_nodes()) == 4, "policy resumed after scale_to"
    c.shutdown()


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(st.sampled_from(["add", "drain", "crash"]),
                    min_size=1, max_size=12))
def test_membership_invariants_under_random_churn(ops):
    """Epochs are monotonic; the rendered hostfile always equals the live
    catalog; node count never goes negative."""
    c = VirtualCluster(n_compute=2, ttl=2.0)
    last_epoch = c.rendering.epoch
    for op in ops:
        nodes = c.compute_nodes()
        if op == "add":
            c.sim.add_nodes(1)
        elif op == "drain" and len(nodes) > 1:
            c.sim.remove_nodes([nodes[-1]])
        elif op == "crash" and len(nodes) > 1:
            c.crash_node(nodes[0])
            c.pump(dt=3.0)
        r = c.pump()
        if r is not None:
            assert r.epoch >= last_epoch
            last_epoch = r.epoch
            live = {m.node_id for m in r.view.members}
            for nid in live:
                assert nid in r.hostfile
    c.shutdown()
