"""Real multi-device behaviors (subprocess with 8 forced host devices):
sharded train step parity, seq-sharded flash-decode merge, hostfile->mesh,
mini dry-run. Kept in child processes so the main pytest session stays on
one device (per the dry-run isolation rule)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_child(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"child failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = run_child("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.configs.base import ParallelPlan, ShapeConfig
        from repro.models.env import Env
        from repro.models import model as Mo
        from repro.launch import steps as St
        from repro.launch.mesh import make_test_mesh
        from repro.parallel import rules
        from repro.optim import AdamWConfig, adamw_init

        cfg = get_smoke("yi-9b")
        shape = ShapeConfig("t", 16, 8, "train")
        opt = AdamWConfig(lr=1e-3)
        rng = jax.random.PRNGKey(0)

        # single device reference
        env0 = Env(None, ParallelPlan(fsdp=False, remat="full",
                                      attn_impl="naive"))
        p0 = Mo.init_params(rng, cfg, env0)
        s0 = {"params": p0, "opt": adamw_init(p0, opt)}
        tokens = jax.random.randint(rng, (8, 16), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        _, m0 = jax.jit(St.make_train_step(cfg, env0, opt))(s0, batch)

        # 4x2 mesh, fsdp+tp sharded
        mesh = make_test_mesh(8, model=2)
        env = Env(mesh, ParallelPlan(fsdp=True, remat="nothing",
                                     attn_impl="naive"))
        p1 = Mo.init_params(rng, cfg, env)
        s1 = {"params": p1, "opt": adamw_init(p1, opt)}
        specs = rules.state_specs(jax.eval_shape(lambda: s1), cfg, env)
        s1 = rules.apply_shardings(s1, specs, env)
        bspecs = rules.batch_specs(batch, cfg, shape, env)
        batch1 = rules.apply_shardings(batch, bspecs, env)
        with mesh:
            _, m1 = jax.jit(St.make_train_step(cfg, env, opt))(s1, batch1)
        a, b = float(m0["loss"]), float(m1["loss"])
        assert abs(a - b) / abs(a) < 2e-2, (a, b)
        print("PARITY OK", a, b)
    """)
    assert "PARITY OK" in out


def test_flash_decode_seq_sharded_merge():
    out = run_child("""
        import jax, jax.numpy as jnp
        from repro.kernels.flash_decode.ops import flash_decode_seq_sharded
        from repro.kernels.flash_decode.ref import decode_ref
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(8, model=8)
        rng = jax.random.PRNGKey(0)
        ks = jax.random.split(rng, 3)
        B,Hq,Hkv,S,hd = 2, 8, 2, 512, 32
        q = jax.random.normal(ks[0], (B,Hq,hd), jnp.float32)
        k = jax.random.normal(ks[1], (B,Hkv,S,hd), jnp.float32)
        v = jax.random.normal(ks[2], (B,Hkv,S,hd), jnp.float32)
        for cur in (0, 100, 400, 511):
            with mesh:
                o = flash_decode_seq_sharded(mesh, "model", q, k, v, cur,
                                             block_k=64, interpret=True)
            r = decode_ref(q, k, v, cur)
            err = float(jnp.max(jnp.abs(o - r)))
            assert err < 1e-3, (cur, err)
        print("MERGE OK")
    """)
    assert "MERGE OK" in out


def test_hostfile_renders_real_multidevice_mesh():
    out = run_child("""
        import jax
        from repro.core import VirtualCluster
        c = VirtualCluster(n_compute=4, devices_per_node=2)
        r = c.rendering
        assert not r.oversubscribed, "members own disjoint real devices"
        assert r.mesh is not None and r.mesh.devices.size == 8
        # scale down -> smaller mesh re-rendered from the catalog
        c.scale_to(2)
        assert c.rendering.mesh.devices.size in (4, 5, 6)
        print("MESH OK", r.mesh.shape)
    """)
    assert "MESH OK" in out


def test_mini_dryrun_multipod_axes():
    """A (2,2,2) pod/data/model mesh lowers + compiles a smoke train step —
    the same code path as the 512-device production dry run."""
    out = run_child("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke
        from repro.configs.base import ParallelPlan, ShapeConfig
        from repro.models.env import Env
        from repro.launch import steps as St

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_smoke("qwen3-32b")
        shape = ShapeConfig("t", 32, 8, "train")
        env = Env(mesh, ParallelPlan(fsdp=True, remat="nothing",
                                     attn_impl="naive"))
        args, in_sh, fn = St.input_specs(cfg, shape, env)
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        ca = compiled.cost_analysis()
        print("MINI DRYRUN OK flops=", ca if isinstance(ca, dict) else ca[0])
    """)
    assert "MINI DRYRUN OK" in out


def test_mini_dryrun_decode_cell():
    out = run_child("""
        import jax
        from repro.configs import get_smoke
        from repro.configs.base import ParallelPlan, ShapeConfig
        from repro.models.env import Env
        from repro.launch import steps as St

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke("granite-3-8b")
        shape = ShapeConfig("t", 64, 8, "decode")
        env = Env(mesh, ParallelPlan(fsdp=False, remat="full",
                                     attn_impl="naive",
                                     kv_cache="seq_sharded"))
        args, in_sh, fn = St.input_specs(cfg, shape, env)
        with mesh:
            jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        print("DECODE DRYRUN OK")
    """)
    assert "DECODE DRYRUN OK" in out
