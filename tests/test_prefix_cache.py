"""Prefix caching with copy-on-write blocks (ISSUE-4 tentpole).

BlockManager level: hash-chain prefix admission attaches shared blocks
with refcounts; the first write into a shared block copies it
(copy-on-write); refcounted frees retain registered blocks in a
reclaimable LRU and extend — never weaken — the double-free guard;
fork–free–fork sequences resurrect cached blocks.

Engine level: lanes start at the first uncached token, prefill compute
drops, TTFT is recorded for fully-cached prompts, and output stays
token-exact vs --prefix-cache off for greedy and seeded sampling (two
requests sharing a prefix never observe each other's writes).
"""
import jax
import numpy as np
import pytest

from repro.core import LatencyPolicy
from repro.configs import get_smoke
from repro.core.clock import ManualClock
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve import (SERVE_PLAN, BlockManager, Request, SamplingParams,
                         ServingEngine, run_to_completion)

CFG = get_smoke("paper-demo")
ENV0 = Env(mesh=None, plan=SERVE_PLAN)
PARAMS = Mo.init_params(jax.random.PRNGKey(0), CFG, ENV0)
P = 16
BS = 4  # 4 full blocks per prompt

SAMPLED = SamplingParams(temperature=0.9, top_k=40, top_p=0.95, seed=7)


def _bm(num_slots=3, max_gen=8, **kw):
    return BlockManager(CFG, ENV0, num_slots=num_slots, prompt_len=P,
                        max_gen=max_gen, block_size=BS, **kw)


def _prompt(seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, (P,), dtype=np.int32)


def _prefill(bm, rid, prompt, gen_len=8):
    """Admit + walk the whole prompt through ensure, as the engine's lanes
    would, then finish (registers full prompt blocks)."""
    slot = bm.admit(rid, gen_len, prefilling=True, prompt=prompt)
    for pos in range(bm.cached_prefix_len(slot), P):
        bm.ensure(slot, pos)
    bm.finish_prefill(slot)
    return slot


def _engine(num_slots=2, max_gen=8, prefix_cache=True, **kw):
    return ServingEngine(CFG, PARAMS, num_slots=num_slots, prompt_len=P,
                         max_gen=max_gen, block_size=BS,
                         prefix_cache=prefix_cache, clock=ManualClock(),
                         **kw)


def _shared_trace(n=4, sampling=None, prefix_seed=0, gen_len=6):
    """n requests sharing a 12-token system prompt + random 4-token tails,
    arrivals staggered so later admissions see the registered prefix."""
    rng = np.random.default_rng(prefix_seed)
    pre = rng.integers(0, CFG.vocab_size, (12,), dtype=np.int32)
    out = []
    for i in range(n):
        sp = SamplingParams() if sampling is None else sampling.derive(i)
        tail = rng.integers(0, CFG.vocab_size, (P - 12,), dtype=np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([pre, tail]),
                           gen_len=gen_len, arrival_t=0.05 * i, sampling=sp))
    return out


# ---------------------------------------------------------------------------
# BlockManager: shared admission, refcounts, copy-on-write
# ---------------------------------------------------------------------------


def test_prefix_admit_attaches_shared_blocks_with_refcounts():
    bm = _bm()
    prompt = _prompt()
    a = _prefill(bm, 0, prompt)
    assert bm.cached_prefix_len(a) == 0, "cold cache: nothing shared"
    used_before = bm.blocks_in_use
    b = bm.admit(1, 8, prefilling=True, prompt=prompt)
    # all 4 full blocks hit; the engine's lane starts at P - 1 (the last
    # prompt token always runs to emit the first generated token)
    assert bm.cached_prefix_len(b) == P - 1
    sb = bm.info(b)
    assert sb.shared_g == 4 and sb.alloc_g == 4
    assert list(bm.table[b][:4]) == list(bm.table[a][:4])
    assert all(bm._ref[int(x)] == 2 for x in bm.table[b][:4])
    assert bm.blocks_in_use == used_before, "sharing allocates nothing"
    # reservation covers only the private future: blocks_for - shared + 1
    # (the +1 is the copy-on-write block the boundary write will take)
    assert sb.reserved == bm.blocks_for(8) - 4 + 1
    # actively-shared occupancy (ref >= 2): exactly the 4 shared blocks
    assert bm.shared_occupancy == pytest.approx(4 / bm.usable_blocks)


def test_first_divergent_write_copies_the_shared_block():
    bm = _bm()
    prompt = _prompt()
    a = _prefill(bm, 0, prompt)
    b = bm.admit(1, 8, prefilling=True, prompt=prompt)
    boundary_a = int(bm.table[a][3])
    bm.ensure(b, P - 1)  # the first (divergent) write position
    sb = bm.info(b)
    assert int(bm.table[b][3]) != boundary_a, "write must land in a copy"
    assert sb.shared_g == 3, "the boundary entry is private now"
    assert bm._ref[boundary_a] == 1 and bm._ref[int(bm.table[b][3])] == 1
    assert sb.reserved == bm.blocks_for(8) - 4, "COW spent its reservation"
    # the copy carries the original KV: reading both slots' shared span
    # must agree bit-for-bit (request b never recomputed those positions)
    ra = jax.tree.leaves(bm.read_slot(a))
    rb = jax.tree.leaves(bm.read_slot(b))
    for la, lb in zip(ra, rb):
        if la.ndim >= 2 and la.shape[-2] >= P:  # k/v leaves, seq dim -2
            np.testing.assert_array_equal(np.asarray(la[..., :P - 1, :]),
                                          np.asarray(lb[..., :P - 1, :]))
    # further growth never COWs again (writes are past the shared prefix)
    cows = bm._cow_copies
    bm.ensure(b, P + 5)
    assert bm._cow_copies == cows


def test_refcounted_frees_retain_cache_and_keep_double_free_guard():
    bm = _bm()
    prompt = _prompt()
    a = _prefill(bm, 0, prompt)
    b = bm.admit(1, 8, prefilling=True, prompt=prompt)
    bm.evict(a)  # first sharer retires: blocks stay (b still references)
    assert all(bm._ref[int(x)] == 1 for x in bm.table[b][:4])
    assert bm.blocks_in_use == 4
    with pytest.raises(RuntimeError, match="double free"):
        bm.evict(a)
    bm.evict(b)  # last reference: registered blocks become reclaimable
    assert bm.blocks_in_use == 0
    assert bm.free_unreserved == bm.usable_blocks
    with pytest.raises(RuntimeError, match="double free"):
        bm.evict(b)


def test_fork_free_fork_resurrects_cached_blocks():
    bm = _bm()
    prompt = _prompt()
    a = _prefill(bm, 0, prompt)
    first = [int(x) for x in bm.table[a][:4]]
    bm.evict(a)
    for _ in range(2):  # fork -> free -> fork again
        s = bm.admit(9, 8, prefilling=True, prompt=prompt)
        assert bm.cached_prefix_len(s) == P - 1
        assert [int(x) for x in bm.table[s][:4]] == first, \
            "the same physical blocks must come back from the reclaim list"
        bm.evict(s)
    assert bm.blocks_in_use == 0


def test_reclaim_lru_yields_cache_to_fresh_allocations():
    # pool sized for exactly one request's worst case: after the cached
    # request retires, a different prompt must be able to take every block
    bm = _bm(num_slots=2, num_blocks=1 + 6)  # blocks_for(8)=6 at bs=4
    pa = _prompt(0)
    a = _prefill(bm, 0, pa)
    bm.evict(a)
    assert len(bm._hash_of) == 4, "prompt blocks retained in the cache"
    pb = _prompt(1)
    b = bm.admit(1, 8, prefilling=True, prompt=pb)
    assert bm.cached_prefix_len(b) == 0
    for pos in range(P + 7):
        bm.ensure(b, pos)  # forces reclaim of the retained blocks
    assert len(bm._hash_of) < 4, "LRU reclaim must unregister cache entries"
    bm.evict(b)
    # the original prompt now (partially) misses — no stale index entries
    c = bm.admit(2, 8, prefilling=True, prompt=pa)
    assert bm.cached_prefix_len(c) < P - 1


def test_preempt_frees_applies_prefix_discount():
    """A candidate whose prompt is mostly cached needs far fewer fresh
    blocks than its worst case — preempt_frees must judge the eviction
    against the same prefix-discounted need can_admit uses, or hot-prefix
    candidates stall in backpressure behind viable preemptions."""
    bm = _bm(num_slots=3, num_blocks=1 + 11)
    r1 = _prefill(bm, 0, _prompt(0))
    for pos in range(P + 7):
        bm.ensure(r1, pos)  # r1 owns all 6 of its blocks (prefix registered)
    r2 = bm.admit(1, 1, prefilling=True, prompt=_prompt(1))
    for pos in range(P):
        bm.ensure(r2, pos)  # r2: 4 blocks, nothing reserved
    assert not bm.can_admit(8, prompt=_prompt(0)), \
        "1 free block < the discounted need of 3"
    assert bm.blocks_for(8) > bm.free_unreserved + 4, \
        "worst-case math would also decline the eviction"
    assert bm.preempt_frees(r2, 8, prompt=_prompt(0)), \
        "eviction covers the prefix-discounted need"
    assert not bm.preempt_frees(r2, 8), \
        "without the prompt the check stays worst-case conservative"


def test_prefix_cache_off_is_the_old_allocator():
    bm = _bm(prefix_cache=False)
    prompt = _prompt()
    a = _prefill(bm, 0, prompt)
    b = bm.admit(1, 8, prefilling=True, prompt=prompt)
    assert bm.cached_prefix_len(b) == 0 and bm.info(b).shared_g == 0
    bm.evict(a)
    assert bm.free_unreserved == bm.usable_blocks - bm.info(b).reserved
    assert not bm._hash_of and not bm._reclaim


# ---------------------------------------------------------------------------
# engine: exactness, isolation, skipped prefill, TTFT
# ---------------------------------------------------------------------------


def test_greedy_exactness_and_prefill_skip_on_shared_prompts():
    on = _engine(prefix_cache=True)
    out_on = run_to_completion(on, _shared_trace(), dt=0.05)
    off = _engine(prefix_cache=False)
    out_off = run_to_completion(off, _shared_trace(), dt=0.05)
    assert out_on == out_off, "prefix cache must be invisible in tokens"
    snap = on.snapshot()
    assert on.metrics.prefill_tokens < off.metrics.prefill_tokens
    assert snap["prefix_hit_rate"] > 0.0
    # drained: nothing is concurrently shared anymore, so the scale-hold
    # signal has decayed and the autoscaler's shrink paths are open
    assert snap["kv_shared_occupancy"] == 0.0
    assert off.snapshot()["prefix_hit_rate"] == 0.0


def test_sampled_requests_sharing_a_prefix_never_cross_contaminate():
    """Divergence under sampling: requests share prompt blocks but sample
    different continuations — writes after divergence must stay private
    (COW), so cache on == cache off bit-for-bit, seeded."""
    mk = lambda pc: run_to_completion(
        _engine(num_slots=3, prefix_cache=pc),
        _shared_trace(n=3, sampling=SAMPLED), dt=0.05)
    assert mk(True) == mk(False)


def test_fully_cached_prompt_gets_first_token_and_ttft():
    """An identical repeat prompt caches all but its last position: one
    lane step emits the first token (TTFT recorded), output matches the
    cold run, and the boundary write went through copy-on-write."""
    eng = _engine(num_slots=2)
    prompt = _prompt(3)
    reqs = [Request(rid=0, prompt=prompt.copy(), gen_len=5, arrival_t=0.0),
            Request(rid=1, prompt=prompt.copy(), gen_len=5, arrival_t=0.4)]
    out = run_to_completion(eng, reqs, dt=0.05)
    assert out[1] == out[0], "identical greedy prompts, identical tokens"
    done = {r.rid: r for r in eng.completed}
    assert done[1].t_first_token is not None
    # rid 1 probed P tokens and hit P - 1 of them
    assert eng.snapshot()["prefix_hit_rate"] == pytest.approx(
        (P - 1) / (2 * P))
    assert eng.pool._cow_copies == 1
    assert eng.pool.blocks_in_use == 0, "drained pool holds only cache"


def test_engine_snapshot_reports_prefill_tokens():
    eng = _engine(num_slots=1)
    run_to_completion(eng, _shared_trace(n=1), dt=0.05)
    assert eng.snapshot()["prefill_tokens"] == float(P)


# ---------------------------------------------------------------------------
# autoscaler: shared-block occupancy holds the shrink
# ---------------------------------------------------------------------------


def test_latency_policy_holds_shrink_while_prefix_cache_is_hot():
    pol = LatencyPolicy(target_p95_ms=1000.0, min_nodes=1, max_nodes=4,
                        hold_shared_above=0.75)

    class V:
        compute = (1, 2)

    healthy = {"latency_p95_ms": 10.0, "queue_depth": 0.0}
    plan = pol.decide(V, {**healthy, "kv_shared_occupancy": 0.9})
    assert plan.target == 2 and "prefix cache hot" in plan.reason
    assert pol.decide(V, {**healthy, "kv_shared_occupancy": 0.2}).target == 1
    assert pol.decide(V, healthy).target == 1, "no signal -> old behavior"
    # the default threshold must be reachable by the real signal, whose
    # ceiling is shared-blocks/pool-size (the smoke bench peaks ~0.13)
    dflt = LatencyPolicy(target_p95_ms=1000.0, min_nodes=1, max_nodes=4)
    assert dflt.decide(V, {**healthy,
                           "kv_shared_occupancy": 0.12}).target == 2
