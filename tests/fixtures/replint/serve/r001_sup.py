"""replint fixture: R001 suppressed — reasoned ignore on a wall-clock read."""
import time


def stamp():
    # replint: ignore[R001] -- fixture: the sanctioned wall-clock boundary for the suppression test
    return time.time()
