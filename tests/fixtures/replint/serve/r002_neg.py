"""replint fixture: R002 negative — jit routed through the shared registry."""
from repro.serve.kv import shared_jit


def build(cfg, fn):
    return shared_jit(("fixture_step", cfg), lambda: fn)
