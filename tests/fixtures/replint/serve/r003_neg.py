"""replint fixture: R003 negative — fixed-shape padded batch."""
import jax.numpy as jnp

from repro.serve.kv import shared_jit

PAD = 128

_step = shared_jit(("fixture_cumsum_neg",), lambda: jnp.cumsum)


def run(tokens):
    del tokens  # the batch is padded to PAD; shape never varies per request
    return _step(jnp.zeros(PAD))
