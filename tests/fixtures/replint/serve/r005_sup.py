"""replint fixture: R005 suppressed — reasoned ignore on an off-schema key."""


class FixMetricsSup:
    def snapshot(self):
        # replint: ignore[R005] -- fixture: experimental key, intentionally off-schema
        return {"fixture_offschema_key": 3.0}
