"""replint fixture: R001 positives — wall clock, global RNG, set iteration."""
import random
import time

import numpy as np


def stamp():
    return time.time()


def jitter():
    return random.random() + np.random.rand()


def drain(keys):
    acc = []
    pending = set(keys)
    for k in pending:
        acc.append(k)
    return acc
