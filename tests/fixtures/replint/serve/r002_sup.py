"""replint fixture: R002 suppressed — reasoned ignore on a bare jit."""
import jax


def build(fn):
    # replint: ignore[R002] -- fixture: one-off offline tool, never instantiated per replica
    return jax.jit(fn)
