"""replint fixture: R005 positive — published key missing from the schema."""


class FixMetricsPos:
    def snapshot(self):
        return {"fixture_unregistered_key": 1.0}
