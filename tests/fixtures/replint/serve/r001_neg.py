"""replint fixture: R001 negatives — injected clock, seeded RNG, sorted sets."""
import numpy as np


def stamp(clock):
    return clock.now()


def jitter(seed):
    return np.random.default_rng(seed).random()


def drain(keys):
    acc = []
    pending = set(keys)
    for k in sorted(pending):
        acc.append(k)
    return acc
