"""replint fixture: R003 positive — per-request len() into a jitted call."""
import jax.numpy as jnp

from repro.serve.kv import shared_jit

_step = shared_jit(("fixture_cumsum",), lambda: jnp.cumsum)


def run(tokens):
    return _step(jnp.zeros(len(tokens)))
