"""replint fixture: R002 positive — bare jax.jit in the data plane."""
import jax


def build(fn):
    return jax.jit(fn)
