"""replint fixture: R005 negative — published keys declared in the schema."""

METRIC_SCHEMA = frozenset({"fixture_known_key"})


class FixMetricsNeg:
    def snapshot(self):
        return {"fixture_known_key": 2.0}
