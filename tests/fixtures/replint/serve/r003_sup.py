"""replint fixture: R003 suppressed — reasoned ignore on a dynamic shape."""
import jax.numpy as jnp

from repro.serve.kv import shared_jit

_step = shared_jit(("fixture_cumsum_sup",), lambda: jnp.cumsum)


def run(tokens):
    # replint: ignore[R003] -- fixture: corpus is fixed-length, so the shape set is closed
    return _step(jnp.zeros(len(tokens)))
