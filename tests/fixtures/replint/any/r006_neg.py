"""replint fixture: R006 negative — device-side select, static closure branch."""
import jax.numpy as jnp


def make_fixture_neg_step(scale, use_bias):
    bias = 1.0 if use_bias else 0.0  # closure value: static at trace time

    def step(x):
        return jnp.where(x > 0, x * scale + bias, x)

    return step
