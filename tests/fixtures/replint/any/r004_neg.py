"""replint fixture: R004 negative — full surface, compatible signatures."""
from typing import Protocol


class FixRanker(Protocol):
    def rank(self, items, now): ...


class FullRanker(FixRanker):
    def rank(self, items, now):
        return sorted(items)
