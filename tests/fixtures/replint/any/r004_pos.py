"""replint fixture: R004 positives — missing method, renamed parameter."""
from typing import Protocol


class FixSelector(Protocol):
    def select(self, queue, now): ...

    def victim(self, slots): ...


class HalfSelector(FixSelector):
    def select(self, queue, now):
        return queue[0]


class RenamedSelector(FixSelector):
    def select(self, q, now):
        return q[-1]

    def victim(self, slots):
        return None
