"""replint fixture: R006 positives — tracer branch, .item() host sync."""


def make_fixture_step(scale):
    def step(x):
        if x > 0:
            return x * scale
        return x.item()

    return step
