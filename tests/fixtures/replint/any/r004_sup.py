"""replint fixture: R004 suppressed — reasoned ignore on a partial double."""
from typing import Protocol


class FixDrain(Protocol):
    def drain(self, slots): ...

    def flush(self, slots): ...


# replint: ignore[R004] -- fixture: partial test double, only drain() is exercised
class PartialDrain(FixDrain):
    def drain(self, slots):
        return slots
