"""replint fixture: R006 suppressed — reasoned ignore on a host sync."""


def make_fixture_sup_step(scale):
    def step(x):
        # replint: ignore[R006] -- fixture: debug-only host sync, stripped from prod step builders
        return x.item() * scale

    return step
