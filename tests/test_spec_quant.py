"""Speculative decoding composed with the int8 quantized KV backend
(--spec ngram --kv quant): the bounded-divergence contract must hold
under verify lanes and truncate rollbacks, self-consistency replaces the
fp oracle (quant+spec is bit-identical to quant without spec), and a
rejection's truncate must never corrupt the backend's int8 scale leaves.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.clock import ManualClock
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve import (SERVE_PLAN, SamplingParams, ServingEngine,
                         repetitive_trace, run_to_completion)

CFG = get_smoke("paper-demo")
ENV0 = Env(mesh=None, plan=SERVE_PLAN)
PARAMS = Mo.init_params(jax.random.PRNGKey(0), CFG, ENV0)
P = 16
SAMPLED = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=11)


def _engine(spec=None, num_slots=3, **kw):
    return ServingEngine(CFG, PARAMS, num_slots=num_slots, prompt_len=P,
                         max_gen=8, kv="quant", spec=spec, spec_k=4,
                         clock=ManualClock(), **kw)


def _rep_trace(n=8, sampling=None):
    return repetitive_trace(n, 48.0, prompt_len=P,
                            vocab_size=CFG.vocab_size, gen_len=8,
                            motif_len=4, sampling=sampling, seed=0)


@pytest.mark.parametrize("sampling", [None, SAMPLED],
                         ids=["greedy", "seeded"])
def test_spec_on_quant_bit_identical_to_quant_baseline(sampling):
    """The --verify contract composed: quant gives up the fp oracle but
    keeps self-consistency, and speculation must be invisible on top of
    it — the same trace through quant engines with and without the ngram
    drafter emits identical tokens, while drafts genuinely land."""
    base = run_to_completion(_engine(), _rep_trace(sampling=sampling),
                             dt=0.05)
    eng = _engine(spec="ngram")
    out = run_to_completion(eng, _rep_trace(sampling=sampling), dt=0.05)
    assert out == base
    snap = eng.snapshot()
    if sampling is None:  # sampled tokens rarely match ngram drafts
        assert snap["accepted_per_step"] > 1.0, \
            "drafts never landed: the composition was not exercised"
    assert snap["accepted_per_step"] >= 1.0
    assert snap["kv_quant_divergence"] < 0.05


def test_spec_on_quant_slot_placement_invariant():
    """Composed self-consistency across slot counts: different lane
    packing, different verify-row layouts, different physical blocks —
    same tokens."""
    a = run_to_completion(_engine(spec="ngram", num_slots=4),
                          _rep_trace(sampling=SAMPLED), dt=0.05)
    b = run_to_completion(_engine(spec="ngram", num_slots=2),
                          _rep_trace(sampling=SAMPLED), dt=0.05)
    assert a == b


def test_truncate_rollback_keeps_scale_leaves_intact():
    """A rejected draft truncates the slot back to its accepted length.
    On the quant backend that returns whole int8 blocks (payload + f32
    scales) to the pool — the surviving prefix's scale leaves must stay
    finite and dequantize-consistent through every rollback, or later
    decode steps read garbage KV."""
    eng = _engine(spec="ngram")
    checked = [0]

    def on_step(i, snap):
        for slot in eng.pool.active_slots():
            kv = eng.pool.read_slot(slot)  # dequantized view
            for leaf in jax.tree_util.tree_leaves(kv):
                arr = np.asarray(leaf)
                assert np.all(np.isfinite(arr)), \
                    f"non-finite KV after rollback in slot {slot}"
            checked[0] += 1

    out = run_to_completion(eng, _rep_trace(sampling=SAMPLED), dt=0.05,
                            on_step=on_step)
    assert checked[0] > 0, "never inspected a live slot"
    snap = eng.snapshot()
    # rollbacks genuinely happened (acceptance below 1.0 means rejections)
    assert snap["spec_acceptance_rate"] < 1.0
    assert all(len(t) == 8 for t in out.values())
    assert snap["kv_quant_divergence"] < 0.05
