"""Serving API v2: SamplingParams (seeded, lane-placement-invariant
sampling; stop tokens; max_tokens), SchedulerPolicy (FIFO vs EDF admission
order, restart-preemption verdicts, deadline-miss feedback into
LatencyPolicy), the KVBackend protocol surface, the RequestQueue sorted
push, and the BlockManager double-free guard.

Greedy (temperature=0) exactness vs the one-shot baselines lives in
tests/test_serving.py and is untouched by v2 — the default SamplingParams
lower to the same fused argmax.
"""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import LatencyPolicy
from repro.core.clock import ManualClock
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve import (SERVE_PLAN, BlockManager, EDFPolicy, FIFOPolicy,
                         KVBackend, Request, RequestQueue, SamplingParams,
                         ServingEngine, SlotPool, make_kv_backend,
                         make_scheduler_policy, run_to_completion)

CFG = get_smoke("paper-demo")
ENV0 = Env(mesh=None, plan=SERVE_PLAN)
PARAMS = Mo.init_params(jax.random.PRNGKey(0), CFG, ENV0)
P = 16  # prompt length used throughout

SAMPLED = SamplingParams(temperature=0.9, top_k=40, top_p=0.95, seed=7)


def _engine(num_slots=2, max_gen=8, clock=None, **kw):
    return ServingEngine(CFG, PARAMS, num_slots=num_slots, prompt_len=P,
                         max_gen=max_gen, clock=clock or ManualClock(), **kw)


def _req(rid, gen_len=6, arrival_t=0.0, seed=0, sampling=None, **kw):
    rng = np.random.default_rng(seed + 100 * rid)
    return Request(rid=rid,
                   prompt=rng.integers(0, CFG.vocab_size, (P,),
                                       dtype=np.int32),
                   gen_len=gen_len, arrival_t=arrival_t,
                   sampling=sampling or SamplingParams(), **kw)


# ---------------------------------------------------------------------------
# SamplingParams surface
# ---------------------------------------------------------------------------


def test_sampling_params_validation_and_defaults():
    sp = SamplingParams()
    assert sp.greedy and sp.stop_set == frozenset()
    assert not SamplingParams(temperature=0.5).greedy
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(max_tokens=0)
    with pytest.raises(ValueError):  # seed rides an int32 metadata row
        SamplingParams(seed=2**31)
    assert SamplingParams(seed=3).derive(5).seed == 8
    wrapped = SamplingParams(seed=2**31 - 2).derive(5)  # wraps, not crashes
    assert 0 <= wrapped.seed < 2**31


def test_max_tokens_caps_gen_len():
    eng = _engine(num_slots=1)
    r = _req(0, gen_len=6,
             sampling=SamplingParams(max_tokens=3))
    out = run_to_completion(eng, [r], dt=0.05)
    assert len(out[0]) == 3


def test_stop_token_ends_request_early():
    # learn the greedy continuation, then stop on its third token
    probe = run_to_completion(_engine(num_slots=1), [_req(0, gen_len=8)],
                              dt=0.05)
    stop = probe[0][2]
    eng = _engine(num_slots=1)
    r = _req(0, gen_len=8, sampling=SamplingParams(stop_tokens=(stop,)))
    out = run_to_completion(eng, [r], dt=0.05)
    assert out[0] == probe[0][:3], "stop token is emitted, then ends the job"
    assert eng.pool.free_slot_count == 1, "early finish must free the slot"


# ---------------------------------------------------------------------------
# seeded sampling: reproducible, seed-sensitive, lane-placement-invariant
# ---------------------------------------------------------------------------


def test_seeded_sampling_is_reproducible_and_differs_from_greedy():
    mk = lambda sp: run_to_completion(
        _engine(num_slots=2), [_req(i, gen_len=8, sampling=sp)
                               for i in range(3)], dt=0.05)
    a = mk(SAMPLED)
    b = mk(SAMPLED)
    assert a == b, "same seeds -> bit-identical output"
    g = mk(SamplingParams())
    assert a != g, "temperature 0.9 should diverge from greedy somewhere"
    c = mk(SamplingParams(temperature=0.9, top_k=40, top_p=0.95, seed=1234))
    assert a != c, "different seed -> different trajectory (w.h.p.)"


def test_greedy_rows_inside_sampling_batch_stay_exact():
    """A greedy request sharing a batch with sampling requests must emit
    exactly what it emits alone — temperature=0 lowers to argmax per row."""
    solo = run_to_completion(_engine(num_slots=1), [_req(0, gen_len=6)],
                             dt=0.05)
    mixed = run_to_completion(
        _engine(num_slots=3),
        [_req(0, gen_len=6),
         _req(1, gen_len=6, sampling=SAMPLED),
         _req(2, gen_len=6, sampling=SAMPLED.derive(1))], dt=0.05)
    assert mixed[0] == solo[0]


@pytest.mark.parametrize("kv,chunk", [("paged", None), ("paged", 0),
                                      ("slot", None)])
def test_lane_placement_invariance(kv, chunk):
    """The tentpole invariance contract: a seeded request admitted alone
    emits bit-identical tokens to the same request admitted into a busy
    mixed-depth batch (different lane, different batch composition, later
    clock) — on both KV backends, chunked or classic admission."""
    kw = {} if chunk is None else {"prefill_chunk": chunk}
    target = lambda: _req(9, gen_len=8, arrival_t=0.3, sampling=SAMPLED)
    solo = run_to_completion(_engine(num_slots=1, kv=kv, **kw), [target()],
                             dt=0.05)
    # busy engine: other requests admitted first, at staggered depths, so
    # the target lands in a different slot at a different step
    noise = [_req(i, gen_len=4 + i, arrival_t=0.05 * i,
                  sampling=SAMPLED.derive(i + 1)) for i in range(3)]
    busy = run_to_completion(_engine(num_slots=4, kv=kv, **kw),
                             [*noise, target()], dt=0.05)
    assert busy[9] == solo[9], (kv, chunk)


def test_sampled_tokens_match_across_backends():
    """Classic-prefill sampling is the same math on slot and paged caches;
    the sampled streams must agree bit-for-bit like the greedy ones do."""
    mk = lambda kv: run_to_completion(
        _engine(num_slots=2, kv=kv,
                **({"prefill_chunk": 0} if kv == "paged" else {})),
        [_req(i, gen_len=6, sampling=SAMPLED.derive(i)) for i in range(3)],
        dt=0.05)
    assert mk("slot") == mk("paged")


# ---------------------------------------------------------------------------
# RequestQueue: sorted online push (satellite regression)
# ---------------------------------------------------------------------------


def test_queue_out_of_order_push_keeps_time_gate():
    """An online push with an *earlier* arrival than the tail must not hide
    behind the tail: pop_ready gates on the head, so an append-only queue
    would return None here and strand the arrived request."""
    q = RequestQueue()
    q.push(_req(0, arrival_t=5.0))
    q.push(_req(1, arrival_t=1.0))  # out-of-order online push
    q.push(_req(2, arrival_t=3.0))
    assert q.depth(1.0) == 1
    r = q.pop_ready(1.0)
    assert r is not None and r.rid == 1
    assert q.pop_ready(1.0) is None
    assert [r.rid for r in q.ready(10.0)] == [2, 0]


def test_queue_push_ties_keep_fifo_order():
    q = RequestQueue()
    for rid in (3, 1, 2):
        q.push(_req(rid, arrival_t=1.0))
    assert [r.rid for r in q.ready(1.0)] == [3, 1, 2]


def test_queue_remove_targets_policy_selection():
    q = RequestQueue([_req(0, arrival_t=0.0), _req(1, arrival_t=0.0)])
    pick = q.ready(0.0)[1]
    q.remove(pick)
    assert [r.rid for r in q.ready(0.0)] == [0] and len(q) == 1


# ---------------------------------------------------------------------------
# BlockManager: double-free / reuse guard (satellite)
# ---------------------------------------------------------------------------


def test_block_manager_double_free_raises():
    bm = BlockManager(CFG, ENV0, num_slots=2, prompt_len=P, max_gen=8,
                      block_size=8)
    s = bm.admit(0, 8)
    bm.ensure(s, P - 1)
    bm.evict(s)
    with pytest.raises(RuntimeError, match="double free"):
        bm.evict(s)
    assert bm.blocks_in_use == 0, "failed double free must not corrupt"


def test_block_manager_aliased_table_free_raises():
    """A table entry pointing at an already-free block (the corruption a
    future refcount bug could introduce) must refuse to free, not push the
    id into the free list twice."""
    bm = BlockManager(CFG, ENV0, num_slots=2, prompt_len=P, max_gen=8,
                      block_size=8)
    a = bm.admit(0, 8)
    bm.ensure(a, P - 1)
    freed = int(bm.table[a, 0])
    before = bm.info(a).alloc_g
    bm.evict(a)
    b = bm.admit(1, 8)
    bm.info(b).alloc_g = before
    bm.table[b, :before] = freed  # forge an alias to a free block
    with pytest.raises(RuntimeError, match="double free"):
        bm.evict(b)


# ---------------------------------------------------------------------------
# scheduler bug sweep (ISSUE-4 satellites): stale victims, submit purity,
# lane-occupied victims
# ---------------------------------------------------------------------------


class _ScriptedPolicy:
    """FIFO admission with an injected (possibly buggy) victim verdict —
    the engine must survive whatever a policy hands back."""
    name = "scripted"

    def __init__(self, victim_fn):
        self.victim_fn = victim_fn

    def select(self, ready, now):
        return ready[0] if ready else None

    def victim(self, running, candidate, now):
        return self.victim_fn(running, candidate, now)


def test_stale_victim_verdict_is_backpressure_not_stopiteration():
    """A victim that occupies no slot (retired this iteration, or a bogus
    request) must read as "no victim" — a bare next() in _slot_of would
    leak StopIteration out of the scheduler loop instead."""
    ghost = _req(99)  # never submitted, occupies nothing
    eng = ServingEngine(CFG, PARAMS, num_slots=2, prompt_len=P, max_gen=8,
                        block_size=8, kv_blocks=1 + 3,  # one request's worth
                        policy=_ScriptedPolicy(lambda run, c, now: ghost),
                        clock=ManualClock())
    out = run_to_completion(eng, [_req(0, gen_len=4),
                                  _req(1, gen_len=4, arrival_t=0.01)],
                            dt=0.05)
    assert sorted(out) == [0, 1], "backpressure, then normal admission"
    assert eng.metrics.preemptions == 0


def test_submit_derives_gen_len_without_mutating_requests():
    """submit() must not write the max_tokens cap back into the caller's
    Request — the CLI --verify re-serve path re-submits the same objects
    and must see the declared gen_len unchanged (double-submit test)."""
    r = _req(0, gen_len=6, sampling=SamplingParams(max_tokens=3))
    out1 = run_to_completion(_engine(num_slots=1), [r], dt=0.05)
    assert r.gen_len == 6, "caller state mutated by submit()"
    assert len(out1[0]) == 3, "the cap still binds at admission"
    r.tokens, r.t_admit, r.t_first_token, r.t_done = [], None, None, None
    out2 = run_to_completion(_engine(num_slots=1), [r], dt=0.05)
    assert out2 == out1 and r.gen_len == 6


def test_preemption_never_targets_an_open_prefill_lane():
    """Only decode slots are preemptible: a (buggy) verdict naming a
    request that is mid-chunked-prefill would leave its _Lane writing
    prompt chunks into a freed/reassigned slot. The engine must skip
    lane-occupied victims and fall back to backpressure."""
    a = _req(0, gen_len=4)
    b = _req(1, gen_len=4, arrival_t=0.01)
    # verdict fires only while nothing decodes — exactly the window where
    # `a` is still prefilling (running excludes prefilling slots)
    pol = _ScriptedPolicy(lambda run, c, now: None if run else a)
    eng = ServingEngine(CFG, PARAMS, num_slots=2, prompt_len=P, max_gen=8,
                        block_size=8, kv_blocks=1 + 3, prefill_chunk=6,
                        policy=pol, clock=ManualClock())
    out = run_to_completion(eng, [a, b], dt=0.05)
    assert eng.metrics.preemptions == 0, "lane-occupied victim was evicted"
    assert sorted(out) == [0, 1]
    solo = run_to_completion(_engine(num_slots=1, prefill_chunk=6),
                             [_req(0, gen_len=4)], dt=0.05)
    assert out[0] == solo[0], "the prefilling request was disturbed"


# ---------------------------------------------------------------------------
# SchedulerPolicy: FIFO / EDF selection, preemption, miss feedback
# ---------------------------------------------------------------------------


def test_policy_registry_and_protocol():
    fifo = make_scheduler_policy("fifo")
    edf = make_scheduler_policy("edf", preemptive=True)
    assert isinstance(fifo, FIFOPolicy) and isinstance(edf, EDFPolicy)
    for pol in (fifo, edf):
        assert isinstance(pol, object) and hasattr(pol, "select") \
            and hasattr(pol, "victim")
    with pytest.raises(ValueError):
        make_scheduler_policy("lifo")


def test_kv_backend_protocol_and_registry():
    for kind, cls in (("paged", BlockManager), ("slot", SlotPool)):
        be = make_kv_backend(kind, CFG, ENV0, num_slots=2, prompt_len=P,
                             max_gen=4)
        assert isinstance(be, cls) and be.kind == kind
        assert isinstance(be, KVBackend)  # runtime_checkable surface
    with pytest.raises(ValueError):
        make_kv_backend("mmap", CFG, ENV0, num_slots=2, prompt_len=P,
                        max_gen=4)


def test_engine_accepts_prebuilt_backend():
    be = make_kv_backend("paged", CFG, ENV0, num_slots=3, prompt_len=P,
                         max_gen=8)
    eng = ServingEngine(CFG, PARAMS, prompt_len=P, max_gen=8, kv=be,
                        clock=ManualClock())
    assert eng.pool is be and eng.kv == "paged"
    out = run_to_completion(eng, [_req(0, gen_len=4)], dt=0.05)
    assert len(out[0]) == 4


def test_edf_selects_earliest_deadline_fifo_on_ties():
    edf = EDFPolicy()
    loose = _req(0, arrival_t=0.0, deadline_s=9.0)
    tight = _req(1, arrival_t=0.0, deadline_s=1.0)
    assert edf.select([loose, tight], 0.0) is tight
    a, b = _req(2, deadline_s=math.inf), _req(3, deadline_s=math.inf)
    assert edf.select([a, b], 0.0) is a, "no deadlines -> FIFO"
    assert FIFOPolicy().select([loose, tight], 0.0) is loose


def test_edf_victim_verdicts():
    edf = EDFPolicy(preemptive=True)
    runner = _req(0, deadline_s=math.inf)
    # urgent-but-salvageable candidate vs a deadline-free runner: preempt
    urgent = _req(2, arrival_t=0.0, deadline_s=2.0)
    assert edf.victim([runner], urgent, now=1.0) is runner
    # a candidate already past its deadline never preempts — destroying
    # the runner's progress cannot save it
    doomed = _req(1, arrival_t=0.0, deadline_s=0.5)
    assert edf.victim([runner], doomed, now=1.0) is None
    # deadline-free candidates never preempt either
    assert edf.victim([runner], _req(3), now=1.0) is None
    # and a runner with comparable slack is not worth restarting
    peer = _req(4, arrival_t=0.0, deadline_s=2.5)
    assert edf.victim([peer], urgent, now=1.0) is None
    assert not FIFOPolicy().victim([runner], urgent, now=1.0)


def test_edf_admission_beats_fifo_on_deadline_misses():
    """One slot, a burst where the later arrivals hold the tight deadlines:
    FIFO serves in arrival order and blows them; EDF reorders and meets
    every deadline it can."""
    def trace():
        # one slot serves ~6 steps x 0.1s per request: prioritized, the two
        # tight ones finish at ~0.6s and ~1.2s (inside 1.5s); behind three
        # loose ones they finish at ~2.4s and ~3.0s (hopeless)
        loose = [_req(i, gen_len=6, deadline_s=60.0) for i in range(3)]
        tight = [_req(3 + i, gen_len=6, deadline_s=1.5) for i in range(2)]
        return loose + tight

    def misses(policy):
        eng = _engine(num_slots=1, policy=policy)
        run_to_completion(eng, trace(), dt=0.1)
        assert len(eng.completed) == 5
        return eng.metrics.deadline_misses

    m_fifo = misses(FIFOPolicy())
    m_edf = misses(EDFPolicy())
    assert m_edf < m_fifo, (m_edf, m_fifo)
    assert m_edf == 0


def test_edf_preemption_restarts_victim_with_identical_tokens():
    """A deadline-free runner is preempted for an urgent arrival; the
    victim restarts later and — because sampling is position-keyed — its
    final token stream matches an undisturbed run bit-for-bit."""
    victim_sp = SAMPLED
    solo = run_to_completion(
        _engine(num_slots=1),
        [_req(0, gen_len=8, sampling=victim_sp)], dt=0.05)
    eng = _engine(num_slots=1,
                  policy=EDFPolicy(preemptive=True, min_slack_s=1.0))
    out = run_to_completion(
        eng,
        [_req(0, gen_len=8, sampling=victim_sp),
         _req(1, gen_len=2, arrival_t=0.12, deadline_s=0.4)], dt=0.05)
    assert eng.metrics.preemptions >= 1, "urgent arrival must preempt"
    assert out[0] == solo[0], "restart must regenerate identical tokens"
    assert len(out[1]) == 2
    done = {r.rid: r for r in eng.completed}
    assert done[1].t_done < done[0].t_done, "urgent request finished first"


def test_preemption_deferred_until_it_can_make_room():
    """An eviction that cannot cover the candidate's reservation must be
    declined up front (pool.preempt_frees) — otherwise the engine restarts
    one runner per step, costing progress without admitting anything.

    Two runners commit 5 blocks each (all 10 usable); the urgent gen-8
    candidate needs 6, and evicting either runner alone frees only 5. The
    verdicts while both run must be declined (runner 0 finishes
    undisturbed, before the urgent request ever admits); once runner 0
    retires, preempting runner 1 genuinely makes room (5 free + 5 freed)
    and is allowed — exactly one restart."""
    eng = _engine(num_slots=3, max_gen=8, block_size=4, kv_blocks=11,
                  policy=EDFPolicy(preemptive=True, min_slack_s=100.0))
    runners = [_req(i, gen_len=4) for i in range(2)]
    # deadline loose enough that the candidate is still salvageable when
    # the eviction finally can make room (doomed candidates never preempt)
    urgent = _req(7, gen_len=8, arrival_t=0.08, deadline_s=1.0)
    out = run_to_completion(eng, [*runners, urgent], dt=0.05)
    assert eng.metrics.preemptions == 1, \
        "fruitless evictions must be declined; the useful one allowed"
    assert sorted(out) == [0, 1, 7] and len(out[7]) == 8
    done = {r.rid: r for r in eng.completed}
    assert done[0].t_done < done[7].t_admit, \
        "runner 0 must finish undisturbed before the urgent one admits"


def test_latency_policy_scales_up_on_new_deadline_misses():
    pol = LatencyPolicy(target_p95_ms=1000.0, min_nodes=1, max_nodes=4)

    class V:
        compute = (1, 2)

    healthy = {"latency_p95_ms": 10.0, "queue_depth": 2.0}
    assert pol.decide(V, {**healthy, "deadline_misses": 0.0}).target == 2
    plan = pol.decide(V, {**healthy, "deadline_misses": 2.0})
    assert plan.target == 3 and "miss" in plan.reason
    # the same cumulative count is not a *new* miss next decision
    assert pol.decide(V, {**healthy, "deadline_misses": 2.0}).target == 2
    off = LatencyPolicy(target_p95_ms=1000.0, min_nodes=1, max_nodes=4,
                        scale_on_misses=False)
    assert off.decide(V, {**healthy, "deadline_misses": 5.0}).target == 2


def test_engine_snapshot_reports_preemptions():
    eng = _engine(num_slots=1, policy=EDFPolicy(preemptive=True,
                                                min_slack_s=1.0))
    run_to_completion(
        eng, [_req(0, gen_len=8),
              _req(1, gen_len=2, arrival_t=0.12, deadline_s=0.4)], dt=0.05)
    assert eng.snapshot()["preemptions"] >= 1.0
