import os
import sys

# pytest must see ONE device (the dry-run alone forces 512 in subprocesses)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

from repro.configs.base import ParallelPlan
from repro.models.env import Env


@pytest.fixture(scope="session")
def local_env():
    plan = ParallelPlan(fsdp=False, remat="full", attn_impl="naive",
                        kv_cache="replicated")
    return Env(mesh=None, plan=plan)


@pytest.fixture()
def rng():
    return jax.random.PRNGKey(0)
