"""Elastic runtime: scale/reshard/restore semantics with a real (1-device)
JAX data plane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ParallelPlan, ShapeConfig
from repro.core import VirtualCluster
from repro.core.elastic import ElasticTrainer

PLAN = ParallelPlan(fsdp=False, remat="full", attn_impl="naive",
                    kv_cache="replicated")
SHAPE = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")


def mk_trainer(tmp_path, cluster, **kw):
    cfg = get_smoke("yi-9b")
    return ElasticTrainer(cluster.template, cfg, SHAPE, str(tmp_path),
                          plan=PLAN, ckpt_every=5, **kw)


def test_planned_scale_preserves_progress(tmp_path):
    c = VirtualCluster(n_compute=2)
    t = mk_trainer(tmp_path, c)
    t.run_steps(4)
    assert t.step == 4
    c.scale_to(3)
    t.run_steps(2)  # triggers checkpoint->reshard->resume
    assert t.step == 6, "no steps lost on planned scale"
    assert t.stats.reshards == 1
    assert t.stats.steps_lost == 0
    c.shutdown()


def test_crash_rolls_back_to_durable_checkpoint(tmp_path):
    c = VirtualCluster(n_compute=3, ttl=2.0)
    t = mk_trainer(tmp_path, c)
    t.run_steps(7)  # ckpt_every=5 -> durable at step 5
    t.ckpt.wait()
    victim = c.compute_nodes()[-1]
    c.crash_node(victim)
    c.pump(dt=3.0)
    t.run_steps(1, planned_changes=False)
    assert t.stats.restores == 1
    assert t.stats.steps_lost == 2  # steps 6,7 rolled back
    assert t.step == 6  # restored 5, ran 1
    c.shutdown()


def test_loss_continuity_across_reshard(tmp_path):
    """The loss stream after a planned reshard equals an uninterrupted run
    (same data order, same state)."""
    cfg = get_smoke("yi-9b")
    # uninterrupted reference
    c1 = VirtualCluster(n_compute=2)
    t1 = mk_trainer(tmp_path / "a", c1)
    losses_ref = []
    for _ in range(6):
        losses_ref.append(t1.run_steps(1)["loss"])
    c1.shutdown()
    # interrupted at step 3 by a scale event
    c2 = VirtualCluster(n_compute=2)
    t2 = mk_trainer(tmp_path / "b", c2)
    losses = []
    for i in range(6):
        if i == 3:
            c2.scale_to(3)
        losses.append(t2.run_steps(1)["loss"])
    c2.shutdown()
    np.testing.assert_allclose(losses, losses_ref, rtol=2e-2)


def test_training_reduces_loss(tmp_path):
    c = VirtualCluster(n_compute=2)
    t = mk_trainer(tmp_path, c)
    first = t.run_steps(1)["loss"]
    last = t.run_steps(30)["loss"]
    assert last < first, (first, last)
    c.shutdown()
