"""Elastic scaling demo — the paper's §IV claim, live:

"If more computing power is needed, all we need to do is to power up more
physical machines and deploy new HPC containers on those machines" — here
the training job KEEPS RUNNING through 2 -> 4 -> 3 nodes, resharding its
state at each membership epoch with zero lost steps.

    PYTHONPATH=src python examples/elastic_scaling.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_smoke
from repro.configs.base import ParallelPlan, ShapeConfig
from repro.core import VirtualCluster
from repro.core.elastic import ElasticTrainer


def main():
    plan = ParallelPlan(fsdp=False, remat="full", attn_impl="naive")
    cluster = VirtualCluster(n_compute=2)
    cfg = get_smoke("paper-demo")
    shape = ShapeConfig("elastic", 32, 8, "train")
    tr = ElasticTrainer(cluster.template, cfg, shape, "/tmp/elastic_ckpt",
                        plan=plan, ckpt_every=25)

    schedule = {5: 4, 12: 3}  # step -> target nodes
    for i in range(20):
        if i in schedule:
            n = schedule[i]
            print(f"--- scaling to {n} nodes (epoch "
                  f"{cluster.rendering.epoch} -> ...) ---")
            cluster.scale_to(n)
        m = tr.run_steps(1)
        print(f"step {tr.step:3d} loss={m['loss']:.4f} "
              f"nodes={len(cluster.compute_nodes())} "
              f"epoch={cluster.rendering.epoch}")
    st = tr.stats
    print(f"\nepoch_changes={st.epoch_changes} reshards={st.reshards} "
          f"steps_lost={st.steps_lost} (expected 0: planned changes)")
    assert st.steps_lost == 0
    cluster.shutdown()


if __name__ == "__main__":
    main()
