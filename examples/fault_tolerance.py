"""Fault-tolerance demo — the failure handling the paper leaves as future
work: a node crashes mid-training (no deregistration), the Consul-analogue
TTL reaps it, the view shrinks, and the job restores from the last durable
checkpoint on the survivors. A straggler is then detected from step-time
metrics and replaced.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_smoke
from repro.configs.base import ParallelPlan, ShapeConfig
from repro.core import StragglerPolicy, VirtualCluster
from repro.core.elastic import ElasticTrainer


def main():
    plan = ParallelPlan(fsdp=False, remat="full", attn_impl="naive")
    # 4 nodes: 3 survive the crash below — median-based straggler detection
    # needs >=3 reporters for one 5x outlier to clear factor*median
    cluster = VirtualCluster(n_compute=4, ttl=2.0,
                             policy=StragglerPolicy(factor=2.0))
    cfg = get_smoke("paper-demo")
    shape = ShapeConfig("ft", 32, 8, "train")
    tr = ElasticTrainer(cluster.template, cfg, shape, "/tmp/ft_ckpt",
                        plan=plan, ckpt_every=5)

    tr.run_steps(7)
    print(f"trained to step {tr.step}; durable ckpt at "
          f"{tr.ckpt.latest_step()}")

    victim = cluster.compute_nodes()[-1]
    print(f"\n--- CRASH {victim} (stops heartbeating; no dereg) ---")
    cluster.crash_node(victim)
    cluster.pump(dt=3.0)  # TTL lapses -> reaped -> epoch bump
    tr.run_steps(1, planned_changes=False)
    print(f"recovered on {len(cluster.compute_nodes())} nodes at step "
          f"{tr.step}; rolled back {tr.stats.steps_lost} steps "
          f"(restores={tr.stats.restores})")

    print("\n--- STRAGGLER: one node reports 5x step times ---")
    slow = cluster.compute_nodes()[0]
    cluster.sim.make_straggler(slow, bias_s=5.0)
    cluster.sim.report_step_times(step=tr.step, base_s=1.0)
    cluster.pump(autoscale=True)
    tr.run_steps(2)
    print(f"straggler {slow} replaced; nodes={cluster.compute_nodes()} "
          f"step={tr.step}")
    assert slow not in cluster.compute_nodes()
    cluster.shutdown()
    print("\nfault-tolerance demo OK")


if __name__ == "__main__":
    main()
