"""Serving demo: continuous batching with load-driven autoscaling.

A Poisson arrival trace is served by the continuous-batching engine over
the paged KV backend; the engine publishes queue depth / latency /
occupancy into the registry KV, and the cluster's autoscaling policy grows
the node set while the backlog is deep, then shrinks it as the queue
drains. The greedy pass verifies tokens against the one-shot serve_batch
baseline; the second pass serves the same trace with seeded nucleus
sampling under EDF admission and verifies the sampled streams are
bit-identical across two different engine shapes (the serving API v2
lane-placement-invariance contract).

    PYTHONPATH=src python examples/serve_demo.py
"""
import os
import subprocess
import sys

BASE = [sys.executable, "-m", "repro.launch.serve", "--arch", "paper-demo",
        "--smoke", "--trace", "poisson", "--verify"]
SAMPLED = ["--temperature", "0.8", "--top-k", "40", "--top-p", "0.95",
           "--sched", "edf", "--deadline", "2.0"]
ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
       # containers with libtpu probe TPU metadata forever otherwise
       "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}

if __name__ == "__main__":
    rc = subprocess.call(BASE, env=ENV)
    sys.exit(rc or subprocess.call(BASE + SAMPLED, env=ENV))
