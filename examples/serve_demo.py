"""Serving demo: continuous batching with load-driven autoscaling.

A Poisson arrival trace is served by the slot-pooled continuous-batching
engine; the engine publishes queue depth / latency / occupancy into the
registry KV, and the cluster's QueueDepthPolicy grows the node set while the
backlog is deep, then shrinks it as the queue drains. Output tokens are
verified against the one-shot serve_batch baseline.

    PYTHONPATH=src python examples/serve_demo.py
"""
import os
import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "paper-demo",
         "--smoke", "--trace", "poisson", "--verify"],
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # containers with libtpu probe TPU metadata forever otherwise
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}))
