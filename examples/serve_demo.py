"""Serving demo: discovery-registered replicas + batched prefill/decode.

    PYTHONPATH=src python examples/serve_demo.py
"""
import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "paper-demo",
         "--smoke", "--requests", "4", "--prompt-len", "16", "--gen", "8"],
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"}))
