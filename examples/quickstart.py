"""Quickstart: the paper's workflow in five steps.

1. Build a ClusterImage (the Dockerfile of Fig. 2, as data).
2. Form a VirtualCluster — nodes self-register in the Consul-analogue.
3. Read the auto-rendered hostfile (consul-template of Fig. 5).
4. Submit an SPMD job over the rendered mesh (`mpirun` of Fig. 8).
5. Train a ~100M-param LM for a few hundred steps with elastic checkpoints.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.configs.base import ParallelPlan, ShapeConfig
from repro.core import ClusterImage, VirtualCluster
from repro.core.elastic import ElasticTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-110m", action="store_true",
                    help="train the full paper-demo 110M model (slow on CPU)")
    args = ap.parse_args()

    # (1) image encapsulation
    cfg = get_config("paper-demo") if args.full_110m else get_smoke("paper-demo")
    plan = ParallelPlan(fsdp=False, remat="full", attn_impl="naive")
    image = ClusterImage.build("mpi-computenode", cfg, plan, "train")
    print(f"[1] built image {image.digest}")
    print(image.dockerfile())

    # (2) discovery: 1 head + 2 compute, exactly the paper's Fig. 4
    cluster = VirtualCluster(n_compute=2, image=image)
    print("[2] nodes registered:", cluster.compute_nodes())

    # (3) the rendered hostfile
    print("[3] hostfile:\n" + cluster.hostfile)

    # (4) a 16-domain SPMD job (paper Fig. 8)
    def mpi_job(mesh):
        x = jnp.linspace(0, 1, 16 * 64).reshape(16, 64)
        step = jax.jit(lambda v: 0.25 * (2 * v + jnp.roll(v, 1, 0)
                                         + jnp.roll(v, -1, 0)))
        for _ in range(8):
            x = step(x)
        return float(x.sum())

    print(f"[4] 16-domain job result: {cluster.submit(mpi_job):.4f}")

    # (5) train with elastic checkpoints
    shape = ShapeConfig("quickstart", seq_len=64,
                        global_batch=8, kind="train")
    trainer = ElasticTrainer(cluster.template, cfg, shape,
                             "/tmp/quickstart_ckpt", plan=plan,
                             ckpt_every=50)
    t0 = time.time()
    for i in range(args.steps // 10):
        m = trainer.run_steps(10)
        print(f"[5] step {trainer.step:4d} loss={m['loss']:.4f} "
              f"({time.time()-t0:.1f}s)")
    trainer.finalize()
    print(f"done; checkpoints at steps {trainer.ckpt.available_steps()}")
    cluster.shutdown()


if __name__ == "__main__":
    main()
