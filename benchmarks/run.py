# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
# --smoke runs the cheap subset (CI: tools/ci.sh).
import argparse
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # so `python benchmarks/run.py` finds the package


def main() -> None:
    from benchmarks.paper_benches import (bench_autoscale_response,
                                          bench_cluster_formation,
                                          bench_env_capture,
                                          bench_interconnect_model,
                                          bench_mpi_job, bench_serve_paged,
                                          bench_serve_paged_full,
                                          bench_serve_prefix,
                                          bench_serve_prefix_full,
                                          bench_serve_replicas,
                                          bench_serve_replicas_full,
                                          bench_serve_rollout,
                                          bench_serve_rollout_full,
                                          bench_serve_sampling,
                                          bench_serve_sampling_full,
                                          bench_serve_spec,
                                          bench_serve_spec_full,
                                          bench_serve_tiered,
                                          bench_serve_tiered_full,
                                          bench_serve_throughput,
                                          bench_serve_throughput_full,
                                          bench_step_time, warmed_sections)

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="cheap subset for CI smoke runs")
    args = ap.parse_args()

    if args.smoke:
        benches = (bench_env_capture, bench_mpi_job, bench_serve_throughput,
                   bench_serve_paged, bench_serve_sampling,
                   bench_serve_prefix, bench_serve_replicas,
                   bench_serve_spec, bench_serve_tiered,
                   bench_serve_rollout)
    else:
        benches = (bench_cluster_formation, bench_autoscale_response,
                   bench_mpi_job, bench_env_capture,
                   bench_interconnect_model, bench_serve_throughput_full,
                   bench_step_time, bench_serve_paged_full,
                   bench_serve_sampling_full, bench_serve_prefix_full,
                   bench_serve_replicas_full, bench_serve_spec_full,
                   bench_serve_tiered_full, bench_serve_rollout_full)

    print("name,us_per_call,derived")
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us},{derived}", flush=True)
        except Exception as e:  # a failed bench must not hide the others
            print(f"{bench.__name__},ERROR,{e!r}", flush=True)

    if args.smoke:
        # every wall-reporting section of BENCH_serve.json must have been
        # warmed with its EXACT timed workload (paper_benches warmup
        # registry) — a partial warm-up silently times compilation
        import json
        path = os.path.abspath(os.path.join(_ROOT, "BENCH_serve.json"))
        with open(path) as f:
            report = json.load(f)
        wall_sections = {
            name for name, sec in report.items()
            if isinstance(sec, dict)
            and any("wall" in k for k in _wall_keys(sec))}
        missing = wall_sections - warmed_sections()
        assert not missing, (
            f"wall-timed sections never warmed with their exact workload: "
            f"{sorted(missing)}")
        print(f"warmup_registry,OK,{sorted(wall_sections)}", flush=True)


def _wall_keys(section: dict):
    for k, v in section.items():
        if isinstance(v, dict):
            yield from _wall_keys(v)
        else:
            yield k


if __name__ == '__main__':
    main()
