# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks.paper_benches import (bench_autoscale_response,
                                          bench_cluster_formation,
                                          bench_env_capture,
                                          bench_interconnect_model,
                                          bench_mpi_job, bench_step_time)

    print("name,us_per_call,derived")
    for bench in (bench_cluster_formation, bench_autoscale_response,
                  bench_mpi_job, bench_env_capture,
                  bench_interconnect_model, bench_step_time):
        try:
            for name, us, derived in bench():
                print(f"{name},{us},{derived}", flush=True)
        except Exception as e:  # a failed bench must not hide the others
            print(f"{bench.__name__},ERROR,{e!r}", flush=True)


if __name__ == '__main__':
    main()
