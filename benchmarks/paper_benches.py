"""One benchmark per paper table/figure (DESIGN.md §6).

Each function returns a list of (name, us_per_call, derived) rows.
"""
from __future__ import annotations

import json
import os
import time
from statistics import median

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ParallelPlan, ShapeConfig
from repro.configs.paper_demo import CLUSTER
from repro.core import ClusterImage, VirtualCluster
from repro.core.elastic import ElasticTrainer

PLAN = ParallelPlan(fsdp=False, remat="full", attn_impl="naive",
                    kv_cache="replicated")


def _t(fn, n=3):
    fn()  # warmup
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return median(ts) * 1e6


# -- Fig. 6/7: containers up + Consul registration -> rendered hostfile -------


def bench_cluster_formation():
    rows = []
    for n in (2, 4, 8, 16, 32):
        t0 = time.perf_counter()
        c = VirtualCluster(n_compute=n)
        r = c.rendering
        dt = (time.perf_counter() - t0) * 1e6
        assert len(r.view.compute) == n
        rows.append((f"cluster_formation_n{n}", round(dt, 1),
                     f"epoch={r.epoch}"))
        c.shutdown()
    return rows


# -- §IV auto-scaling: trigger -> new epoch (control plane only + with reshard)


def bench_autoscale_response(tmpdir="/tmp/bench_as"):
    rows = []
    c = VirtualCluster(n_compute=2)
    t0 = time.perf_counter()
    c.scale_to(4)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("autoscale_2to4_controlplane", round(dt, 1),
                 f"epoch={c.rendering.epoch}"))
    # with a live training job (checkpoint -> reshard -> resume)
    cfg = get_smoke("paper-demo")
    shape = ShapeConfig("b", 16, 4, "train")
    tr = ElasticTrainer(c.template, cfg, shape, tmpdir, plan=PLAN,
                        ckpt_every=100)
    tr.run_steps(2)
    t0 = time.perf_counter()
    c.scale_to(6)
    tr.run_steps(1)  # includes ckpt+reshard+rejit
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("autoscale_with_reshard", round(dt, 1),
                 f"reshards={tr.stats.reshards}"))
    c.shutdown()
    return rows


# -- Fig. 8: the 16-domain MPI job (halo-exchange stencil) ---------------------


def bench_mpi_job():
    n = CLUSTER.mpi_ranks
    c = VirtualCluster(n_compute=2)

    def job(mesh):
        x = jnp.linspace(0, 1, n * 256).reshape(n, 256)

        @jax.jit
        def step(x):
            return 0.25 * (2 * x + jnp.roll(x, 1, 0) + jnp.roll(x, -1, 0))

        step(x).block_until_ready()
        us = _t(lambda: step(x).block_until_ready(), n=10)
        return us

    us = c.submit(job)
    c.shutdown()
    return [(f"mpi_job_{n}domain_step", round(us, 1), "halo-exchange")]


# -- Table I/II: environment capture (image encapsulation) ----------------------


def bench_env_capture():
    cfg = get_smoke("paper-demo")
    img = ClusterImage.build("bench", cfg, PLAN, "train")
    us = _t(lambda: ClusterImage.build("bench", cfg, PLAN, "train").digest)
    img2 = ClusterImage.build("bench", cfg, PLAN, "train")
    det = img.digest == img2.digest
    return [("image_build_digest", round(us, 1), f"deterministic={det}")]


# -- Conclusion: interconnect influence (10GbE vs ICI on collective bytes) -------


def bench_interconnect_model():
    rows = []
    rep_dir = "reports/dryrun/single_pod_16x16"
    if not os.path.isdir(rep_dir):
        return [("interconnect_model", 0.0, "no dry-run reports")]
    for fn in sorted(os.listdir(rep_dir))[:40]:
        with open(os.path.join(rep_dir, fn)) as f:
            rep = json.load(f)
        by = rep.get("collective_by_type", {})
        bytes_total = sum(by.values())
        ici_s = rep.get("collective_s", 0.0)
        # the paper's 10GbE fabric: 1.25 GB/s shared per node
        geth_s = sum((2.0 if k == "all-reduce" else 1.0) * b / 1.25e9
                     for k, b in by.items())
        rows.append((f"coll_{rep['arch']}_{rep['shape']}",
                     round(ici_s * 1e6, 1),
                     f"10GbE={geth_s*1e6:.0f}us x{geth_s/max(ici_s,1e-12):.0f}"))
    return rows


# -- continuous-batching serving: tokens/s + p95 latency under a Poisson trace --


def bench_serve_throughput(smoke: bool = True):
    from repro.core import QueueDepthPolicy
    from repro.models import model as Mo
    from repro.models.env import Env
    from repro.serve import SERVE_PLAN, ServingEngine, poisson_trace

    n_req, gen, prompt_len = (16, 8, 16) if smoke else (64, 32, 32)
    cfg = get_smoke("paper-demo")
    params = Mo.init_params(jax.random.PRNGKey(0), cfg,
                            Env(mesh=None, plan=SERVE_PLAN))
    c = VirtualCluster(n_compute=1,
                       policy=QueueDepthPolicy(target_per_node=2,
                                               min_nodes=1, max_nodes=6),
                       cooldown_s=0.3)
    eng = ServingEngine(cfg, params, num_slots=4, prompt_len=prompt_len,
                        max_gen=gen, clock=c.clock)
    trace = poisson_trace(n_req, 16.0, prompt_len=prompt_len,
                          vocab_size=cfg.vocab_size, gen_len=gen, seed=0)
    # warm the jitted prefill/decode outside the timed window (other benches
    # warm up via _t); then reset the engine's counters and metrics
    from repro.serve import ServingMetrics, run_to_completion
    run_to_completion(eng, poisson_trace(1, 100.0, prompt_len=prompt_len,
                                         vocab_size=cfg.vocab_size,
                                         gen_len=2, seed=1), dt=0.001)
    eng.metrics = ServingMetrics(window_s=10.0)
    eng.completed.clear()
    eng.decode_steps = 0
    sizes = []
    t0 = time.perf_counter()
    out = c.serve(eng, trace, dt=lambda n: 0.05 / max(n, 1),
                  on_step=lambda i, s, cl: sizes.append(
                      len(cl.current_view().compute)))
    wall = time.perf_counter() - t0
    snap = eng.snapshot()
    n_tok = sum(len(t) for t in out.values())
    c.shutdown()
    return [
        ("serve_throughput", round(wall / max(eng.decode_steps, 1) * 1e6, 1),
         f"{n_tok/wall:.0f} tok/s(wall) "
         f"p95={snap.get('latency_p95_ms', 0.0):.0f}ms"),
        ("serve_autoscale_span", round(eng.clock.now() * 1e6, 1),
         f"nodes 1->{max(sizes)}->{sizes[-1]} over {len(trace)} reqs"),
    ]


def bench_serve_throughput_full():
    return bench_serve_throughput(smoke=False)


# -- paged KV vs slot reservation at a fixed KV memory budget -------------------
#
# The perf claim of the paged serving data plane: at the same KV HBM budget,
# block tables + on-demand allocation admit >= 2x the concurrent requests
# (slot reservation pins prompt+max_gen per slot; paging commits only what a
# request's declared gen_len can touch) and decode >= 1.5x the tokens/s,
# with greedy output still token-exact vs the one-shot baseline.
# Emits BENCH_serve.json next to the repo root so CI records the trajectory.


def _cache_bytes(caches) -> int:
    return int(sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(caches)))


# -- warmup registry ------------------------------------------------------------
#
# Every WALL-timed serving section must be warmed with its EXACT timed
# workload before measurement: step jits are shared across engines
# (serve/kv.py shared_jit), so a partial warm-up silently compares a pass
# warmed by earlier benches against one still tracing mid-measurement.
# Benches register the workload fingerprint they warmed with; the timed
# run asserts its own fingerprint was registered, and run.py --smoke
# asserts every wall-reporting section in BENCH_serve.json checked in
# here. (Sim-time numbers — decode steps x a manual clock — are invariant
# to compile time and need no warm-up.)

_WARMUPS: dict = {}


def _trace_fingerprint(trace) -> str:
    import hashlib
    h = hashlib.sha256()
    for r in trace:
        h.update(np.asarray(r.prompt, np.int32).tobytes())
        h.update(repr((r.rid, r.gen_len, r.arrival_t, r.sampling)).encode())
    return h.hexdigest()[:16]


def _register_warmup(section: str, trace) -> str:
    fp = _trace_fingerprint(trace)
    _WARMUPS.setdefault(section, set()).add(fp)
    return fp


def _assert_warmed(section: str, trace) -> None:
    fp = _trace_fingerprint(trace)
    assert fp in _WARMUPS.get(section, set()), (
        f"section {section!r}: timed workload {fp} was never run as its "
        f"own warm-up (registered: {sorted(_WARMUPS.get(section, set()))})")


def warmed_sections() -> set:
    """Sections whose timed workload was warmed exactly (for run.py)."""
    return set(_WARMUPS)


def _serve_engine_bench(eng, mk_trace, *, baseline_streamed: bool,
                        repeats: int = 3, section: str = "paged"):
    from repro.launch.serve import serve_batch
    from repro.serve import SERVE_PLAN, ServingMetrics, run_to_completion

    cfg = eng.cfg
    trace = mk_trace()
    # warm with the exact timed workload so EVERY step shape this trace
    # exercises (consecutive lane steps, lane->decode, pure decode, both
    # prev-token lengths) compiles outside the timed window
    run_to_completion(eng, mk_trace(), dt=1e-4)
    _register_warmup(section, trace)
    wall, sim, out, peak, snap = float("inf"), 0.0, None, [0], {}
    for _ in range(max(repeats, 1)):  # best-of-N: shields CI noise
        eng.metrics = ServingMetrics(window_s=1e9)
        eng.completed.clear()
        eng.decode_steps = 0
        peak = [0]
        timed = mk_trace()
        _assert_warmed(section, timed)
        c0 = eng.clock.now()
        t0 = time.perf_counter()
        run = run_to_completion(
            eng, timed, dt=1e-4,
            on_step=lambda i, s: peak.__setitem__(
                0, max(peak[0], len(eng.pool.occupied_slots()))))
        w = time.perf_counter() - t0
        if w < wall:
            wall, sim, out = w, eng.clock.now() - c0, run
            snap = eng.snapshot()
    n_tok = sum(len(t) for t in out.values())
    prompts = jnp.asarray(np.stack([r.prompt for r in trace]))
    base = np.asarray(serve_batch(None, cfg, eng.params, prompts,
                                  max(r.gen_len for r in trace), SERVE_PLAN,
                                  streamed_prefill=baseline_streamed))
    exact = all(np.array_equal(base[r.rid][:r.eff_gen_len],
                               np.array(out[r.rid]))
                for r in trace)
    kv_bytes = _cache_bytes(eng.pool.caches)
    return {
        "tokens": n_tok,
        "tokens_per_s_wall": round(n_tok / wall, 1),
        "ms_per_token_wall": round(wall / max(n_tok, 1) * 1e3, 4),
        "ms_per_token_sim": round(sim / max(n_tok, 1) * 1e3, 4),
        "decode_steps": eng.decode_steps,
        "latency_p95_ms_sim": round(snap.get("latency_p95_ms", 0.0), 2),
        "kv_bytes": kv_bytes,
        "peak_concurrent": peak[0],
        "kv_bytes_per_request": round(kv_bytes / max(peak[0], 1)),
        "token_exact_vs_one_shot": bool(exact),
        "wall_s": round(wall, 3),
    }


def bench_serve_paged(smoke: bool = True):
    """Slot-reserved vs paged KV on the same burst trace at ~equal KV HBM.

    slot: 2 slots x (prompt+max_gen) reserved tokens.
    paged: the same token budget as a block pool; requests commit only
    ceil((prompt+gen_len)/bs) blocks, so more of them fit at once.
    """
    from repro.models import model as Mo
    from repro.models.env import Env
    from repro.serve import SERVE_PLAN, ServingEngine, burst_trace

    cfg = get_smoke("paper-demo")
    params = Mo.init_params(jax.random.PRNGKey(0), cfg,
                            Env(mesh=None, plan=SERVE_PLAN))
    prompt_len, max_gen, bs = 16, 64, 8
    n_req = 96 if smoke else 192
    slot_slots = 3
    budget_tokens = slot_slots * (prompt_len + max_gen)  # 240
    kv_blocks = budget_tokens // bs  # incl. the null block -> equal budget
    trace = burst_trace(n_req, prompt_len=prompt_len,
                        vocab_size=cfg.vocab_size, gen_len=8, seed=0)
    trace[1].gen_len = max_gen  # the long tail that slot reservation fears

    def mk(kv, **kw):
        return ServingEngine(cfg, params, prompt_len=prompt_len,
                             max_gen=max_gen, kv=kv, **kw)

    mk_trace = lambda: [dataclasses_replace(r) for r in trace]
    res_slot = _serve_engine_bench(
        mk("slot", num_slots=slot_slots), mk_trace,
        baseline_streamed=False, section="slot")
    res_paged = _serve_engine_bench(
        mk("paged", num_slots=10, block_size=bs, kv_blocks=kv_blocks,
           prefill_chunk=2 * prompt_len), mk_trace,
        baseline_streamed=True, section="paged")

    report = {
        "config": {"arch": cfg.name, "prompt_len": prompt_len,
                   "max_gen": max_gen, "block_size": bs,
                   "requests": n_req, "kv_budget_tokens": budget_tokens,
                   "backend": jax.default_backend()},
        "slot": res_slot,
        "paged": res_paged,
        "speedup_tokens_per_s": round(res_paged["tokens_per_s_wall"]
                                      / max(res_slot["tokens_per_s_wall"],
                                            1e-9), 2),
        "concurrency_ratio": round(res_paged["peak_concurrent"]
                                   / max(res_slot["peak_concurrent"], 1), 2),
        "kv_bytes_ratio": round(res_paged["kv_bytes"]
                                / max(res_slot["kv_bytes"], 1), 3),
        "token_exact": bool(res_slot["token_exact_vs_one_shot"]
                            and res_paged["token_exact_vs_one_shot"]),
    }
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    with open(os.path.abspath(path), "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return [
        ("serve_paged_tokens_per_s", res_paged["tokens_per_s_wall"],
         f"slot={res_slot['tokens_per_s_wall']} "
         f"speedup={report['speedup_tokens_per_s']}x"),
        ("serve_paged_concurrency", res_paged["peak_concurrent"],
         f"slot={res_slot['peak_concurrent']} at "
         f"{report['kv_bytes_ratio']}x kv bytes "
         f"exact={report['token_exact']}"),
    ]


def bench_serve_paged_full():
    return bench_serve_paged(smoke=False)


def _merge_bench_report(section: dict) -> None:
    """Merge keys into BENCH_serve.json (bench_serve_paged writes the base
    report each run; later benches add their sections to it)."""
    path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "BENCH_serve.json"))
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged.update(section)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")


# -- serving API v2: sampled decoding + scheduler policies ----------------------
#
# Two claims recorded per commit (merged into BENCH_serve.json):
#   scheduling: EDF admission beats FIFO on deadline-miss rate on a trace
#     where the urgent requests arrive behind loose ones (same engine, same
#     KV, only the SchedulerPolicy differs).
#   sampling: seeded temperature/top-k/top-p decoding through the fused
#     sample step stays reproducible (two runs, bit-identical output) at a
#     recorded tokens/s alongside the greedy rate on the same trace.


def bench_serve_sampling(smoke: bool = True):
    from repro.models import model as Mo
    from repro.models.env import Env
    from repro.serve import (EDFPolicy, FIFOPolicy, SERVE_PLAN,
                             SamplingParams, ServingEngine, ServingMetrics,
                             burst_trace, run_to_completion)

    cfg = get_smoke("paper-demo")
    params = Mo.init_params(jax.random.PRNGKey(0), cfg,
                            Env(mesh=None, plan=SERVE_PLAN))
    prompt_len, gen = 16, 8

    def mk_engine(policy=None, num_slots=1):
        return ServingEngine(cfg, params, num_slots=num_slots,
                             prompt_len=prompt_len, max_gen=gen,
                             policy=policy)

    # -- scheduling: FIFO vs EDF on a deadline trace (sim time) -----------
    # one slot serves ~gen steps x 0.05s per request: prioritized, the
    # tight requests all fit their deadline; behind the loose ones, none do
    n_loose, n_tight = (6, 4) if smoke else (12, 8)
    tight_deadline = 0.05 * gen * (n_tight + 1.5)

    def deadline_trace():
        loose = burst_trace(n_loose, prompt_len=prompt_len,
                            vocab_size=cfg.vocab_size, gen_len=gen,
                            deadline_s=60.0, seed=0)
        tight = burst_trace(n_tight, prompt_len=prompt_len,
                            vocab_size=cfg.vocab_size, gen_len=gen,
                            deadline_s=tight_deadline, seed=1)
        for i, r in enumerate(tight):
            r.rid = n_loose + i
        return loose + tight

    sched = {}
    for name, policy in (("fifo", FIFOPolicy()), ("edf", EDFPolicy())):
        eng = mk_engine(policy=policy)
        out = run_to_completion(eng, deadline_trace(), dt=0.05)
        n = n_loose + n_tight
        n_tok = sum(len(t) for t in out.values())
        sched[name] = {
            "requests": n,
            "deadline_misses": eng.metrics.deadline_misses,
            "miss_rate": round(eng.metrics.deadline_misses / n, 3),
            "ms_per_token_sim": round(eng.clock.now() / max(n_tok, 1) * 1e3,
                                      4),
        }

    # -- sampling: seeded top-k/top-p throughput + reproducibility --------
    n_req = 32 if smoke else 96
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=11)

    def run_timed(sampling):
        def trace():
            return burst_trace(n_req, prompt_len=prompt_len,
                               vocab_size=cfg.vocab_size, gen_len=gen,
                               sampling=sampling, seed=3)

        eng = mk_engine(num_slots=4)
        # warm with the exact timed workload (see the warmup registry note
        # above _register_warmup) so EVERY step shape compiles outside the
        # timed window
        run_to_completion(eng, trace(), dt=1e-4)
        _register_warmup("sampling", trace())
        eng.metrics = ServingMetrics(window_s=1e9)
        eng.completed.clear()
        timed = trace()
        _assert_warmed("sampling", timed)
        c0 = eng.clock.now()
        t0 = time.perf_counter()
        out = run_to_completion(eng, timed, dt=1e-4)
        wall = time.perf_counter() - t0
        sim = eng.clock.now() - c0
        toks = sum(len(t) for t in out.values())
        return out, round(toks / wall, 1), {
            "ms_per_token_wall": round(wall / max(toks, 1) * 1e3, 4),
            "ms_per_token_sim": round(sim / max(toks, 1) * 1e3, 4),
        }

    out_a, tps_sampled, ms_sampled = run_timed(sp)
    out_b, _, _ = run_timed(sp)
    _, tps_greedy, ms_greedy = run_timed(None)

    report = {
        "scheduling": {**sched,
                       "tight_deadline_s": round(tight_deadline, 3),
                       "edf_beats_fifo": sched["edf"]["miss_rate"]
                       < sched["fifo"]["miss_rate"]},
        "sampling": {"params": {"temperature": sp.temperature,
                                "top_k": sp.top_k, "top_p": sp.top_p},
                     "requests": n_req,
                     "tokens_per_s_wall": tps_sampled,
                     "greedy_tokens_per_s_wall": tps_greedy,
                     **ms_sampled,
                     "greedy_ms_per_token_wall":
                         ms_greedy["ms_per_token_wall"],
                     "greedy_ms_per_token_sim":
                         ms_greedy["ms_per_token_sim"],
                     # the CI floor is this ratio (machine-speed-proof):
                     # the fused mask+Gumbel must not tank decode rate
                     "sampled_vs_greedy": round(tps_sampled
                                                / max(tps_greedy, 1e-9), 3),
                     "reproducible": out_a == out_b},
    }
    _merge_bench_report(report)
    return [
        ("serve_sched_miss_rate_edf", sched["edf"]["miss_rate"],
         f"fifo={sched['fifo']['miss_rate']} "
         f"(deadline {tight_deadline:.2f}s)"),
        ("serve_sampled_tokens_per_s", tps_sampled,
         f"greedy={tps_greedy} reproducible="
         f"{report['sampling']['reproducible']}"),
    ]


def bench_serve_sampling_full():
    return bench_serve_sampling(smoke=False)


# -- prefix caching: copy-on-write shared prompt blocks -------------------------
#
# The claim recorded per commit (merged into BENCH_serve.json): on a
# shared-system-prompt trace at an *equal* KV block budget, prefix caching
# cuts the prefill tokens actually computed by >= 2x and improves TTFT p95
# (simulated time: fewer lane steps before a first token, and the queue
# drains faster), while output stays token-exact vs --prefix-cache off for
# both greedy and seeded sampling. Everything asserted is sim-time /
# token-count deterministic, so the CI floors are machine-speed-proof.


def bench_serve_prefix(smoke: bool = True):
    from repro.launch.serve import serve_batch
    from repro.models import model as Mo
    from repro.models.env import Env
    from repro.serve import (SERVE_PLAN, SamplingParams, ServingEngine,
                             ServingMetrics, run_to_completion,
                             sysprompt_trace)

    cfg = get_smoke("paper-demo")
    params = Mo.init_params(jax.random.PRNGKey(0), cfg,
                            Env(mesh=None, plan=SERVE_PLAN))
    prompt_len, gen, bs = 16, 8, 4
    prefix_len = 12  # 3 full blocks of shared system prompt per request
    n_req = 24 if smoke else 64

    def mk_trace(sampling=None):
        return sysprompt_trace(n_req, 64.0, prompt_len=prompt_len,
                               vocab_size=cfg.vocab_size,
                               prefix_len=prefix_len, gen_len=gen,
                               sampling=sampling, seed=0)

    def run(prefix_cache, sampling=None):
        def mk_engine():
            return ServingEngine(cfg, params, num_slots=4,
                                 prompt_len=prompt_len, max_gen=gen,
                                 block_size=bs, prefix_cache=prefix_cache)

        # warm a THROWAWAY engine with the exact timed workload (warmup
        # registry note above _register_warmup): compilation lives in the
        # shared jit cache and survives the engine, while the timed engine
        # below starts with a cold prefix cache — hit rates and prefill
        # reductions keep their cold-trace semantics. Same dt as the timed
        # run so the schedule (and thus every jitted shape) is identical.
        run_to_completion(mk_engine(), mk_trace(sampling), dt=0.05)
        _register_warmup("prefix", mk_trace(sampling))
        eng = mk_engine()
        eng.metrics = ServingMetrics(window_s=1e9)
        peak_shared = [0.0]  # actively-shared occupancy decays by drain
        timed = mk_trace(sampling)
        _assert_warmed("prefix", timed)
        t0 = time.perf_counter()
        out = run_to_completion(
            eng, timed, dt=0.05,
            on_step=lambda i, s: peak_shared.__setitem__(
                0, max(peak_shared[0], s.get("kv_shared_occupancy", 0.0))))
        wall = time.perf_counter() - t0
        snap = eng.snapshot()
        snap["kv_shared_occupancy"] = peak_shared[0]
        n_tok = sum(len(t) for t in out.values())
        snap["ms_per_token_wall"] = round(wall / max(n_tok, 1) * 1e3, 4)
        snap["ms_per_token_sim"] = round(
            eng.clock.now() / max(n_tok, 1) * 1e3, 4)
        return out, snap

    out_on, snap_on = run(True)
    out_off, snap_off = run(False)
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=17)
    sam_on, _ = run(True, sampling=sp)
    sam_off, _ = run(False, sampling=sp)

    # absolute anchor: the cache-off greedy stream matches the one-shot
    # streamed-prefill baseline (the chunked-prefill fp path)
    trace = mk_trace()
    prompts = jnp.asarray(np.stack([r.prompt for r in trace]))
    base = np.asarray(serve_batch(None, cfg, params, prompts, gen,
                                  SERVE_PLAN, streamed_prefill=True))
    base_exact = all(np.array_equal(base[r.rid][:r.eff_gen_len],
                                    np.array(out_off[r.rid]))
                     for r in trace)

    reduction = snap_off["prefill_tokens"] / max(snap_on["prefill_tokens"], 1)
    report = {
        "prefix": {
            "requests": n_req, "prompt_len": prompt_len,
            "prefix_len": prefix_len, "block_size": bs,
            "prefill_tokens_on": snap_on["prefill_tokens"],
            "prefill_tokens_off": snap_off["prefill_tokens"],
            "prefill_reduction": round(reduction, 2),
            "prefix_hit_rate": round(snap_on["prefix_hit_rate"], 3),
            "kv_shared_occupancy": round(snap_on["kv_shared_occupancy"], 3),
            "ttft_p95_ms_on": round(snap_on.get("ttft_p95_ms", 0.0), 2),
            "ttft_p95_ms_off": round(snap_off.get("ttft_p95_ms", 0.0), 2),
            "ms_per_token_wall_on": snap_on["ms_per_token_wall"],
            "ms_per_token_wall_off": snap_off["ms_per_token_wall"],
            "ms_per_token_sim_on": snap_on["ms_per_token_sim"],
            "ms_per_token_sim_off": snap_off["ms_per_token_sim"],
            "token_exact": bool(out_on == out_off and base_exact),
            "sampled_exact": bool(sam_on == sam_off),
        }
    }
    _merge_bench_report(report)
    px = report["prefix"]
    return [
        ("serve_prefix_prefill_reduction", px["prefill_reduction"],
         f"hit_rate={px['prefix_hit_rate']} exact={px['token_exact']} "
         f"sampled_exact={px['sampled_exact']}"),
        ("serve_prefix_ttft_p95_ms", px["ttft_p95_ms_on"],
         f"off={px['ttft_p95_ms_off']} (sim)"),
    ]


def bench_serve_prefix_full():
    return bench_serve_prefix(smoke=False)


def dataclasses_replace(r):
    """Fresh Request for a second engine run (engines mutate requests)."""
    import dataclasses
    return dataclasses.replace(r, tokens=[], t_admit=None,
                               t_first_token=None, t_done=None)


# -- multi-replica data plane: router + per-replica KV -------------------------
#
# Three claims recorded per commit (merged into BENCH_serve.json):
#   scale-out: at an EQUAL TOTAL KV byte budget and equal per-node compute
#     (slots = a node's decode lanes), 4 replicas beat 1 on decode
#     tokens/s — the queue drains through 4 fused steps per sim tick
#     instead of 1. This is the speedup the autoscaler's ScalePlans now
#     actually buy (before the router, scaling only rescaled a simulated
#     dt).
#   routing: on a shared-system-prompt trace, prefix-affine routing keeps
#     each template pinned to one replica's prefix cache and so achieves a
#     strictly higher fleet hit rate than cache-blind least-occupancy
#     routing (which smears every template across every replica's cache,
#     paying the cold miss N times).
#   exactness: per-request output is bit-identical across 1 vs 4 replicas
#     and across both routing policies, greedy and seeded — the router
#     moves requests, never tokens.


def bench_serve_replicas(smoke: bool = True):
    from repro.models import model as Mo
    from repro.models.env import Env
    from repro.serve import (SERVE_PLAN, ReplicaSet, ServingEngine,
                             SamplingParams, ServingMetrics,
                             run_to_completion, sysprompt_trace)

    cfg = get_smoke("paper-demo")
    params = Mo.init_params(jax.random.PRNGKey(0), cfg,
                            Env(mesh=None, plan=SERVE_PLAN))
    prompt_len, gen, bs = 16, 8, 4
    prefix_len, n_prefixes = 12, 4  # 3 shared blocks x 4 tenant templates
    n_req = 48 if smoke else 96
    n_replicas, slots = 4, 4
    # equal total KV bytes: per-replica worst case is slots * blocks_for
    # (6 blocks each at these shapes); the single engine gets the whole
    # fleet budget in one pool (+1 null block per pool is the only skew,
    # and it favors the single engine)
    per_replica_usable = slots * 6
    fleet_total = n_replicas * per_replica_usable

    def mk_trace(sampling=None):
        return sysprompt_trace(n_req, 64.0, prompt_len=prompt_len,
                               vocab_size=cfg.vocab_size,
                               prefix_len=prefix_len, gen_len=gen,
                               n_prefixes=n_prefixes, sampling=sampling,
                               seed=0)

    def run(mk_engine, sampling=None):
        # throwaway-engine warm-up with the exact timed workload (warmup
        # registry note above _register_warmup): jits are shared, cache
        # and routing state start cold for the timed engine. Same dt as
        # the timed run so every jitted shape matches.
        run_to_completion(mk_engine(), mk_trace(sampling), dt=0.05)
        _register_warmup("replicas", mk_trace(sampling))
        engine = mk_engine()
        if hasattr(engine, "replicas"):
            for r in engine.replicas:
                r.metrics = ServingMetrics(window_s=1e9)
        else:
            engine.metrics = ServingMetrics(window_s=1e9)
        timed = mk_trace(sampling)
        _assert_warmed("replicas", timed)
        t0 = time.perf_counter()
        out = run_to_completion(engine, timed, dt=0.05)
        wall = time.perf_counter() - t0
        snap = engine.snapshot()
        n_tok = sum(len(t) for t in out.values())
        snap["tokens_per_s_sim"] = n_tok / max(engine.clock.now(), 1e-9)
        snap["ms_per_token_wall"] = round(wall / max(n_tok, 1) * 1e3, 4)
        snap["ms_per_token_sim"] = round(
            engine.clock.now() / max(n_tok, 1) * 1e3, 4)
        return out, snap

    def single(**kw):
        return ServingEngine(cfg, params, num_slots=slots,
                             prompt_len=prompt_len, max_gen=gen,
                             block_size=bs, kv_blocks=fleet_total + 1, **kw)

    def fleet(routing, **kw):
        return ReplicaSet(cfg, params, replicas=n_replicas, routing=routing,
                          num_slots=slots, prompt_len=prompt_len,
                          max_gen=gen, block_size=bs,
                          kv_blocks=per_replica_usable + 1, **kw)

    out_1, snap_1 = run(single)
    out_aff, snap_aff = run(lambda: fleet("prefix"))
    out_occ, snap_occ = run(lambda: fleet("occupancy"))
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=17)
    sam_1, _ = run(single, sampling=sp)
    sam_aff, _ = run(lambda: fleet("prefix"), sampling=sp)

    speedup = (snap_aff["tokens_per_s_sim"]
               / max(snap_1["tokens_per_s_sim"], 1e-9))
    report = {
        "replicas": {
            "requests": n_req, "replicas": n_replicas,
            "slots_per_replica": slots,
            "kv_blocks_total": fleet_total,
            "prefix_len": prefix_len, "n_prefixes": n_prefixes,
            "tokens_per_s_1": round(snap_1["tokens_per_s_sim"], 2),
            "tokens_per_s_4": round(snap_aff["tokens_per_s_sim"], 2),
            "speedup_tokens_per_s": round(speedup, 2),
            "ttft_p95_ms_1": round(snap_1.get("ttft_p95_ms", 0.0), 2),
            "ttft_p95_ms_4": round(snap_aff.get("ttft_p95_ms", 0.0), 2),
            "ms_per_token_wall_1": snap_1["ms_per_token_wall"],
            "ms_per_token_wall_4": snap_aff["ms_per_token_wall"],
            "ms_per_token_sim_1": snap_1["ms_per_token_sim"],
            "ms_per_token_sim_4": snap_aff["ms_per_token_sim"],
            "affine_hit_rate": round(snap_aff["prefix_hit_rate"], 3),
            "occupancy_hit_rate": round(snap_occ["prefix_hit_rate"], 3),
            "token_exact": bool(out_aff == out_1 and out_occ == out_1),
            "sampled_exact": bool(sam_aff == sam_1),
        }
    }
    _merge_bench_report(report)
    rp = report["replicas"]
    return [
        ("serve_replicas_speedup", rp["speedup_tokens_per_s"],
         f"4x{slots} slots vs 1x{slots} at {fleet_total} blocks "
         f"exact={rp['token_exact']} sampled_exact={rp['sampled_exact']}"),
        ("serve_replicas_hit_rate", rp["affine_hit_rate"],
         f"prefix-affine vs occupancy={rp['occupancy_hit_rate']} (sim)"),
    ]


def bench_serve_replicas_full():
    return bench_serve_replicas(smoke=False)


# -- speculative decoding: draft/verify lanes on the fused step ------------------
#
# Claims recorded per commit (merged into BENCH_serve.json): on the
# repetitive-suffix trace family the ngram drafter (prompt-lookup, k=4)
# delivers >= 1.5x decode tokens/s in SIM time — the ratio is a pure
# decode-step count, machine-speed-proof — and strictly lower ms/token at
# EQUAL KV bytes (speculation allocates no extra KV: verify rows write
# into the request's own block reservation and roll back via
# KVBackend.truncate), with accepted_per_step > 1.0 and output bit-exact
# vs non-speculative serving, greedy and seeded.


def bench_serve_spec(smoke: bool = True):
    from repro.models import model as Mo
    from repro.models.env import Env
    from repro.serve import (SERVE_PLAN, SamplingParams, ServingEngine,
                             ServingMetrics, repetitive_trace,
                             run_to_completion)

    cfg = get_smoke("paper-demo")
    params = Mo.init_params(jax.random.PRNGKey(0), cfg,
                            Env(mesh=None, plan=SERVE_PLAN))
    prompt_len, gen, bs, spec_k = 16, 64, 4, 4
    n_req = 24 if smoke else 48
    num_slots = 3
    # both engines get the identical pool: speculation needs no extra KV
    kv_blocks = num_slots * ((prompt_len + gen) // bs) + 1

    def mk_trace(sampling=None):
        return repetitive_trace(n_req, 64.0, prompt_len=prompt_len,
                                vocab_size=cfg.vocab_size, gen_len=gen,
                                sampling=sampling, seed=0)

    def run(spec, sampling=None):
        def mk_engine():
            return ServingEngine(cfg, params, num_slots=num_slots,
                                 prompt_len=prompt_len, max_gen=gen,
                                 kv="paged", block_size=bs,
                                 kv_blocks=kv_blocks, spec=spec,
                                 spec_k=spec_k)

        # throwaway-engine warm-up with the exact timed workload at the
        # timed dt (warmup registry note above _register_warmup)
        run_to_completion(mk_engine(), mk_trace(sampling), dt=0.05)
        _register_warmup("spec", mk_trace(sampling))
        eng = mk_engine()
        eng.metrics = ServingMetrics(window_s=1e9)
        timed = mk_trace(sampling)
        _assert_warmed("spec", timed)
        t0 = time.perf_counter()
        out = run_to_completion(eng, timed, dt=0.05)
        wall = time.perf_counter() - t0
        snap = eng.snapshot()
        n_tok = sum(len(t) for t in out.values())
        sim = eng.clock.now()
        res = {
            "tokens": n_tok,
            "decode_steps": eng.decode_steps,
            "tokens_per_s_sim": round(n_tok / max(sim, 1e-9), 2),
            "ms_per_token_sim": round(sim / max(n_tok, 1) * 1e3, 4),
            "ms_per_token_wall": round(wall / max(n_tok, 1) * 1e3, 4),
            "kv_bytes": _cache_bytes(eng.pool.caches),
        }
        if "accepted_per_step" in snap:
            res["accepted_per_step"] = round(snap["accepted_per_step"], 3)
            res["spec_acceptance_rate"] = round(
                snap["spec_acceptance_rate"], 3)
        return out, res

    out_base, base = run(None)
    out_ngram, ngram = run("ngram")
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=17)
    sam_base, _ = run(None, sampling=sp)
    sam_ngram, _ = run("ngram", sampling=sp)

    report = {
        "spec": {
            "requests": n_req, "prompt_len": prompt_len, "gen_len": gen,
            "drafter": "ngram", "spec_k": spec_k,
            "baseline": base,
            "ngram": ngram,
            # decode-step ratio: machine-speed-proof (same dt both runs)
            "speedup_decode_tokens_per_s": round(
                ngram["tokens_per_s_sim"]
                / max(base["tokens_per_s_sim"], 1e-9), 3),
            "kv_bytes_equal": bool(base["kv_bytes"] == ngram["kv_bytes"]),
            "accepted_per_step": ngram.get("accepted_per_step", 0.0),
            "spec_acceptance_rate": ngram.get("spec_acceptance_rate", 0.0),
            "token_exact": bool(out_ngram == out_base),
            "sampled_exact": bool(sam_ngram == sam_base),
        }
    }
    if not smoke:
        # the model drafter is simulation-grade (per-token host sync) —
        # record its acceptance on the full tier only
        out_model, model = run("model")
        report["spec"]["model"] = model
        report["spec"]["model_token_exact"] = bool(out_model == out_base)
    _merge_bench_report(report)
    spx = report["spec"]
    return [
        ("serve_spec_speedup", spx["speedup_decode_tokens_per_s"],
         f"ngram k={spec_k} accepted/step={spx['accepted_per_step']} "
         f"exact={spx['token_exact']} sampled_exact={spx['sampled_exact']}"),
        ("serve_spec_ms_per_token_sim", spx["ngram"]["ms_per_token_sim"],
         f"baseline={spx['baseline']['ms_per_token_sim']} at equal KV "
         f"({spx['kv_bytes_equal']})"),
    ]


def bench_serve_spec_full():
    return bench_serve_spec(smoke=False)


# -- tiered KV: int8 quant backend + host swap-out preemption -------------------
#
# Two claims recorded per commit (merged into BENCH_serve.json):
#   capacity: at EQUAL pool bytes the int8 backend admits >= 1.8x the
#     peak concurrency of the bf16 paged backend (per-block ratio is
#     (hd + 4) / (2 * hd), so head_dim=64 -> 1.88x more blocks), with
#     ms/token for slot / paged / quant on the same burst recorded.
#   swap: a preemption with the host tier on resumes with
#     recomputed_tokens == 0 where the restart path replays the victim's
#     prompt + generated prefix — same tokens either way.


def bench_serve_tiered(smoke: bool = True):
    import dataclasses

    from repro.models import model as Mo
    from repro.models.env import Env
    from repro.serve import (SERVE_PLAN, EDFPolicy, SamplingParams,
                             ServingEngine, burst_trace, run_to_completion)

    # the smoke arch's head_dim=16 would only buy (16+4)/32 = 1.6x blocks
    # — below the paper-scale claim. head_dim=64 (the full paper-demo
    # width) gives the per-block byte ratio the tier actually ships with.
    cfg = dataclasses.replace(get_smoke("paper-demo"),
                              name="paper-demo-tiered", head_dim=64)
    params = Mo.init_params(jax.random.PRNGKey(0), cfg,
                            Env(mesh=None, plan=SERVE_PLAN))
    prompt_len, max_gen, bs = 16, 16, 8
    n_req = 48 if smoke else 96
    blocks_per_req = (prompt_len + max_gen) // bs  # 4
    fp_blocks = 21  # incl. null: 20 usable -> 5 concurrent requests
    # equal device bytes: quant blocks cost (hd+4)/(2*hd) of bf16 blocks
    quant_blocks = int(fp_blocks * 2 * cfg.head_dim // (cfg.head_dim + 4))
    slot_slots = fp_blocks * bs // (prompt_len + max_gen)  # same token budget
    trace = burst_trace(n_req, prompt_len=prompt_len,
                        vocab_size=cfg.vocab_size, gen_len=max_gen, seed=0)
    mk_trace = lambda: [dataclasses_replace(r) for r in trace]

    def mk(kv, **kw):
        return ServingEngine(cfg, params, prompt_len=prompt_len,
                             max_gen=max_gen, kv=kv, **kw)

    res = {}
    res["slot"] = _serve_engine_bench(
        mk("slot", num_slots=slot_slots), mk_trace,
        baseline_streamed=False, section="tiered")
    res["paged"] = _serve_engine_bench(
        mk("paged", num_slots=12, block_size=bs, kv_blocks=fp_blocks),
        mk_trace, baseline_streamed=True, section="tiered")
    res["quant"] = _serve_engine_bench(
        mk("quant", num_slots=12, block_size=bs, kv_blocks=quant_blocks),
        mk_trace, baseline_streamed=True, section="tiered")
    bytes_ratio = res["quant"]["kv_bytes"] / max(res["paged"]["kv_bytes"], 1)
    assert bytes_ratio <= 1.01, \
        f"quant pool must fit the fp byte budget, got {bytes_ratio}"
    conc_ratio = (res["quant"]["peak_concurrent"]
                  / max(res["paged"]["peak_concurrent"], 1))

    # swap vs restart: EDF preempts a deadline-free runner for an urgent
    # arrival; with the host tier on, the victim resumes where it stopped
    sp = SamplingParams(temperature=0.9, top_k=40, top_p=0.95, seed=7)

    def preempt_run(swap):
        # prefix_cache off so the restart path's recompute bill is not
        # masked by warm prompt blocks — the delta isolates the host tier
        eng = mk("paged", num_slots=1, block_size=bs, kv_blocks=fp_blocks,
                 policy=EDFPolicy(preemptive=True, min_slack_s=1.0),
                 swap=swap, prefix_cache=False)
        reqs = burst_trace(2, prompt_len=prompt_len,
                           vocab_size=cfg.vocab_size, gen_len=8, seed=1)
        reqs[0] = dataclasses.replace(reqs[0], sampling=sp)
        reqs[1] = dataclasses.replace(reqs[1], gen_len=2, arrival_t=0.12,
                                      deadline_s=0.4)
        out = run_to_completion(eng, reqs, dt=0.05)
        snap = eng.snapshot()
        return out, {
            "preemptions": int(snap["preemptions"]),
            "recomputed_tokens": int(snap["recomputed_tokens"]),
            "swapped_blocks": int(snap.get("swapped_blocks", 0)),
            "swap_in_bytes": int(snap.get("swap_in_bytes", 0)),
        }

    out_restart, restart = preempt_run(swap=False)
    out_swap, swap = preempt_run(swap=True)

    div_eng = mk("quant", num_slots=2, block_size=bs,
                 kv_blocks=quant_blocks)
    kv_quant_div = div_eng.pool.metrics()["kv_quant_divergence"]
    div_eng.replica.release()

    report = {
        "tiered": {
            "config": {"arch": cfg.name, "head_dim": cfg.head_dim,
                       "prompt_len": prompt_len, "max_gen": max_gen,
                       "block_size": bs, "requests": n_req,
                       "fp_kv_blocks": fp_blocks,
                       "quant_kv_blocks": quant_blocks,
                       "blocks_per_request": blocks_per_req},
            "slot": res["slot"],
            "paged": res["paged"],
            "quant": res["quant"],
            "kv_bytes_ratio_quant_vs_fp": round(bytes_ratio, 4),
            "quant_concurrency_ratio": round(conc_ratio, 3),
            "kv_quant_divergence": round(kv_quant_div, 5),
            "swap": {
                "restart": restart,
                "swap": swap,
                "tokens_identical": bool(out_restart == out_swap),
                "recomputed_tokens_saved":
                    restart["recomputed_tokens"] - swap["recomputed_tokens"],
            },
        }
    }
    _merge_bench_report(report)
    t = report["tiered"]
    return [
        ("serve_tiered_concurrency_ratio", t["quant_concurrency_ratio"],
         f"quant={res['quant']['peak_concurrent']} "
         f"fp={res['paged']['peak_concurrent']} at "
         f"{t['kv_bytes_ratio_quant_vs_fp']}x kv bytes "
         f"divergence={t['kv_quant_divergence']}"),
        ("serve_tiered_ms_per_token_wall",
         res["quant"]["ms_per_token_wall"],
         f"paged={res['paged']['ms_per_token_wall']} "
         f"slot={res['slot']['ms_per_token_wall']}"),
        ("serve_tiered_swap_recompute", swap["recomputed_tokens"],
         f"restart={restart['recomputed_tokens']} "
         f"swapped_blocks={swap['swapped_blocks']} "
         f"identical={t['swap']['tokens_identical']}"),
    ]


def bench_serve_tiered_full():
    return bench_serve_tiered(smoke=False)


# -- rollout generation (rollout/engine.py over the serving plane) -----------
# Claims recorded per commit (merged into BENCH_serve.json):
#   (1) driving the fleet as a rollout generator costs ~nothing over plain
#       serving at an equal KV budget — sim tokens/s ratio >= 0.8 (the
#       rollout engine adds request fan-out + harvest, no decode work);
#   (2) the multi-turn rollout trace (completions re-entering as follow_up
#       requests with grown shared prefixes) out-dedups the static
#       sysprompt trace: fleet prefix hit rate strictly above the
#       sysprompt baseline at the same engine shape and request volume;
#   (3) seeded rollouts are bit-reproducible across fleet shapes: the
#       same prompt set on 2 replicas x 4 slots and on 1 replica x 2
#       slots emits identical tokens per (prompt, sample, turn).
# Everything is sim-time / token-count deterministic (no wall keys, so no
# warmup registration) — the CI floors are machine-speed-proof.


def bench_serve_rollout(smoke: bool = True):
    from repro.core.clock import ManualClock
    from repro.models import model as Mo
    from repro.models.env import Env
    from repro.rollout import RolloutEngine, rollout_signature
    from repro.serve import (SERVE_PLAN, SamplingParams, burst_trace,
                             make_scheduler_policy, make_serving_engine,
                             run_to_completion, sysprompt_trace)

    cfg = get_smoke("paper-demo")
    params = Mo.init_params(jax.random.PRNGKey(0), cfg,
                            Env(mesh=None, plan=SERVE_PLAN))
    base_len, gen, bs = 16, 8, 4
    turns = 4
    n_prompts = 2 if smoke else 4
    n_samples = 4
    plen = base_len + (turns - 1) * gen  # final-turn context budget
    kv_blocks = 160 if smoke else 320  # roomy pool: prefix chains survive
    sampling = SamplingParams(temperature=0.7, seed=0)

    def mk_engine(replicas=1, slots=2, prompt_len=plen):
        return make_serving_engine(
            cfg, params, replicas=replicas, routing="prefix",
            num_slots=slots, prompt_len=prompt_len, max_gen=gen,
            kv="paged", block_size=bs, kv_blocks=kv_blocks,
            prefix_cache=True, policy=make_scheduler_policy("fifo"),
            clock=ManualClock())

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(base_len,),
                            dtype=np.int32) for _ in range(n_prompts)]

    # (1) single-turn rollout generation vs plain serving of the same
    # burst at the same engine shape (equal KV bytes by construction)
    eng_r = mk_engine(prompt_len=base_len)
    ro = RolloutEngine(eng_r, n_samples=n_samples, gen_len=gen,
                       sampling=sampling)
    rollouts_1t = ro.generate(prompts, dt=0.05, turns=1)
    r_tok = sum(len(r.tokens) for r in rollouts_1t)
    r_tps = r_tok / max(eng_r.clock.now(), 1e-9)
    kv_bytes_rollout = _cache_bytes(
        eng_r.pool.caches if hasattr(eng_r, "pool")
        else eng_r.replicas[0].pool.caches)

    eng_s = mk_engine(prompt_len=base_len)
    trace = burst_trace(n_prompts * n_samples, prompt_len=base_len,
                        vocab_size=cfg.vocab_size, gen_len=gen,
                        sampling=sampling, seed=0)
    out = run_to_completion(eng_s, trace, dt=0.05)
    s_tok = sum(len(t) for t in out.values())
    s_tps = s_tok / max(eng_s.clock.now(), 1e-9)
    ratio = r_tps / max(s_tps, 1e-9)

    # (2) multi-turn re-entrant trace vs the sysprompt baseline: same
    # engine shape, same request volume, same per-request gen budget
    eng_mt = mk_engine()
    ro_mt = RolloutEngine(eng_mt, n_samples=n_samples, gen_len=gen,
                          sampling=sampling)
    rollouts_mt = ro_mt.generate(prompts, dt=0.05, turns=turns)
    mt = eng_mt.snapshot()
    n_req = n_prompts * n_samples * turns
    eng_sys = mk_engine()
    sys_trace = sysprompt_trace(n_req, 8.0, prompt_len=plen,
                                vocab_size=cfg.vocab_size,
                                prefix_len=3 * plen // 4, gen_len=gen,
                                sampling=sampling, seed=0)
    run_to_completion(eng_sys, sys_trace, dt=0.05)
    sysr = eng_sys.snapshot()

    # (3) reproducibility across fleet shapes (multi-turn, the hard case:
    # follow_up arrival times depend on fleet scheduling)
    eng_a = mk_engine(replicas=2, slots=4)
    sig_a = rollout_signature(
        RolloutEngine(eng_a, n_samples=n_samples, gen_len=gen,
                      sampling=sampling).generate(prompts, dt=0.05,
                                                  turns=turns))
    sig_b = rollout_signature(rollouts_mt)  # 1 replica x 2 slots above
    reproducible = sig_a == sig_b

    report = {
        "rollout": {
            "prompts": n_prompts, "n_samples": n_samples, "turns": turns,
            "gen_len": gen, "block_size": bs, "kv_blocks": kv_blocks,
            "rollout_tokens": r_tok,
            "tokens_per_s_sim": round(r_tps, 2),
            "serve_tokens_per_s_sim": round(s_tps, 2),
            "throughput_ratio": round(ratio, 3),
            "kv_bytes": kv_bytes_rollout,
            "multiturn_rollouts": len(rollouts_mt),
            "multiturn_hit_rate": round(mt["prefix_hit_rate"], 3),
            "sysprompt_hit_rate": round(sysr["prefix_hit_rate"], 3),
            "multiturn_prefill_tokens": mt["prefill_tokens"],
            "reproducible": bool(reproducible),
        }
    }
    _merge_bench_report(report)
    rx = report["rollout"]
    return [
        ("serve_rollout_throughput_ratio", rx["throughput_ratio"],
         f"rollout={rx['tokens_per_s_sim']} serve="
         f"{rx['serve_tokens_per_s_sim']} tok/s (sim) at equal KV"),
        ("serve_rollout_multiturn_hit_rate", rx["multiturn_hit_rate"],
         f"sysprompt_baseline={rx['sysprompt_hit_rate']} "
         f"reproducible={rx['reproducible']}"),
    ]


def bench_serve_rollout_full():
    return bench_serve_rollout(smoke=False)


# -- per-arch smoke step times (throughput harness) -------------------------------


def bench_step_time():
    rows = []
    for arch in ("yi-9b", "grok-1-314b", "recurrentgemma-9b", "rwkv6-1.6b"):
        cfg = get_smoke(arch)
        from repro.models import model as Mo
        from repro.models.env import Env
        from repro.launch import steps as St
        from repro.optim import AdamWConfig, adamw_init
        env = Env(None, PLAN)
        rng = jax.random.PRNGKey(0)
        p = Mo.init_params(rng, cfg, env)
        opt = AdamWConfig()
        state = {"params": p, "opt": adamw_init(p, opt)}
        tokens = jax.random.randint(rng, (4, 32), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        step = jax.jit(St.make_train_step(cfg, env, opt))
        state, m = step(state, batch)  # compile
        us = _t(lambda: jax.block_until_ready(step(state, batch)), n=3)
        toks = tokens.size
        rows.append((f"step_{arch}", round(us, 1),
                     f"{toks/(us/1e6):.0f} tok/s (smoke,cpu)"))
    return rows
