#!/usr/bin/env bash
# Tier-1 CI: full test suite + benchmark smoke subset + the closed-loop
# serving demo with token verification. Run from the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint (ruff, if installed) =="
if command -v ruff >/dev/null 2>&1; then
  ruff check src tests benchmarks tools examples
else
  echo "ruff not installed; skipping (the GitHub workflow runs it)"
fi

echo "== replint (determinism / compile-once / protocol contracts) =="
# stdlib-only, runs in seconds — a contract break fails here, before
# pytest spends minutes. Fails on any unsuppressed finding; the JSON
# report is kept as a build artifact (docs/analysis.md).
python -m repro.analysis.lint src --format json --output replint.json \
  || { python -m repro.analysis.lint src; exit 1; }
python -m repro.analysis.lint src

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke =="
python benchmarks/run.py --smoke

echo "== serving perf record (BENCH_serve.json: paged vs slot KV) =="
python - <<'PY'
import json
r = json.load(open("BENCH_serve.json"))
print(json.dumps(r, indent=2))
assert r["token_exact"], "paged serving lost greedy token-exactness"
assert r["kv_bytes_ratio"] <= 1.01, "paged ran with a bigger KV budget"
# perf trajectory floors — the ISSUE-2 acceptance bar (CPU smoke,
# best-of-N timed; TPU runs the Pallas paged kernel)
assert r["speedup_tokens_per_s"] >= 1.5, r["speedup_tokens_per_s"]
assert r["concurrency_ratio"] >= 2.0, r["concurrency_ratio"]
# serving API v2 floors (ISSUE-3): EDF must beat FIFO on deadline-miss
# rate, and seeded sampling must stay reproducible at a sane rate
# (ratio floor, like speedup/concurrency above — machine-speed-proof)
s = r["scheduling"]
assert s["edf"]["miss_rate"] < s["fifo"]["miss_rate"], s
assert s["edf"]["miss_rate"] == 0.0, s
sam = r["sampling"]
assert sam["reproducible"], "seeded sampling output drifted between runs"
assert sam["sampled_vs_greedy"] >= 0.25, sam
# prefix-cache floors (ISSUE-4): on a shared-system-prompt trace at an
# equal KV budget, caching must cut prefill compute >= 2x and improve
# TTFT p95 while staying token-exact vs cache-off (greedy and seeded) —
# all sim-time deterministic, machine-speed-proof
px = r["prefix"]
assert px["token_exact"], "prefix caching lost greedy token-exactness"
assert px["sampled_exact"], "prefix caching perturbed seeded sampling"
assert px["prefill_reduction"] >= 2.0, px
assert px["prefix_hit_rate"] >= 0.5, px
assert px["ttft_p95_ms_on"] < px["ttft_p95_ms_off"], px
# multi-replica floors (ISSUE-5): at an equal total KV byte budget the
# 4-replica router must beat the single engine on decode tokens/s (the
# data-parallel speedup is real now, not a dt rescale), prefix-affine
# routing must beat cache-blind occupancy routing on fleet hit rate, and
# the router must never perturb tokens — all sim-time deterministic
rp = r["replicas"]
assert rp["token_exact"], "the router perturbed greedy tokens"
assert rp["sampled_exact"], "the router perturbed seeded sampling"
assert rp["speedup_tokens_per_s"] >= 2.0, rp
assert rp["affine_hit_rate"] > rp["occupancy_hit_rate"], rp
assert rp["ttft_p95_ms_4"] < rp["ttft_p95_ms_1"], rp
# speculative-decoding floors (ISSUE-6): ngram drafting (k>=3) on the
# repetitive-suffix trace must emit >1 token per decode step, reach
# >=1.5x decode tokens/s AND strictly lower ms/token than the
# non-speculative baseline at an equal KV byte budget, while staying
# bit-identical to --spec off (greedy and seeded) — sim-time ratios,
# machine-speed-proof
sx = r["spec"]
assert sx["token_exact"], "speculation perturbed greedy tokens"
assert sx["sampled_exact"], "speculation perturbed seeded sampling"
assert sx["kv_bytes_equal"], "spec ran with a different KV budget"
assert sx["spec_k"] >= 3, sx
assert sx["speedup_decode_tokens_per_s"] >= 1.5, sx
assert sx["accepted_per_step"] > 1.0, sx
assert sx["spec_acceptance_rate"] > 0.0, sx
assert (sx["ngram"]["ms_per_token_sim"]
        < sx["baseline"]["ms_per_token_sim"]), sx
# tiered-KV floors (ISSUE-7): at an EQUAL device byte budget the int8
# quant backend must admit >= 1.8x the fp paged peak concurrency with
# its calibrated divergence inside the documented bound, and a swap-out
# preemption must resume with zero recomputed tokens where the restart
# path replays the victim's prompt — same output tokens either way
tx = r["tiered"]
assert tx["kv_bytes_ratio_quant_vs_fp"] <= 1.01, tx
assert tx["quant_concurrency_ratio"] >= 1.8, tx
assert tx["kv_quant_divergence"] < 0.05, tx
assert tx["paged"]["token_exact_vs_one_shot"], \
    "fp paged lost exactness in the tiered bench"
sw = tx["swap"]
assert sw["tokens_identical"], "swap vs restart produced different tokens"
assert sw["swap"]["recomputed_tokens"] == 0, sw
assert sw["restart"]["recomputed_tokens"] > 0, sw
assert sw["swap"]["swapped_blocks"] > 0, sw
# rollout floors (ISSUE-8): driving the fleet as a rollout generator must
# cost ~nothing over plain serving at an equal KV budget, the multi-turn
# re-entrant trace must out-dedup the static sysprompt baseline on fleet
# prefix hit rate, and seeded rollouts must be bit-reproducible across
# fleet shapes — all sim-time deterministic, machine-speed-proof
rx = r["rollout"]
assert rx["reproducible"], "rollouts drifted across fleet shapes"
assert rx["throughput_ratio"] >= 0.8, rx
assert rx["multiturn_hit_rate"] > rx["sysprompt_hit_rate"], rx
assert rx["kv_bytes"] > 0, rx
PY

echo "== serving demo (paged KV + chunked prefill + autoscale + verify) =="
python -m repro.launch.serve --trace poisson --smoke --verify

echo "== serving demo (seeded sampling + EDF + deadlines + verify) =="
python -m repro.launch.serve --trace poisson --smoke --verify \
  --temperature 0.8 --top-k 40 --top-p 0.95 --sched edf --deadline 2.0

echo "== serving demo (shared system prompts + prefix cache + verify) =="
python -m repro.launch.serve --trace sysprompt --smoke --verify \
  --block-size 4

echo "== serving demo (4-replica router + prefix-affine routing + live drain + verify) =="
python -m repro.launch.serve --replicas 4 --routing prefix --smoke --verify

echo "== serving demo (speculative decoding, ngram drafter + verify vs --spec off) =="
python -m repro.launch.serve --spec ngram --smoke --verify

echo "== serving demo (tiered KV: int8 quant + host swap tier + verify) =="
python -m repro.launch.serve --kv quant --swap on --smoke --verify

echo "== rollout demo (generate -> score -> DPO train loop + reproducibility verify) =="
python -m repro.launch.rollout --smoke --verify
