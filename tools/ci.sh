#!/usr/bin/env bash
# Tier-1 CI: full test suite + benchmark smoke subset + the closed-loop
# serving demo with token verification. Run from the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke =="
python benchmarks/run.py --smoke

echo "== serving demo (continuous batching + autoscale + verify) =="
python -m repro.launch.serve --trace poisson --smoke --verify
