"""Regenerate the EXPERIMENTS.md roofline tables from reports/dryrun."""
import json
import os
import sys


def table(d, cols):
    rows = []
    for fn in sorted(os.listdir(d)):
        r = json.load(open(os.path.join(d, fn)))
        rows.append(r)
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant |"
           " useful | fraction | fits 16GB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r.get('useful_flops_ratio', 0):.3f} | "
            f"{r.get('roofline_fraction', 0):.3f} | {m['fits_16GB']} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(table(sys.argv[1], None))
