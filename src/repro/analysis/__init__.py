"""replint — the repo's domain-specific static-analysis pass.

The serving plane's production claims (bit-reproducible rollouts,
fleet-wide compile-once jit, schema'd metrics) are *contracts*, and until
now they were enforced only by runtime tests: a stray `time.time()` in a
sim path or a bare `jax.jit` in a replica constructor ships silently and
only surfaces when a bench floor trips. replint makes the contracts
machine-checked at CI time, before any test runs.

    python -m repro.analysis.lint src            # text report, exit != 0
    python -m repro.analysis.lint src --format json

The engine (analysis/core.py) is stdlib-only — no jax import — so the CI
step fails contract breaks in seconds. Rules live in analysis/rules/ and
register themselves in rules.ALL_RULES; suppressions require a written
reason (`# replint: ignore[R001] -- why`). See docs/analysis.md.
"""
from repro.analysis.core import (Corpus, Finding, LintResult, Rule,
                                 SourceFile, run_lint)
from repro.analysis.rules import ALL_RULES

__all__ = ["ALL_RULES", "Corpus", "Finding", "LintResult", "Rule",
           "SourceFile", "run_lint"]
