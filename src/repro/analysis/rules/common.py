"""Shared AST helpers for replint rules — name resolution, import maps,
set-typedness, and jit-callable tracking. Stdlib-only."""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

# directory scopes: the simulated data plane (determinism contracts apply)
SIM_SCOPES = ("serve", "rollout", "core")
# the serving data plane (compile-once / retrace contracts apply)
DATA_PLANE_SCOPES = ("serve", "rollout")


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_map(tree: ast.Module) -> Dict[str, str]:
    """Local alias -> canonical dotted module/name for every import."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def resolve_call(node: ast.Call, imports: Dict[str, str]) -> Optional[str]:
    """The canonical dotted name a call resolves to, de-aliasing the
    leading segment through the module's imports (np.random.rand ->
    numpy.random.rand; from time import time; time() -> time.time)."""
    dn = dotted_name(node.func)
    if dn is None:
        return None
    head, _, rest = dn.partition(".")
    canon = imports.get(head)
    if canon is not None:
        dn = canon + ("." + rest if rest else "")
    return dn


def walk_functions(tree: ast.Module) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            yield node


def func_params(fn: ast.AST) -> List[str]:
    """Parameter names (self/cls dropped) of a def or lambda."""
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return [n for n in names if n not in ("self", "cls")]


STATIC_ATTRS = ("shape", "dtype", "ndim", "sharding")


def refs_names(node: ast.AST, names: Set[str]) -> bool:
    """Does `node` reference any of `names` OUTSIDE a static-metadata
    attribute access (x.shape / x.dtype / x.ndim are trace-static)?"""

    class V(ast.NodeVisitor):
        hit = False

        def visit_Attribute(self, n: ast.Attribute) -> None:
            if n.attr in STATIC_ATTRS:
                return  # static metadata: don't descend into n.value
            self.generic_visit(n)

        def visit_Name(self, n: ast.Name) -> None:
            if n.id in names:
                self.hit = True

    v = V()
    v.visit(node)
    return v.hit


def is_setlike(node: ast.AST, local_sets: Set[str],
               attr_sets: Set[str]) -> bool:
    """Syntactically set-typed: a set literal / comprehension, a
    set()/frozenset() call, a union/difference/intersection of set-likes,
    or a name (self.attr) the enclosing scope assigned one of those."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return (is_setlike(node.left, local_sets, attr_sets)
                or is_setlike(node.right, local_sets, attr_sets))
    if isinstance(node, ast.Name):
        return node.id in local_sets
    if isinstance(node, ast.Attribute):
        return dotted_name(node) in attr_sets
    return False


def collect_set_bindings(scope: ast.AST) -> Tuple[Set[str], Set[str]]:
    """(local names, self.X dotted names) assigned a set-like value
    anywhere under `scope` (a class body tracks self attrs class-wide)."""
    local: Set[str] = set()
    attrs: Set[str] = set()
    for node in ast.walk(scope):
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        value = node.value
        if value is None or not is_setlike(value, local, attrs):
            continue
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            if isinstance(t, ast.Name):
                local.add(t.id)
            else:
                dn = dotted_name(t)
                if dn is not None and dn.startswith("self."):
                    attrs.add(dn)
    return local, attrs


JIT_FACTORIES = ("jax.jit", "shared_jit", "repro.serve.kv.shared_jit")


def is_jit_factory(node: ast.AST, imports: Dict[str, str]) -> bool:
    """Is `node` a call to jax.jit / the shared_jit registry?"""
    if not isinstance(node, ast.Call):
        return False
    dn = resolve_call(node, imports)
    return dn in JIT_FACTORIES


def collect_jitted_names(tree: ast.Module,
                         imports: Dict[str, str]) -> Set[str]:
    """Names (locals and self attributes, dotted) bound to a jitted
    callable: direct `x = jax.jit(...)` / `self._f = shared_jit(...)`
    assignments, plus dict literals / comprehensions whose VALUES are jit
    factory calls (the dual greedy/sampling step tables)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        v = node.value
        jitted = is_jit_factory(v, imports)
        if isinstance(v, ast.Dict):
            jitted = jitted or any(is_jit_factory(x, imports)
                                   for x in v.values)
        if isinstance(v, ast.DictComp):
            jitted = jitted or is_jit_factory(v.value, imports)
        if not jitted:
            continue
        for t in node.targets:
            dn = dotted_name(t)
            if dn is not None:
                out.add(dn)
    return out
