"""R005 — metric schema: every published or consumed metric key must be
declared in METRIC_SCHEMA.

The metrics plane is stringly-typed end to end: ServingMetrics.snapshot
emits keys, NodeAgent.report_serving namespaces them into the registry
KV, AutoScaler.read_metrics re-aggregates them by name, and the scaling
policies .get() them back out. A typo'd key at ANY of those four hops
doesn't error — the reading side just silently sees nothing, and the
symptom is an autoscaler that stops reacting (a silently-unaggregated
counter looks exactly like an idle fleet). METRIC_SCHEMA
(serve/metrics.py) is the single declared key set; this rule statically
collects every key the plane publishes or consumes and checks membership:

  * string keys of dict literals, `out["key"] = ...` subscript stores,
    for-loop tuple iterables, and .update(key=...) kwargs inside
    functions named snapshot / metrics / metric_sources under serve/ and
    rollout/ (dict-literal keys whose values are themselves dict
    literals are source names, not metrics, and are skipped);
  * string tuples bound to module-level SERVING_* / *_METRICS constants
    (the autoscaler aggregation tables, the rollout phase-metric list);
  * `metrics.get("key")` reads inside decide() / read_metrics() under
    core/.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

from repro.analysis.core import Corpus, Finding, Rule, SourceFile
from repro.analysis.rules import common

PUBLISH_FUNCS = ("snapshot", "metrics", "metric_sources")
CONSUME_FUNCS = ("decide", "read_metrics")
EXEMPT = ("__ts",)


def _schema_keys(corpus: Corpus) -> Tuple[Optional[SourceFile], Set[str]]:
    keys: Set[str] = set()
    where = None
    for sf in corpus:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Assign):
                continue
            if not any(isinstance(t, ast.Name) and t.id == "METRIC_SCHEMA"
                       for t in node.targets):
                continue
            where = where or sf
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Constant) \
                        and isinstance(sub.value, str):
                    keys.add(sub.value)
    return where, keys


def _strings_in(node: ast.AST) -> Iterator[ast.Constant]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            yield sub


class MetricSchemaRule(Rule):
    id = "R005"
    name = "metric-schema"
    doc = ("every key published via ServingMetrics.snapshot/"
           "report_serving and consumed by the autoscaler must appear "
           "in METRIC_SCHEMA")

    def check(self, corpus: Corpus) -> Iterator[Finding]:
        used: List[Tuple[SourceFile, ast.AST, str, str]] = []
        for sf in corpus:
            if sf.in_dirs(common.DATA_PLANE_SCOPES):
                used += [(sf, n, k, "published")
                         for n, k in self._published(sf)]
            if sf.in_dirs(("core",)):
                used += [(sf, n, k, "consumed")
                         for n, k in self._consumed(sf)]
            used += [(sf, n, k, "aggregated")
                     for n, k in self._table_constants(sf)]
        if not used:
            return
        schema_sf, schema = _schema_keys(corpus)
        if schema_sf is None:
            sf, node, _, _ = used[0]
            yield self.finding(
                sf, node,
                "metric keys are published but no METRIC_SCHEMA is "
                "declared anywhere in the scanned tree (declare the "
                "full key set in serve/metrics.py)")
            return
        for sf, node, key, how in used:
            if key in schema or key in EXEMPT:
                continue
            yield self.finding(
                sf, node,
                f"metric key '{key}' is {how} but not declared in "
                f"METRIC_SCHEMA ({schema_sf.relpath}) — an undeclared "
                "key is invisible to the aggregation/tombstone paths")

    # -- collectors --------------------------------------------------------
    def _published(self, sf: SourceFile
                   ) -> Iterator[Tuple[ast.AST, str]]:
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, ast.FunctionDef) \
                    or fn.name not in PUBLISH_FUNCS:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Dict):
                    for k, v in zip(node.keys, node.values):
                        if isinstance(v, ast.Dict):
                            continue  # {source: {…}} nesting: outer key
                        if isinstance(k, ast.Constant) \
                                and isinstance(k.value, str):
                            yield k, k.value
                elif isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Subscript) \
                                and isinstance(t.slice, ast.Constant) \
                                and isinstance(t.slice.value, str):
                            yield t, t.slice.value
                elif isinstance(node, (ast.For, ast.AsyncFor)) \
                        and isinstance(node.iter, (ast.Tuple, ast.List)):
                    for s in _strings_in(node.iter):
                        yield s, s.value
                elif isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "update":
                    for kw in node.keywords:
                        if kw.arg is not None:
                            yield node, kw.arg

    def _consumed(self, sf: SourceFile) -> Iterator[Tuple[ast.AST, str]]:
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, ast.FunctionDef) \
                    or fn.name not in CONSUME_FUNCS:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "get" and node.args \
                        and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str):
                    yield node, node.args[0].value

    def _table_constants(self, sf: SourceFile
                         ) -> Iterator[Tuple[ast.AST, str]]:
        for node in sf.tree.body:
            if not isinstance(node, ast.Assign):
                continue
            names = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if not any(n.isupper() and (n.endswith("_METRICS")
                                        or n.startswith("SERVING_"))
                       for n in names):
                continue
            for s in _strings_in(node.value):
                yield s, s.value
