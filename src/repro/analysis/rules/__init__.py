"""The replint rule registry.

Adding a rule: subclass analysis.core.Rule in a new module here, set
id/name/doc, implement check(corpus), and append the class to ALL_RULES.
Rule ids are stable (suppressions reference them); never reuse one.
"""
from repro.analysis.rules.r001_determinism import DeterminismRule
from repro.analysis.rules.r002_bare_jit import BareJitRule
from repro.analysis.rules.r003_retrace import RetraceRule
from repro.analysis.rules.r004_protocol import ProtocolRule
from repro.analysis.rules.r005_metric_schema import MetricSchemaRule
from repro.analysis.rules.r006_tracer import TracerHygieneRule

ALL_RULES = (DeterminismRule, BareJitRule, RetraceRule, ProtocolRule,
             MetricSchemaRule, TracerHygieneRule)

__all__ = ["ALL_RULES", "DeterminismRule", "BareJitRule", "RetraceRule",
           "ProtocolRule", "MetricSchemaRule", "TracerHygieneRule"]
