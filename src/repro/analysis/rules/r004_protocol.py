"""R004 — protocol conformance: every concrete implementation carries the
full protocol surface with compatible signatures.

The serving plane is seamed on runtime-checkable Protocols (KVBackend,
Scorer, SchedulerPolicy, RoutingPolicy, the autoscaler's Policy and
Provisioner) plus one plain base class (Drafter). runtime_checkable's
isinstance() only checks NAMES at runtime — a backend that renames a
parameter or forgets `cancel_resume_plans` passes isinstance and
explodes deep inside a drain. This rule does the structural check
statically, over the AST:

  * protocol definitions are discovered in the scanned corpus — classes
    with `Protocol` among their bases, plus registered plain base
    classes (Drafter-style, whose abstract surface is the methods that
    `raise NotImplementedError`);
  * a class IMPLEMENTS a protocol if the protocol is among its
    (transitive) bases, or if it structurally matches the protocol's
    marker methods (a distinctive subset; single-marker protocols also
    require one shared parameter name so e.g. an unrelated `route()`
    method doesn't match);
  * each implementation must then define every protocol method
    (inherited concrete defs count; inherited abstract ones don't) and
    every annotated protocol attribute, with compatible signatures:
    positional names in protocol order, extras defaulted, protocol
    keyword-onlys present — *args/**kwargs absorb.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.core import Corpus, Finding, Rule, SourceFile

# distinctive marker-method sets for the repo's protocols (structural
# detection); a corpus protocol not listed here falls back to its first
# declared method as the marker
KNOWN_MARKERS: Dict[str, Tuple[str, ...]] = {
    "KVBackend": ("can_admit", "admit", "decode", "evict"),
    "Drafter": ("propose",),
    "Scorer": ("score",),
    "SchedulerPolicy": ("select", "victim"),
    "RoutingPolicy": ("route",),
    "Policy": ("decide",),
    "Provisioner": ("add_nodes", "remove_nodes"),
}

# plain base classes whose abstract surface (raise NotImplementedError)
# is treated as a protocol for their subclasses
BASE_CLASS_PROTOCOLS = ("Drafter",)


@dataclasses.dataclass
class MethodSig:
    name: str
    pos: List[str]           # positional param names, self/cls dropped
    defaults: int            # how many trailing positionals have defaults
    kwonly: List[str]
    kwonly_defaults: Set[str]
    has_vararg: bool
    has_kwarg: bool
    is_property: bool
    lineno: int


@dataclasses.dataclass
class ClassInfo:
    name: str
    sf: SourceFile
    node: ast.ClassDef
    bases: List[str]
    methods: Dict[str, MethodSig]
    attrs: Set[str]          # class-level assigns/annotations + self.X
    abstract: Set[str]       # methods whose body raises NotImplementedError
    is_protocol: bool


def _method_sig(fn: ast.FunctionDef, *, is_property: bool) -> MethodSig:
    a = fn.args
    pos = [p.arg for p in a.posonlyargs + a.args]
    if pos and pos[0] in ("self", "cls"):
        pos = pos[1:]
    return MethodSig(
        name=fn.name, pos=pos, defaults=len(a.defaults),
        kwonly=[p.arg for p in a.kwonlyargs],
        kwonly_defaults={p.arg for p, d in zip(a.kwonlyargs, a.kw_defaults)
                         if d is not None},
        has_vararg=a.vararg is not None, has_kwarg=a.kwarg is not None,
        is_property=is_property, lineno=fn.lineno)


def _is_abstract(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Raise):
            exc = node.exc
            if isinstance(exc, ast.Call):
                exc = exc.func
            if isinstance(exc, ast.Name) \
                    and exc.id == "NotImplementedError":
                return True
    return False


def _collect_class(sf: SourceFile, node: ast.ClassDef) -> ClassInfo:
    bases = []
    for b in node.bases:
        if isinstance(b, ast.Name):
            bases.append(b.id)
        elif isinstance(b, ast.Attribute):
            bases.append(b.attr)  # typing.Protocol -> Protocol
    methods: Dict[str, MethodSig] = {}
    attrs: Set[str] = set()
    abstract: Set[str] = set()
    for item in node.body:
        if isinstance(item, ast.FunctionDef):
            is_prop = any(isinstance(d, ast.Name) and d.id == "property"
                          for d in item.decorator_list)
            methods[item.name] = _method_sig(item, is_property=is_prop)
            if _is_abstract(item):
                abstract.add(item.name)
        elif isinstance(item, ast.AnnAssign) \
                and isinstance(item.target, ast.Name):
            attrs.add(item.target.id)
        elif isinstance(item, ast.Assign):
            for t in item.targets:
                if isinstance(t, ast.Name):
                    attrs.add(t.id)
    # dataclass fields and self.X assignments both count as attributes
    for fn in node.body:
        if not isinstance(fn, ast.FunctionDef):
            continue
        for sub in ast.walk(fn):
            if isinstance(sub, (ast.Assign, ast.AnnAssign)):
                targets = sub.targets if isinstance(sub, ast.Assign) \
                    else [sub.target]
                for t in targets:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id == "self":
                        attrs.add(t.attr)
    return ClassInfo(node.name, sf, node, bases, methods, attrs, abstract,
                     is_protocol="Protocol" in bases)


class ProtocolRule(Rule):
    id = "R004"
    name = "protocol"
    doc = ("concrete KVBackend/Drafter/Scorer/SchedulerPolicy/"
           "RoutingPolicy/Policy/Provisioner implementations must carry "
           "the full protocol surface with compatible signatures")

    def check(self, corpus: Corpus) -> Iterator[Finding]:
        classes: Dict[str, ClassInfo] = {}
        for sf in corpus:
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.ClassDef):
                    # first definition wins (names are unique in-repo)
                    classes.setdefault(node.name, _collect_class(sf, node))

        protocols = {c.name: c for c in classes.values()
                     if c.is_protocol or c.name in BASE_CLASS_PROTOCOLS}
        for proto in protocols.values():
            for impl in classes.values():
                if impl.name == proto.name or impl.is_protocol:
                    continue
                if not self._implements(impl, proto, classes):
                    continue
                yield from self._check_impl(impl, proto, classes)

    # -- detection ---------------------------------------------------------
    def _implements(self, impl: ClassInfo, proto: ClassInfo,
                    classes: Dict[str, ClassInfo]) -> bool:
        if self._inherits(impl, proto.name, classes):
            return True
        if proto.name in BASE_CLASS_PROTOCOLS:
            return False  # plain bases are nominal-only
        markers = KNOWN_MARKERS.get(proto.name)
        if markers is None:
            markers = tuple(list(proto.methods)[:1])
        if not markers:
            return False
        methods = self._transitive_methods(impl, classes)
        if not all(m in methods for m in markers):
            return False
        if len(markers) == 1:
            # single-marker protocols also need one shared non-self param
            # name, so an unrelated method with the same name (a network
            # sim's route()) doesn't get conscripted into the protocol
            pm = proto.methods.get(markers[0])
            im = methods.get(markers[0])
            if pm is None or im is None:
                return False
            if pm.pos and not (set(pm.pos) & set(im.pos)) \
                    and not im.has_vararg:
                return False
        return True

    def _inherits(self, impl: ClassInfo, base_name: str,
                  classes: Dict[str, ClassInfo], _depth: int = 0) -> bool:
        if _depth > 10:
            return False
        for b in impl.bases:
            if b == base_name:
                return True
            parent = classes.get(b)
            if parent is not None and self._inherits(parent, base_name,
                                                     classes, _depth + 1):
                return True
        return False

    def _transitive_methods(self, impl: ClassInfo,
                            classes: Dict[str, ClassInfo],
                            _depth: int = 0) -> Dict[str, MethodSig]:
        """impl's methods, with concrete inherited defs from bases found
        in the corpus (protocol bases contribute nothing — `...` stubs
        are not implementations). Abstract base methods don't satisfy."""
        out: Dict[str, MethodSig] = {}
        if _depth <= 10:
            for b in impl.bases:
                parent = classes.get(b)
                if parent is None or parent.is_protocol:
                    continue
                for name, sig in self._transitive_methods(
                        parent, classes, _depth + 1).items():
                    if name not in parent.abstract:
                        out[name] = sig
                out.update({n: s for n, s in parent.methods.items()
                            if n not in parent.abstract})
        out.update(impl.methods)
        return out

    def _transitive_attrs(self, impl: ClassInfo,
                          classes: Dict[str, ClassInfo],
                          _depth: int = 0) -> Set[str]:
        out = set(impl.attrs)
        if _depth <= 10:
            for b in impl.bases:
                parent = classes.get(b)
                if parent is not None and not parent.is_protocol:
                    out |= self._transitive_attrs(parent, classes,
                                                  _depth + 1)
        return out

    # -- conformance -------------------------------------------------------
    def _check_impl(self, impl: ClassInfo, proto: ClassInfo,
                    classes: Dict[str, ClassInfo]) -> Iterator[Finding]:
        methods = self._transitive_methods(impl, classes)
        attrs = self._transitive_attrs(impl, classes)
        required = {n for n in proto.methods
                    if n in proto.abstract or proto.is_protocol}
        for name in sorted(required):
            psig = proto.methods[name]
            isig = methods.get(name)
            if isig is None:
                if psig.is_property and name in attrs:
                    continue  # a plain attribute satisfies a property
                yield self.finding(
                    impl.sf, impl.node,
                    f"{impl.name} implements {proto.name} but is missing "
                    f"{name}() (declared at {proto.sf.relpath}:"
                    f"{psig.lineno})")
                continue
            if name in impl.methods:  # only check defs we can see verbatim
                msg = self._sig_mismatch(psig, isig)
                if msg:
                    f = self.finding(impl.sf, impl.node, "")
                    yield dataclasses.replace(
                        f, line=isig.lineno,
                        message=f"{impl.name}.{name} signature "
                                f"incompatible with {proto.name}.{name}: "
                                f"{msg}")
        for attr in sorted(proto.attrs):
            if attr not in attrs and attr not in methods:
                yield self.finding(
                    impl.sf, impl.node,
                    f"{impl.name} implements {proto.name} but never "
                    f"defines the protocol attribute `{attr}`")

    @staticmethod
    def _sig_mismatch(proto: MethodSig, impl: MethodSig) -> Optional[str]:
        if proto.is_property != impl.is_property:
            want = "a property" if proto.is_property else "a method"
            return f"protocol declares {want}"
        if impl.has_vararg and impl.has_kwarg:
            return None  # absorbs anything
        n = len(proto.pos)
        ipos = impl.pos
        if not impl.has_vararg:
            if len(ipos) < n:
                return (f"takes {len(ipos)} positional arg(s), protocol "
                        f"declares {n}")
            for i, pname in enumerate(proto.pos):
                if ipos[i] != pname:
                    return (f"positional arg {i + 1} is `{ipos[i]}`, "
                            f"protocol names it `{pname}` (callers pass "
                            "it by keyword)")
            extra = ipos[n:]
            undefaulted = len(ipos) - impl.defaults
            if extra and undefaulted > n:
                return (f"extra required positional arg(s) "
                        f"{ipos[n:undefaulted]} beyond the protocol "
                        "surface")
        if not impl.has_kwarg:
            for k in proto.kwonly:
                if k not in impl.kwonly and k not in impl.pos:
                    return f"missing keyword-only arg `{k}`"
            for k in impl.kwonly:
                if k not in proto.kwonly and k not in proto.pos \
                        and k not in impl.kwonly_defaults:
                    return (f"extra required keyword-only arg `{k}` "
                            "beyond the protocol surface")
        return None
