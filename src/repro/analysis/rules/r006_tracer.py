"""R006 — tracer hygiene: no Python control flow or host syncs on traced
values inside jitted step builders.

A fused-step builder (launch/steps.py make_*_step / make_*_fn, the
trainer's _build_step) returns a function that jax traces; inside it,
`if`/`while` on a traced argument raises TracerBoolConversionError at
best and silently specializes the trace at worst, and `.item()` /
`float(x)` / `np.asarray(x)` forces a device->host sync in the hot path.
Branching on CLOSURE values (cfg, sample, prompt_len) is static by
construction and fine — the rule only flags expressions that reference
the traced function's own parameters, and `.shape`/`.dtype`/`.ndim`
accesses are exempt (trace-static metadata).
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, List, Set, Tuple

from repro.analysis.core import Corpus, Finding, Rule, SourceFile
from repro.analysis.rules import common

BUILDER_NAME = re.compile(r"^(make_\w*_(step|fn)|_build_step)$")
HOST_CASTS = ("float", "int", "bool")
HOST_ARRAY_CASTS = ("numpy.asarray", "numpy.array")


class TracerHygieneRule(Rule):
    id = "R006"
    name = "tracer-hygiene"
    doc = ("Python bool()/if on traced values and .item()/float() host "
           "syncs inside jitted step builders")

    def check(self, corpus: Corpus) -> Iterator[Finding]:
        for sf in corpus:
            imports = common.import_map(sf.tree)
            for traced, params in self._traced_functions(sf, imports):
                yield from self._check_traced(sf, traced, params, imports)

    # -- what counts as "traced" ------------------------------------------
    def _traced_functions(self, sf: SourceFile, imports
                          ) -> Iterator[Tuple[ast.AST, Set[str]]]:
        """(function node, traced param names) for every function jax will
        trace: inner defs/lambdas of make_*_step builders, and lambdas
        handed to jax.jit / returned by a shared_jit builder thunk."""
        seen: List[ast.AST] = []
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.FunctionDef) \
                    and BUILDER_NAME.match(node.name):
                for inner in ast.walk(node):
                    if inner is node:
                        continue
                    if isinstance(inner, (ast.FunctionDef, ast.Lambda)):
                        seen.append(inner)
            elif isinstance(node, ast.Call) \
                    and common.is_jit_factory(node, imports):
                for arg in node.args:
                    if isinstance(arg, ast.Lambda):
                        # shared_jit takes a zero-arg builder thunk whose
                        # BODY is the traced callable; jax.jit takes the
                        # traced callable directly
                        body = arg.body
                        if not arg.args.args and isinstance(body,
                                                            ast.Lambda):
                            seen.append(body)
                        elif arg.args.args:
                            seen.append(arg)
        emitted = set()
        for fn in seen:
            if id(fn) in emitted:
                continue
            emitted.add(id(fn))
            yield fn, set(common.func_params(fn))

    # -- the checks --------------------------------------------------------
    def _check_traced(self, sf: SourceFile, fn: ast.AST, params: Set[str],
                      imports) -> Iterator[Finding]:
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                # nested defs inherit the traced param set (their own
                # params join it — they are traced values when called
                # from traced code)
                if isinstance(node, (ast.FunctionDef, ast.Lambda)):
                    params = params | set(common.func_params(node))
                if isinstance(node, (ast.If, ast.While)):
                    if common.refs_names(node.test, params):
                        yield self.finding(
                            sf, node,
                            "Python branch on a traced value inside a "
                            "jitted step builder — use jnp.where / "
                            "lax.cond (bool() on a tracer raises)")
                elif isinstance(node, ast.IfExp):
                    if common.refs_names(node.test, params):
                        yield self.finding(
                            sf, node,
                            "ternary on a traced value inside a jitted "
                            "step builder — use jnp.where")
                elif isinstance(node, ast.Assert):
                    if common.refs_names(node.test, params):
                        yield self.finding(
                            sf, node,
                            "assert on a traced value inside a jitted "
                            "step builder — it forces a host sync (or "
                            "silently passes on the tracer); use "
                            "checkify or move it to the host side")
                elif isinstance(node, ast.Call):
                    yield from self._check_call(sf, node, params, imports)

    def _check_call(self, sf: SourceFile, node: ast.Call,
                    params: Set[str], imports) -> Iterator[Finding]:
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item":
            yield self.finding(
                sf, node,
                ".item() inside a jitted step builder — a device->host "
                "sync in the traced hot path")
            return
        dn = common.resolve_call(node, imports)
        if dn in HOST_CASTS and node.args \
                and common.refs_names(node.args[0], params):
            yield self.finding(
                sf, node,
                f"{dn}() cast of a traced value inside a jitted step "
                "builder — a host sync (or a TracerConversionError); "
                "keep it on device (jnp ops) or return it")
        elif dn in HOST_ARRAY_CASTS and node.args \
                and common.refs_names(node.args[0], params):
            yield self.finding(
                sf, node,
                f"{dn.replace('numpy', 'np')}() on a traced value inside "
                "a jitted step builder — host materialization in the "
                "traced hot path; use jnp.asarray")
