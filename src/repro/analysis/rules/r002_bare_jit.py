"""R002 — bare-jit: every jit in the serving data plane goes through the
shared_jit registry.

A fleet builds N replicas per config; each ReplicaEngine, drafter,
trainer, and scorer owns step callables. `serve/kv.py shared_jit` memoizes
those callables on the frozen (cfg, plan, mesh, ...) key so the WHOLE
FLEET compiles once per config — a bare `jax.jit` inside serve/ or
rollout/ silently re-traces per instance, and the cost only shows up as a
warmup-skewed benchmark (PR 5 found exactly that). The registry file
itself (serve/kv.py) is the one sanctioned caller.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import Corpus, Finding, Rule
from repro.analysis.rules import common


class BareJitRule(Rule):
    id = "R002"
    name = "bare-jit"
    doc = ("jax.jit in serve/ or rollout/ outside the shared_jit "
           "registry (fleets must compile once per config)")

    def check(self, corpus: Corpus) -> Iterator[Finding]:
        for sf in corpus:
            if not sf.in_dirs(common.DATA_PLANE_SCOPES):
                continue
            if sf.is_file("serve", "kv.py"):
                continue  # the registry itself wraps jax.jit
            imports = common.import_map(sf.tree)
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                dn = common.resolve_call(node, imports)
                if dn in ("jax.jit", "jax.pmap"):
                    yield self.finding(
                        sf, node,
                        f"bare {dn}(...) in the serving data plane — "
                        "route it through serve.kv.shared_jit keyed on "
                        "the frozen config so a fleet of instances "
                        "compiles once per config")
