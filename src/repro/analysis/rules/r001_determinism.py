"""R001 — determinism: no wall clock, no unseeded global RNG, no
unordered-set iteration in the simulated data plane.

The serving/rollout/core sim paths promise bit-reproducible behavior
(seeded rollouts are a pure function of (params, prompt, seed); drains
and preemptions replay bit-identically). Three things silently break
that promise:

  * wall-clock reads (time.time / monotonic / perf_counter, datetime.now,
    time.sleep) — sim paths must take a core.clock.Clock, the one
    injectable time source (launch/ and benchmarks/ measure real wall
    time on purpose and are out of scope);
  * module-level RNG (random.*, numpy.random.* global state) — only
    np.random.default_rng(seed) / jax.random with an explicit key keep a
    trace reproducible;
  * iterating a set — Python sets hash-order their elements, and string
    hashing is salted per process, so `for x in some_set` visits in a
    different order run to run. Wrap in sorted(...). (Dicts are
    insertion-ordered since 3.7 and are NOT flagged: a deterministic
    insertion order is a deterministic iteration order.)
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import Corpus, Finding, Rule, SourceFile
from repro.analysis.rules import common

WALL_CLOCK = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.sleep",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
})

# numpy.random module-level (global state) calls that stay reproducible /
# are explicitly seeded constructors — everything else under numpy.random
# is the legacy global generator
NP_RANDOM_OK = frozenset({"default_rng", "Generator", "SeedSequence",
                          "PCG64", "Philox"})

WALL_ALLOWED_DIRS = ("launch", "benchmarks", "examples", "tools", "tests")


class DeterminismRule(Rule):
    id = "R001"
    name = "determinism"
    doc = ("wall-clock reads, unseeded module-level RNG, and unordered "
           "set iteration inside serve/rollout/core sim paths")

    def check(self, corpus: Corpus) -> Iterator[Finding]:
        for sf in corpus:
            if not sf.in_dirs(common.SIM_SCOPES):
                continue
            if sf.in_dirs(WALL_ALLOWED_DIRS):
                continue
            yield from self._check_file(sf)

    def _check_file(self, sf: SourceFile) -> Iterator[Finding]:
        imports = common.import_map(sf.tree)
        yield from self._check_calls(sf, imports)
        yield from self._check_set_iteration(sf)

    def _check_calls(self, sf: SourceFile,
                     imports) -> Iterator[Finding]:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            dn = common.resolve_call(node, imports)
            if dn is None:
                continue
            if dn in WALL_CLOCK:
                yield self.finding(
                    sf, node,
                    f"wall-clock call {dn}() in a sim path — time must "
                    "come from an injected core.clock.Clock so tests and "
                    "replays are deterministic")
            elif dn.startswith("random.") and dn.count(".") == 1:
                yield self.finding(
                    sf, node,
                    f"module-level RNG {dn}() draws from unseeded global "
                    "state — use np.random.default_rng(seed) or "
                    "jax.random with an explicit key")
            elif dn.startswith("numpy.random.") \
                    and dn.split(".")[2] not in NP_RANDOM_OK:
                yield self.finding(
                    sf, node,
                    f"numpy global-state RNG {dn}() — construct a seeded "
                    "np.random.default_rng(seed) generator instead")

    def _check_set_iteration(self, sf: SourceFile) -> Iterator[Finding]:
        # one module-wide binding pass: local names and self.X attributes
        # assigned set-like values anywhere (an over-approximation — a
        # name that is a set in ANY scope is treated as a set in all —
        # which is the conservative direction for a determinism check)
        local, attrs = common.collect_set_bindings(sf.tree)
        for node in ast.walk(sf.tree):
            iters = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters = [node.iter]
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.DictComp, ast.GeneratorExp)):
                iters = [g.iter for g in node.generators]
            for it in iters:
                if common.is_setlike(it, local, attrs):
                    yield self.finding(
                        sf, node,
                        "iteration over an unordered set in a sim path — "
                        "set order is hash-salted per process; wrap the "
                        "iterable in sorted(...) to pin it")
