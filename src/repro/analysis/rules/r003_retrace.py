"""R003 — retrace hazards: per-request Python scalars flowing into
jitted call arguments.

The serving plane's jitted steps are traced once per *shape*; an argument
expression built from `len(request.tokens)` or `x.shape[...]` is a
Python int that varies per request, and anything whose shape derives from
it (np.zeros(len(...)), padding to the current batch's max) re-traces the
step on every new value — the continuous-batching promise ("admission
never re-compiles") dies quietly. The repo idiom is static pinning:
fixed-shape padded batches (rollout/preference.py pad_pairs/pad_len) and
pool-shaped metadata arrays.

Detection: a call through a name bound to jax.jit(...)/shared_jit(...)
(including the dual greedy/sampling dict tables) whose argument
expression contains a bare len(...) call or .shape access.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from repro.analysis.core import Corpus, Finding, Rule
from repro.analysis.rules import common


def _has_dynamic_scalar(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name) \
                and n.func.id == "len":
            return True
        if isinstance(n, ast.Attribute) and n.attr == "shape":
            return True
    return False


class RetraceRule(Rule):
    id = "R003"
    name = "retrace"
    doc = ("per-request Python scalars (len(...), .shape) flowing into "
           "jitted call args without static pinning")

    def check(self, corpus: Corpus) -> Iterator[Finding]:
        for sf in corpus:
            if not sf.in_dirs(common.DATA_PLANE_SCOPES):
                continue
            imports = common.import_map(sf.tree)
            jitted: Set[str] = common.collect_jitted_names(sf.tree, imports)
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                if not self._is_jitted_call(node, jitted, imports):
                    continue
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if _has_dynamic_scalar(arg):
                        yield self.finding(
                            sf, arg,
                            "argument to a jitted callable is built from "
                            "a per-request Python scalar (len/.shape) — "
                            "each new value re-traces the step; pin the "
                            "shape (pad to a fixed size or pass a "
                            "pool-shaped array)")

    @staticmethod
    def _is_jitted_call(node: ast.Call, jitted: Set[str], imports) -> bool:
        fn = node.func
        # f(...) / self._step(...) through a jit-bound name
        dn = common.dotted_name(fn)
        if dn is not None and dn in jitted:
            return True
        # self._decode[sample](...) through a jit-holding dict table
        if isinstance(fn, ast.Subscript):
            dn = common.dotted_name(fn.value)
            if dn is not None and dn in jitted:
                return True
        # jax.jit(f)(...) invoked in place
        return common.is_jit_factory(fn, imports)
