"""replint CLI — `python -m repro.analysis.lint src`.

Exit code 0 when every finding is suppressed (with a written reason),
1 when any unsuppressed finding remains — the CI step runs this before
pytest so a contract break fails in seconds, not minutes.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.analysis.core import run_lint
from repro.analysis.rules import ALL_RULES


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="replint: the repo's determinism / compile-once / "
                    "protocol contract checker (docs/analysis.md)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="report format (json includes suppressed "
                         "findings and unused suppressions)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run "
                         "(e.g. R001,R004); default: all")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed findings in text mode")
    ap.add_argument("--output", default=None,
                    help="write the report to a file instead of stdout")
    args = ap.parse_args(argv)

    rules = [cls() for cls in ALL_RULES]
    if args.rules:
        want = {r.strip().upper() for r in args.rules.split(",")}
        unknown = want - {r.id for r in rules}
        if unknown:
            ap.error(f"unknown rule id(s): {', '.join(sorted(unknown))}")
        rules = [r for r in rules if r.id in want]

    result = run_lint(args.paths, rules)
    report = (result.format_json() if args.format == "json"
              else result.format_text(show_suppressed=args.show_suppressed))
    if args.output:
        with open(args.output, "w") as f:
            f.write(report + "\n")
    else:
        print(report)
    return 1 if result.unsuppressed else 0


if __name__ == "__main__":
    sys.exit(main())
