"""replint core — findings, suppressions, the rule base, and the runner.

Deliberately stdlib-only (ast + re + pathlib): the linter runs as the
first CI step, before pytest and before anything imports jax, so a
contract break fails in seconds.

Suppression grammar (mandatory reason — a suppression is a recorded
decision, not an escape hatch):

    <offending code>  # replint: ignore[R001] -- why this is sanctioned
    # replint: ignore[R002,R003] -- a standalone comment covers the NEXT line

A suppression with no `-- reason` is itself reported (rule R000), and a
suppression that matches no finding is reported as unused — stale
suppressions rot into blind spots otherwise.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*replint:\s*ignore\[(?P<ids>[A-Za-z0-9_,\s]*)\]"
    r"(?:\s*--\s*(?P<reason>.*\S))?")

# engine-level findings (suppression syntax, parse failures) use this id
ENGINE_RULE = "R000"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str        # "R001"
    name: str        # "determinism"
    path: str        # scan-root-relative posix path
    line: int
    col: int
    message: str
    suppressed: bool = False
    reason: str = ""  # the suppression's written reason, when suppressed

    def format(self) -> str:
        tag = f"{self.rule} [{self.name}]"
        loc = f"{self.path}:{self.line}:{self.col}"
        suf = f"  (suppressed: {self.reason})" if self.suppressed else ""
        return f"{loc}: {tag} {self.message}{suf}"

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Suppression:
    line: int        # line the suppression comment sits on
    ids: Tuple[str, ...]
    reason: str
    covers_next: bool  # standalone comment line: applies to line + 1
    used: bool = False

    def covers(self, rule: str, line: int) -> bool:
        if rule not in self.ids:
            return False
        return line == self.line or (self.covers_next
                                     and line == self.line + 1)


class SourceFile:
    """One parsed module: source text, AST, path parts, suppressions."""

    def __init__(self, path: Path, relpath: str, text: str):
        self.path = path
        self.relpath = relpath          # posix, relative to the scan root
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.Module] = None
        self.parse_error: Optional[str] = None
        try:
            self.tree = ast.parse(text)
        except SyntaxError as e:  # surfaced as an R000 finding by the runner
            self.parse_error = f"syntax error: {e.msg} (line {e.lineno})"
        parts = Path(relpath).parts
        self.parts = parts              # every segment, filename included
        self.dir_parts = parts[:-1]
        self.suppressions: List[Suppression] = []
        self.malformed_suppressions: List[Tuple[int, str]] = []
        self._parse_suppressions()

    def _parse_suppressions(self) -> None:
        # tokenize so only real COMMENT tokens count — a directive quoted
        # in a docstring or string literal is documentation, not a
        # suppression
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(self.text).readline))
        except (tokenize.TokenError, IndentationError, SyntaxError):
            return  # unparseable file — already reported via parse_error
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            i, col = tok.start
            comment = tok.string
            m = SUPPRESS_RE.search(comment)
            if not m:
                if "replint:" in comment:
                    self.malformed_suppressions.append(
                        (i, "unparseable replint directive (expected "
                            "'# replint: ignore[R00X] -- reason')"))
                continue
            ids = tuple(s.strip().upper()
                        for s in m.group("ids").split(",") if s.strip())
            reason = (m.group("reason") or "").strip()
            if not ids:
                self.malformed_suppressions.append(
                    (i, "suppression lists no rule ids"))
                continue
            if not reason:
                self.malformed_suppressions.append(
                    (i, f"suppression of {', '.join(ids)} has no reason "
                        "(grammar: # replint: ignore[R00X] -- why)"))
                continue
            src_line = self.lines[i - 1] if i <= len(self.lines) else ""
            covers_next = not src_line[:col].strip()
            self.suppressions.append(
                Suppression(i, ids, reason, covers_next))

    # -- path scoping helpers (rules call these) ---------------------------
    def in_dirs(self, names: Sequence[str]) -> bool:
        """Any directory segment of the path matches — works for both
        src/repro/serve/x.py and a fixture corpus's serve/x.py."""
        return any(p in names for p in self.dir_parts)

    def is_file(self, *tail: str) -> bool:
        """Path ends with the given segments (e.g. is_file('serve', 'kv.py'))."""
        return self.parts[-len(tail):] == tuple(tail)


class Corpus:
    """Every parsed file of one lint run — corpus-wide rules (protocol
    conformance, metric schema) see all modules at once."""

    def __init__(self, files: List[SourceFile]):
        self.files = files

    def __iter__(self) -> Iterator[SourceFile]:
        return iter(self.files)


class Rule:
    """Base rule: subclass, set id/name/doc, implement check()."""

    id = "R???"
    name = "unnamed"
    doc = ""

    def check(self, corpus: Corpus) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, sf: SourceFile, node: ast.AST, message: str) -> Finding:
        return Finding(self.id, self.name, sf.relpath,
                       getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]          # suppressed + unsuppressed, sorted
    files_scanned: int
    unused_suppressions: List[Tuple[str, int, str]]  # (path, line, ids)

    @property
    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def suppressed(self) -> List[Finding]:
        return [f for f in self.findings if f.suppressed]

    def format_text(self, *, show_suppressed: bool = False) -> str:
        out = [f.format() for f in self.unsuppressed]
        if show_suppressed:
            out += [f.format() for f in self.suppressed]
        for path, line, ids in self.unused_suppressions:
            out.append(f"{path}:{line}:0: note: unused suppression [{ids}]")
        out.append(f"replint: {len(self.unsuppressed)} finding(s), "
                   f"{len(self.suppressed)} suppressed, "
                   f"{self.files_scanned} file(s) scanned")
        return "\n".join(out)

    def format_json(self) -> str:
        return json.dumps({
            "findings": [f.to_json() for f in self.findings],
            "unsuppressed": len(self.unsuppressed),
            "suppressed": len(self.suppressed),
            "files_scanned": self.files_scanned,
            "unused_suppressions": [
                {"path": p, "line": ln, "ids": ids}
                for p, ln, ids in self.unused_suppressions],
        }, indent=2)


def discover(paths: Sequence[str]) -> List[Tuple[Path, str]]:
    """(abs path, scan-root-relative posix path) for every .py file.

    Relative paths are computed against each argument, so scanning `src`
    yields repro/serve/... and scanning a fixture corpus yields its own
    serve/... layout — path-scoped rules match either."""
    out: List[Tuple[Path, str]] = []
    for arg in paths:
        root = Path(arg)
        if root.is_file():
            out.append((root, root.name))
            continue
        for p in sorted(root.rglob("*.py")):
            if "__pycache__" in p.parts:
                continue
            out.append((p, p.relative_to(root).as_posix()))
    return out


def run_lint(paths: Sequence[str],
             rules: Optional[Sequence[Rule]] = None) -> LintResult:
    """Parse every file under `paths`, run every rule, apply suppressions."""
    if rules is None:
        from repro.analysis.rules import ALL_RULES
        rules = [cls() for cls in ALL_RULES]
    files = [SourceFile(p, rel, p.read_text())
             for p, rel in discover(paths)]
    corpus = Corpus([f for f in files if f.tree is not None])

    findings: List[Finding] = []
    for sf in files:
        if sf.parse_error:
            findings.append(Finding(ENGINE_RULE, "engine", sf.relpath, 1, 0,
                                    sf.parse_error))
        for line, msg in sf.malformed_suppressions:
            findings.append(Finding(ENGINE_RULE, "engine", sf.relpath,
                                    line, 0, msg))
    for rule in rules:
        findings.extend(rule.check(corpus))

    by_path = {sf.relpath: sf for sf in files}
    resolved: List[Finding] = []
    for f in findings:
        sf = by_path.get(f.path)
        sup = None
        if sf is not None and f.rule != ENGINE_RULE:
            sup = next((s for s in sf.suppressions
                        if s.covers(f.rule, f.line)), None)
        if sup is not None:
            sup.used = True
            resolved.append(dataclasses.replace(f, suppressed=True,
                                                reason=sup.reason))
        else:
            resolved.append(f)
    resolved.sort(key=lambda f: (f.path, f.line, f.rule, f.col))

    unused = [(sf.relpath, s.line, ",".join(s.ids))
              for sf in files for s in sf.suppressions if not s.used]
    return LintResult(resolved, len(files), unused)
