"""Sharded checkpointing with cross-topology RESHARD on restore.

This is the substrate the elastic runtime (core/elastic.py) stands on: a
checkpoint written on an N-node mesh restores onto an M-node mesh by
device_put-ing each leaf with the *target* sharding — the JAX analogue of
re-laying MPI ranks after the paper's cluster grows or shrinks.

Format: <dir>/step_<k>/
  manifest.json  — flat key -> {shape, dtype}, plus step + user metadata
  <key>.npy      — one file per leaf (bf16 stored via ml_dtypes view)

Features: atomic publish (tmp dir + rename), retention of last K, async
save (background thread + wait()), integrity check on restore.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_SEP = "\x1d"


def _flatten_with_paths(tree: Pytree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(jax.tree_util.keystr((p,)) for p in path)
        out[key] = leaf
    return out


def _np_save(path: str, arr) -> None:
    a = np.asarray(jax.device_get(arr))
    if a.dtype == jnp.bfloat16:  # npy has no bf16: store raw bits + tag
        np.save(path, a.view(np.uint16))
        with open(path + ".npy.dtype", "w") as f:
            f.write("bfloat16")
    else:
        np.save(path, a)


def _np_load(path: str):
    a = np.load(path + ".npy")
    tag = path + ".npy.dtype"
    if os.path.exists(tag):
        a = a.view(jnp.bfloat16)
    return a


def _safe(key: str) -> str:
    return "".join(c if (c.isalnum() or c in "._-") else "_" for c in key)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # ---- save ---------------------------------------------------------------
    def save(self, step: int, state: Pytree,
             metadata: Optional[Dict[str, Any]] = None) -> str:
        """Synchronous save; atomic publish via rename."""
        flat = _flatten_with_paths(state)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
        for key, leaf in flat.items():
            fname = _safe(key)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(leaf.shape),
                "dtype": str(leaf.dtype),
            }
            _np_save(os.path.join(tmp, fname), leaf)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._retain()
        return final

    def save_async(self, step: int, state: Pytree,
                   metadata: Optional[Dict[str, Any]] = None) -> Future:
        """Device->host copy happens now; file IO in the background."""
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        with self._lock:
            self._pending = self._pool.submit(self.save, step, host_state,
                                              metadata)
            return self._pending

    def wait(self) -> None:
        with self._lock:
            pending = self._pending
        if pending is not None:
            pending.result()

    def _retain(self) -> None:
        steps = self.available_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---- restore --------------------------------------------------------------
    def available_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, target_struct: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None) -> Pytree:
        """Restore into target_struct's tree, RESHARDING each leaf with the
        matching entry of `shardings` (same structure, NamedSharding or None).

        target_struct supplies the pytree structure (values ignored)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        base = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        flat_target = _flatten_with_paths(target_struct)
        flat_shard = (_flatten_with_paths(shardings)
                      if shardings is not None else {})
        missing = set(flat_target) - set(manifest["leaves"])
        extra = set(manifest["leaves"]) - set(flat_target)
        if missing or extra:
            raise ValueError(
                f"checkpoint/target tree mismatch: missing={sorted(missing)[:3]}"
                f" extra={sorted(extra)[:3]}")
        out = {}
        for key in flat_target:
            info = manifest["leaves"][key]
            arr = _np_load(os.path.join(base, info["file"]))
            want = flat_target[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {want.shape}")
            sh = flat_shard.get(key)
            a = jnp.asarray(arr)
            out[key] = jax.device_put(a, sh) if sh is not None else a
        # rebuild the tree
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_struct)
        leaves = []
        for path, _ in flat:
            key = _SEP.join(jax.tree_util.keystr((p,)) for p in path)
            leaves.append(out[key])
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def metadata(self, step: Optional[int] = None) -> Dict[str, Any]:
        step = step if step is not None else self.latest_step()
        base = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(base, "manifest.json")) as f:
            return json.load(f)["metadata"]
