"""Sharding rules: DP/FSDP/TP/EP/SP partition specs for every tree in the
system (params, optimizer state, KV/state caches, batches).

One resolver maps a tree path + rank to a PartitionSpec; every dim whose
size the mesh axis does not divide falls back to replication (validated
against the actual mesh), so the same rules serve the production 16x16 mesh,
subprocess 8-device test meshes, and oversubscribed single-CPU sims.

Layout summary (DESIGN.md §4):
  column weights  [d_in, d_out]    P(fsdp, tp)     (QKV, MLP-in, ...)
  row weights     [d_in, d_out]    P(tp, fsdp)     (O, MLP-out, ...)
  embed/unembed                    P(None, tp)
  MoE experts                      moe_param_specs (ep|tp mode)
  KV caches (decode)               seq-sharded over tp (flash-decoding)
  recurrent states                 width/heads over tp
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.env import Env

Pytree = Any

_COL = {"wq", "w_gate", "w_up", "w_in", "w_gate_in", "w_r", "w_k", "w_v",
        "w_g", "cm_k", "cm_r", "decay_B"}
_ROW = {"wo", "w_down", "w_out", "cm_v", "w_o"}
_REPL_SMALL = {"bk", "bv", "q_norm", "k_norm", "ln1", "ln2", "lnx", "ln_x",
               "final_norm", "enc_norm", "mu", "cmu", "decay_A", "router"}
_MOE_LEAVES = {"w_gate", "w_up", "w_down"}


def _names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def _param_dims(names, cfg: ModelConfig, env: Env) -> Tuple:
    """Spec dims for an (unstacked) parameter leaf."""
    leaf = names[-1]
    tp = env.plan.tp_axis
    fs = ("pod", "data") if env.plan.fsdp else None
    if "moe" in names and leaf in (_MOE_LEAVES | {"router"}):
        mode = env.plan.resolve_moe(cfg, max(env.tp, 1))
        if leaf == "router":
            return (None, None)
        if mode == "ep":
            return {"w_gate": (tp, fs, None), "w_up": (tp, fs, None),
                    "w_down": (tp, None, fs)}[leaf]
        return {"w_gate": (None, fs, tp), "w_up": (None, fs, tp),
                "w_down": (None, tp, fs)}[leaf]
    if leaf in ("embed", "unembed"):
        return (None, tp)
    if leaf in _COL:
        return (fs, tp)
    if leaf in _ROW:
        return (tp, fs)
    if leaf in ("wk", "wv"):
        return (fs, None)  # small KV projections: replicate columns (GQA)
    if leaf == "bq":
        return (tp,)
    if leaf == "conv_w":
        return (None, tp)
    if leaf in ("w_rgate", "w_igate"):
        return (tp, None, None)  # block-diagonal gates: blocks over tp
    if leaf in ("lam", "decay_base"):
        return (tp,)
    if leaf == "bonus_u":
        return (tp, None)
    return None  # -> replicate


def _cache_dims(names, rank: int, cfg: ModelConfig, env: Env) -> Tuple:
    leaf = names[-1]
    tp = env.plan.tp_axis
    dp = env.dpx or None
    seq_sh = env.plan.kv_cache == "seq_sharded"
    if leaf in ("k", "v", "xk", "xv"):  # [B, Hkv, S, hd]
        return (dp, None, tp if seq_sh else None, None)
    if leaf == "h":  # rglru state [B, w]
        return (dp, tp)
    if leaf == "conv":  # [B, cw-1, w]
        return (dp, None, tp)
    if leaf == "s":  # rwkv state [B, H, hd, hd]
        return (dp, tp, None, None)
    if leaf in ("tm_prev", "cm_prev"):  # [B, d]
        return (dp, None)
    return None


def _resolve(names, rank: int, cfg: ModelConfig, env: Env,
             kind: str) -> Tuple:
    leaf = names[-1]
    if leaf == "step":
        return ()
    # optimizer state wrapping: .../<param>/q or /s  (int8 moments)
    if kind == "state" and leaf in ("q", "s") and len(names) >= 2:
        base = _resolve(names[:-1], rank if leaf == "q" else rank + 1, cfg,
                        env, "state")
        return base if leaf == "q" else base[:-1]
    if kind == "cache":
        dims = _cache_dims(names, rank, cfg, env)
    else:
        dims = _param_dims(names, cfg, env)
    if dims is None:
        dims = (None,) * rank
    # stacked leading scan dim for repeated blocks
    stacked = any(n in ("blocks", "enc_blocks") for n in names[:-1])
    if kind == "cache":
        stacked = "blocks" in names[:1]
    if stacked and len(dims) == rank - 1:
        dims = (None,) + dims
    if len(dims) != rank:  # rank mismatch (e.g. replicated default)
        dims = tuple(dims[:rank]) + (None,) * max(0, rank - len(dims))
    return dims


def _validated(dims, shape, env: Env) -> P:
    """Drop axis assignments that do not divide the dim size."""
    if env.mesh is None:
        return P()
    out = []
    for size, d in zip(shape, dims):
        if d is None:
            out.append(None)
            continue
        axes = d if isinstance(d, tuple) else (d,)
        axes = tuple(a for a in axes if a in env.axis_names)
        if not axes:
            out.append(None)
            continue
        n = 1
        for a in axes:
            n *= env.mesh.shape[a]
        out.append((d if not isinstance(d, tuple) else axes)
                   if (n > 0 and size % n == 0) else None)
    return P(*out)


def _tree_specs(struct: Pytree, cfg: ModelConfig, env: Env, kind: str
                ) -> Pytree:
    def one(path, leaf):
        names = _names(path)
        dims = _resolve(names, len(leaf.shape), cfg, env, kind)
        return _validated(dims, leaf.shape, env)

    return jax.tree_util.tree_map_with_path(one, struct)


# ---- public API --------------------------------------------------------------


def param_specs(params_struct: Pytree, cfg: ModelConfig, env: Env) -> Pytree:
    return _tree_specs(params_struct, cfg, env, "param")


def state_specs(state_struct: Pytree, cfg: ModelConfig, env: Env) -> Pytree:
    """Train state {"params":…, "opt": {step, master, m, v}}."""
    return _tree_specs(state_struct, cfg, env, "state")


def cache_specs(cache_struct: Pytree, cfg: ModelConfig, env: Env) -> Pytree:
    return _tree_specs(cache_struct, cfg, env, "cache")


def batch_specs(batch_struct: Pytree, cfg: ModelConfig, shape: ShapeConfig,
                env: Env) -> Pytree:
    dp = env.dpx if (env.dp and shape.global_batch % max(env.dp, 1) == 0) \
        else None

    def one(path, leaf):
        dims = (dp,) + (None,) * (len(leaf.shape) - 1)
        return _validated(dims, leaf.shape, env)

    return jax.tree_util.tree_map_with_path(one, batch_struct)


def to_shardings(specs: Pytree, env: Env) -> Optional[Pytree]:
    if env.mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(env.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def apply_shardings(tree: Pytree, specs: Pytree, env: Env) -> Pytree:
    """device_put a concrete tree with the resolved shardings."""
    sh = to_shardings(specs, env)
    if sh is None:
        return tree
    return jax.tree.map(jax.device_put, tree, sh)
