from repro.parallel.rules import (  # noqa: F401
    apply_shardings,
    batch_specs,
    cache_specs,
    param_specs,
    state_specs,
    to_shardings,
)
