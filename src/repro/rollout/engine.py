"""RolloutEngine — the serving fleet as a reproducible generation engine.

Post-training needs N sampled completions per prompt ("rollouts"). The
serving plane already knows how to batch, page, route, and autoscale that
traffic — this module drives it as a *generator* instead of rebuilding a
second decode path: a prompt set fans out as a burst trace with n_samples
requests per prompt, each carrying a SamplingParams seed derived from
(prompt_id, sample_idx), and the engine's position-keyed PRNG makes every
completion a pure function of (params, prompt, seed). Slot count, replica
count, lane placement, preemptions, swaps — none of it shows in the
tokens, so rollouts generated on a 4-replica fleet are bit-identical to
the same prompts on a single engine. That is the reproducibility contract
RL-style post-training wants: a reward assigned to a rollout re-derives
against the exact same tokens anywhere.

Multi-turn rollouts re-enter the queue as follow_up() requests whose
prompts grow by each turn's completion — the traffic shape prefix caching
and prefix-affine routing were built for (siblings share the base prompt;
a lineage's turns share ever-longer prefixes).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.request import Request
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import run_to_completion


@dataclass
class Rollout:
    """One completion of one prompt at one conversation turn.

    `tokens` is this turn's completion only; `prompt` is the full context
    it was generated from (turn > 0: the lineage's grown prefix). `seed`
    is the derived per-request PRNG root — with the prompt and the params
    it fully determines `tokens`.
    """
    prompt_id: int
    sample_idx: int
    rid: int
    turn: int
    prompt: np.ndarray
    tokens: Tuple[int, ...]
    seed: int
    reward: float = 0.0


def rollout_signature(rollouts: Sequence[Rollout]) -> Dict[int, Tuple[int, ...]]:
    """rid -> tokens map — the equality object for reproducibility checks
    (two generations match iff their signatures are equal)."""
    return {r.rid: tuple(r.tokens) for r in rollouts}


class RolloutEngine:
    """Fan a prompt set out over a serving engine as seeded rollouts.

    `engine` is a ServingEngine or a ReplicaSet (serve/router.py) — both
    expose submit/step/drained/results. Request rids are laid out as

        rid = turn * stride + prompt_id * n_samples + sample_idx,
        stride = n_prompts * n_samples

    so every (prompt, sample, turn) coordinate has one deterministic rid
    regardless of completion order, and turn-0 seeds derive as
    sampling.derive(rid) — the same additive derivation every trace
    generator uses. Later turns derive through the *lineage*
    (SamplingParams.derive_turn via Request.follow_up), not the child rid,
    so a turn's distribution never depends on how rids were numbered.
    """

    def __init__(self, engine, *, n_samples: int = 4, gen_len: int = 8,
                 sampling: Optional[SamplingParams] = None,
                 deadline_s: float = math.inf):
        if n_samples < 1:
            raise ValueError(f"n_samples must be >= 1, got {n_samples}")
        self.engine = engine
        self.n_samples = n_samples
        self.gen_len = gen_len
        self.sampling = sampling if sampling is not None else SamplingParams()
        self.deadline_s = deadline_s
        self.last_tokens = 0  # completion tokens of the last generate()

    # -- request fan-out ----------------------------------------------------
    def requests_for(self, prompts: Sequence[np.ndarray], *,
                     at: float = 0.0) -> List[Request]:
        """The turn-0 burst: n_samples seeded requests per prompt, all
        arriving at `at`. Pure function of (prompts, engine config) — two
        calls build equivalent traces, which is what lets a verify pass
        regenerate the same workload for a second engine."""
        out = []
        for pid, prompt in enumerate(prompts):
            p = np.asarray(prompt, np.int32)
            for k in range(self.n_samples):
                rid = pid * self.n_samples + k
                out.append(Request(rid=rid, prompt=p, gen_len=self.gen_len,
                                   arrival_t=at, deadline_s=self.deadline_s,
                                   sampling=self.sampling.derive(rid)))
        return out

    # -- generation ---------------------------------------------------------
    def generate(self, prompts: Sequence[np.ndarray], *, cluster=None,
                 dt=0.05, turns: int = 1, max_steps: int = 100_000,
                 on_step=None) -> List[Rollout]:
        """Run the prompt set to completion and return every rollout.

        turns > 1 is the multi-turn trace: each completed request's output
        re-enters the queue as a follow_up() whose prompt is the grown
        context (arrival at the parent's completion time — ordering is
        preserved, so the run replays bit-identically). The injection
        happens inside the serve loop's on_step callback, which both
        run_to_completion and VirtualCluster.serve invoke *before*
        re-checking drained() — a follow-up submitted there keeps the
        loop alive.

        With `cluster`, the generation phase runs through
        cluster.serve(): engine metrics publish to the registry KV and
        the autoscaler resizes the fleet mid-rollout.
        """
        if turns < 1:
            raise ValueError(f"turns must be >= 1, got {turns}")
        stride = len(prompts) * self.n_samples
        reqs = self.requests_for(prompts)
        # pending holds our own Request references — the engine mutates
        # them in place, so completion state is visible here even if a
        # draining replica archives its completed list before we scan it
        pending: Dict[int, Request] = {r.rid: r for r in reqs}
        coords: Dict[int, Tuple[int, int]] = {
            r.rid: (r.rid // self.n_samples, r.rid % self.n_samples)
            for r in reqs}
        rollouts: List[Rollout] = []

        def _harvest():
            for rid in [r for r, q in pending.items() if q.done]:
                req = pending.pop(rid)
                pid, k = coords[rid]
                rollouts.append(Rollout(
                    prompt_id=pid, sample_idx=k, rid=rid, turn=req.turn,
                    prompt=req.prompt, tokens=tuple(req.tokens),
                    seed=req.sampling.seed, reward=0.0))
                if req.turn + 1 < turns:
                    child = req.follow_up(rid=rid + stride,
                                          gen_len=self.gen_len)
                    coords[child.rid] = (pid, k)
                    pending[child.rid] = child
                    self.engine.submit([child])

        def _cb(i, snap, *rest):
            _harvest()
            if on_step is not None:
                on_step(i, snap, *rest)

        if cluster is not None:
            cluster.serve(self.engine, reqs, dt=dt, max_steps=max_steps,
                          on_step=_cb)
        else:
            run_to_completion(self.engine, reqs, dt=dt, max_steps=max_steps,
                              on_step=_cb)
        _harvest()  # requests that retired on the final step
        assert not pending, f"undrained rollouts: {sorted(pending)}"
        rollouts.sort(key=lambda r: r.rid)
        self.last_tokens = sum(len(r.tokens) for r in rollouts)
        return rollouts
