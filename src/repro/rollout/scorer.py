"""Scorers — per-completion rewards over a batch of rollouts.

A Scorer maps rollouts to scalar rewards; the preference stage
(rollout/preference.py) only ever sees the numbers, so any reward model
plugs in behind this protocol. The three references cover the common
shapes: a programmatic length target, keyword matching over the
completion, and a reference-model log-probability score (the "does a
judge model like this text" family, batched through the same iota-masked
log-prob path the DPO loss uses).

Scorers are deterministic functions of the rollout tokens — rewards
re-derive bit-identically anywhere the rollouts do, which keeps the whole
generate -> score -> train round reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.models.env import Env
from repro.rollout.engine import Rollout
from repro.rollout.preference import completion_logprobs, pack_sequences
from repro.serve.kv import shared_jit
from repro.serve.scheduler import SERVE_PLAN


@runtime_checkable
class Scorer(Protocol):
    name: str

    def score(self, rollouts: Sequence[Rollout]) -> List[float]:
        """One reward per rollout, same order. Pure in the tokens."""
        ...


@dataclass
class LengthScorer:
    """Reward completions for hitting a target length: 0 at exactly
    `target` generated tokens, -1 per token of miss (normalized). With
    stop_tokens in play completions end early at different lengths, so
    this separates samples; without them it is the degenerate all-tie
    case build_pairs skips."""
    target: int
    name: str = "length"

    def score(self, rollouts):
        d = max(self.target, 1)
        return [-abs(len(r.tokens) - self.target) / d for r in rollouts]


@dataclass
class KeywordScorer:
    """Fraction of completion tokens that are in the keyword set — the
    classic programmatic reward (did the rollout mention X)."""
    keywords: Tuple[int, ...]
    name: str = "keyword"

    def score(self, rollouts):
        kw = set(self.keywords)
        return [sum(t in kw for t in r.tokens) / max(len(r.tokens), 1)
                for r in rollouts]


class LogprobScorer:
    """Mean per-token completion log-probability under a reference model
    — rewards fluent-under-the-reference completions. The reference
    params are whatever the caller snapshots (typically the pre-training
    serving params, same anchor as the DPO reference)."""
    name = "logprob"

    def __init__(self, cfg, params, *, env: Optional[Env] = None):
        self.cfg = cfg
        self.env = env if env is not None else Env(mesh=None, plan=SERVE_PLAN)
        self.params = params
        cfg_, env_ = self.cfg, self.env
        # same key family as the DPO step: scorers across a fleet (and the
        # trainer's loss internals) share one completion-logprob trace
        self._lp = shared_jit(
            ("completion_lp", cfg_, env_.plan, env_.mesh),
            lambda: (lambda p, t, m: completion_logprobs(
                p, t, m, cfg_, env_)))

    def score(self, rollouts):
        if not rollouts:
            return []
        toks, mask = pack_sequences(rollouts)
        lp = np.asarray(self._lp(self.params, toks, mask))
        n = np.maximum(mask.sum(axis=-1), 1.0)
        return [float(x) for x in lp / n]


def make_scorer(kind: str, **kw) -> Scorer:
    """The one scorer-kind dispatch ("length", "keyword", "logprob")."""
    if kind == "length":
        return LengthScorer(**kw)
    if kind == "keyword":
        return KeywordScorer(**kw)
    if kind == "logprob":
        return LogprobScorer(**kw)
    raise ValueError(f"unknown scorer {kind!r} "
                     "(expected 'length', 'keyword', or 'logprob')")
