"""Rollout subsystem — the serving fleet as a reproducible generation
engine for post-training.

RolloutEngine (rollout/engine.py) fans a prompt set out over the
continuous-batching serving plane as seeded, bit-reproducible rollouts;
Scorers (rollout/scorer.py) assign per-completion rewards;
PreferenceTrainer (rollout/preference.py) turns scored rollouts into
DPO-style parameter updates through the existing AdamW optimizer; and
RolloutLoop (rollout/loop.py) alternates the phases on one VirtualCluster
whose autoscaler arbitrates capacity between them.

See docs/rollout.md for the loop diagram and the reproducibility contract.
"""
from repro.rollout.engine import (  # noqa: F401
    Rollout,
    RolloutEngine,
    rollout_signature,
)
from repro.rollout.loop import PHASE_METRICS, RolloutLoop  # noqa: F401
from repro.rollout.preference import (  # noqa: F401
    PreferenceTrainer,
    build_pairs,
    completion_logprobs,
    pack_pair_batch,
    pack_sequences,
)
from repro.rollout.scorer import (  # noqa: F401
    KeywordScorer,
    LengthScorer,
    LogprobScorer,
    Scorer,
    make_scorer,
)
