"""DPO-style preference updates from scored rollouts.

Closes the generate -> score -> train loop against the serving model's own
parameters: scored rollouts pair up (best vs worst completion per prompt),
and the trainer steps the existing AdamW optimizer (optim/adamw.py) on the
direct-preference objective

    L = -log sigmoid(beta * ((lp_pi(c) - lp_ref(c)) - (lp_pi(r) - lp_ref(r))))

where lp(.) is the summed log-probability of the *completion* tokens under
the (frozen-reference vs trained) model. Completion log-probs reuse the
iota-masked pattern of Mo.lm_loss — no gather over the (vocab-padded,
possibly TP-sharded) logits — with a per-position mask selecting the
completion span, so prompts of different lengths batch together.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as Mo
from repro.models.env import Env
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.rollout.engine import Rollout
from repro.serve.kv import shared_jit
from repro.serve.scheduler import SERVE_PLAN


# -- batching -----------------------------------------------------------------

def pack_sequences(items: Sequence[Rollout], *, pad_len: Optional[int] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Rollouts -> ([B,S] int32 token matrix, [B,S-1] float32 mask).

    Row i is prompt_i ++ tokens_i padded to a common length; mask[i, j]
    is 1.0 exactly on the *label* positions of completion tokens (inputs
    are seq[:-1], labels seq[1:], so completion token t at sequence index
    p contributes at label index p-1). Padding predicts nothing.
    """
    seqs = [np.concatenate([np.asarray(r.prompt, np.int32),
                            np.asarray(r.tokens, np.int32)]) for r in items]
    S = max((len(s) for s in seqs), default=2)
    if pad_len is not None:
        if pad_len < S:
            raise ValueError(f"pad_len {pad_len} < longest sequence {S}")
        S = pad_len
    S = max(S, 2)  # forward needs at least one label position
    toks = np.zeros((len(seqs), S), np.int32)
    mask = np.zeros((len(seqs), S - 1), np.float32)
    for i, (r, s) in enumerate(zip(items, seqs)):
        toks[i, :len(s)] = s
        lo = len(r.prompt) - 1
        mask[i, lo:lo + len(r.tokens)] = 1.0
    return toks, mask


def build_pairs(rollouts: Sequence[Rollout]
                ) -> List[Tuple[Rollout, Rollout]]:
    """Chosen/rejected pairs: per (prompt_id, turn) group, the highest-
    vs lowest-reward completion. Groups whose rewards are all equal carry
    no preference signal and are skipped (a tie teaches nothing and the
    DPO gradient at margin 0 would just shrink both)."""
    groups: Dict[Tuple[int, int], List[Rollout]] = {}
    for r in rollouts:
        groups.setdefault((r.prompt_id, r.turn), []).append(r)
    pairs = []
    for key in sorted(groups):
        g = sorted(groups[key], key=lambda r: (r.reward, -r.sample_idx))
        if g[-1].reward > g[0].reward:
            pairs.append((g[-1], g[0]))
    return pairs


def pack_pair_batch(pairs: Sequence[Tuple[Rollout, Rollout]], *,
                    pad_pairs: Optional[int] = None,
                    pad_len: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Pairs -> fixed-shape arrays for the jitted DPO step. pad_pairs /
    pad_len pin the batch shape across rounds (pair counts vary when ties
    are skipped) so the step never re-traces; pair_mask zeroes the
    padding rows out of the loss."""
    P = len(pairs) if pad_pairs is None else pad_pairs
    if P < len(pairs):
        raise ValueError(f"pad_pairs {P} < {len(pairs)} pairs")
    chosen = [c for c, _ in pairs]
    rejected = [r for _, r in pairs]
    S = max((len(x.prompt) + len(x.tokens) for x in chosen + rejected),
            default=2)
    S = max(S, pad_len or 0)
    ct, cm = pack_sequences(chosen, pad_len=S)
    rt, rm = pack_sequences(rejected, pad_len=S)

    def _pad(a, rows):
        out = np.zeros((P,) + a.shape[1:], a.dtype)
        out[:rows] = a
        return out

    pm = np.zeros((P,), np.float32)
    pm[:len(pairs)] = 1.0
    return {"chosen": _pad(ct, len(pairs)), "chosen_mask": _pad(cm, len(pairs)),
            "rejected": _pad(rt, len(pairs)),
            "rejected_mask": _pad(rm, len(pairs)), "pair_mask": pm}


# -- log-probs ----------------------------------------------------------------

def completion_logprobs(params, tokens, mask, cfg, env) -> jnp.ndarray:
    """Summed log p(completion | prompt) per row ([B]).

    Same vocab-padding treatment as Mo.lm_loss: iota comparison masks the
    padded columns from the partition function and selects the label
    column without a gather over the sharded vocab dim.
    """
    logits, _, _ = Mo.forward(params, tokens[:, :-1], cfg, env, mode="train")
    labels = tokens[:, 1:]
    vp = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    viota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, vp), 2)
    lf = jnp.where(viota < cfg.vocab_size, lf, -1e30)
    logz = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.sum(jnp.where(viota == labels[..., None], lf, 0.0), axis=-1)
    return jnp.sum((ll - logz) * mask, axis=-1)


# -- the trainer --------------------------------------------------------------

class PreferenceTrainer:
    """DPO over the serving model's params with a frozen reference.

    The reference is a snapshot of the params at construction — the
    standard DPO anchor keeping the policy near its rollout distribution.
    step() is jitted once per batch shape; adamw_update returns params in
    the same tree structure and dtype as the serving copy, so
    engine.set_params(trainer.params) swaps them in without re-jit.
    """

    def __init__(self, cfg, params, *, env: Optional[Env] = None,
                 beta: float = 0.5, opt: Optional[AdamWConfig] = None):
        self.cfg = cfg
        self.env = env if env is not None else Env(mesh=None, plan=SERVE_PLAN)
        self.params = params
        self.ref_params = jax.tree_util.tree_map(
            lambda x: jnp.array(x, copy=True), params)
        self.beta = beta
        self.opt_cfg = opt if opt is not None else AdamWConfig(
            lr=1e-3, warmup_steps=0, total_steps=100, weight_decay=0.0)
        self.opt_state = adamw_init(params, self.opt_cfg)
        self.steps_done = 0
        # fleet-shared compile: every trainer with the same (model, plan,
        # mesh, beta, optimizer) config reuses one traced DPO step
        self._step = shared_jit(
            ("dpo_step", self.cfg, self.env.plan, self.env.mesh,
             self.beta, self.opt_cfg),
            self._build_step)

    def _build_step(self):
        cfg, env, beta, ocfg = self.cfg, self.env, self.beta, self.opt_cfg

        def loss_fn(params, ref, batch):
            pi_c = completion_logprobs(params, batch["chosen"],
                                       batch["chosen_mask"], cfg, env)
            pi_r = completion_logprobs(params, batch["rejected"],
                                       batch["rejected_mask"], cfg, env)
            rf_c = completion_logprobs(ref, batch["chosen"],
                                       batch["chosen_mask"], cfg, env)
            rf_r = completion_logprobs(ref, batch["rejected"],
                                       batch["rejected_mask"], cfg, env)
            margin = (pi_c - rf_c) - (pi_r - rf_r)
            pm = batch["pair_mask"]
            n = jnp.maximum(jnp.sum(pm), 1.0)
            loss = jnp.sum(-jax.nn.log_sigmoid(beta * margin) * pm) / n
            return loss, jnp.sum(margin * pm) / n

        def step(params, ref, opt_state, batch):
            (loss, margin), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, ref, batch)
            new_params, new_state = adamw_update(grads, opt_state, ocfg)
            return new_params, new_state, loss, margin

        return step

    def step(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        """One optimizer step on a packed pair batch."""
        self.params, self.opt_state, loss, margin = self._step(
            self.params, self.ref_params, self.opt_state, batch)
        self.steps_done += 1
        return {"train_loss": float(loss), "dpo_margin": float(margin)}

    def train(self, pairs: Sequence[Tuple[Rollout, Rollout]], *,
              steps: int = 1, pad_pairs: Optional[int] = None,
              pad_len: Optional[int] = None) -> Dict[str, float]:
        """`steps` optimizer steps on one packed batch of pairs. Returns
        the first/last losses (the loop's train_loss-decreasing check) and
        the final margin. No pairs (all ties) is a no-op round."""
        if not pairs:
            return {"train_loss": 0.0, "train_loss_first": 0.0,
                    "dpo_margin": 0.0, "pairs_per_round": 0.0}
        batch = pack_pair_batch(pairs, pad_pairs=pad_pairs, pad_len=pad_len)
        first = last = None
        for _ in range(max(steps, 1)):
            m = self.step(batch)
            first = m if first is None else first
            last = m
        return {"train_loss": last["train_loss"],
                "train_loss_first": first["train_loss"],
                "dpo_margin": last["dpo_margin"],
                "pairs_per_round": float(len(pairs))}
