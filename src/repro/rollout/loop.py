"""RolloutLoop — generate -> score -> train rounds on one VirtualCluster.

The loop alternates phases on the *same* cluster the serving fleet runs
on, which is the point: during a generate phase the engine's live
snapshots stream through the registry KV and the autoscaler grows the
fleet into the rollout burst; during a train phase the serve queue is
empty, the loop publishes its own phase metrics (rollout_tokens,
reward_mean, pairs_per_round, train_loss) under the "rollout" source, and
the very same policy reads them next to the idle serve signals and hands
capacity back — serve and train arbitrate through one metrics plane, no
side channel.

After each train phase the freshly stepped params are pushed into every
replica (engine.set_params), so round r+1's rollouts sample from the
round-r policy — the minimal on-policy post-training loop.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.rollout.engine import RolloutEngine
from repro.rollout.preference import PreferenceTrainer, build_pairs
from repro.rollout.scorer import Scorer

# the four phase metrics the autoscaler aggregates (core/autoscaler.py):
# token/pair counters sum across sources, reward/loss levels average
PHASE_METRICS = ("rollout_tokens", "reward_mean", "pairs_per_round",
                 "train_loss")


class RolloutLoop:
    def __init__(self, cluster, rollout_engine: RolloutEngine,
                 scorer: Scorer, trainer: PreferenceTrainer, *,
                 prompts: Sequence[np.ndarray], dt: float = 0.05,
                 turns: int = 1, train_steps: int = 2,
                 train_phase_s: float = 0.2, on_step=None):
        self.cluster = cluster
        self.rollouts = rollout_engine
        self.scorer = scorer
        self.trainer = trainer
        self.prompts = list(prompts)
        self.dt = dt
        self.turns = turns
        self.train_steps = train_steps
        self.train_phase_s = train_phase_s
        self.on_step = on_step
        self.history: List[Dict[str, float]] = []

    @property
    def engine(self):
        return self.rollouts.engine

    def _publish(self, phase: Dict[str, float]) -> None:
        """Push the phase metrics into the registry KV as the "rollout"
        source and pump the control plane through the simulated train
        time — the autoscaler decides with the rollout numbers in view
        while the serve queue reads idle."""
        head = self.cluster.sim.nodes[self.cluster.head_id].agent
        head.report_serving(phase, source="rollout")
        self.cluster.pump(dt=self.train_phase_s, autoscale=True)
        reconcile = getattr(self.engine, "reconcile", None)
        if reconcile is not None:
            n = max(len(self.cluster.current_view().compute), 1)
            reconcile(n)

    def round(self) -> Dict[str, float]:
        """One generate -> score -> train round. Returns the phase
        metrics (also appended to history and published to the KV)."""
        ros = self.rollouts.generate(self.prompts, cluster=self.cluster,
                                     dt=self.dt, turns=self.turns,
                                     on_step=self.on_step)
        rewards = self.scorer.score(ros)
        for r, w in zip(ros, rewards):
            r.reward = float(w)
        pairs = build_pairs(ros)
        # pad to the max possible pair count / context length so the jitted
        # DPO step keeps one shape across rounds
        pad_len = max(len(r.prompt) + len(r.tokens) for r in ros)
        tm = self.trainer.train(pairs, steps=self.train_steps,
                                pad_pairs=len(self.prompts) * self.turns,
                                pad_len=pad_len)
        if pairs:
            self.engine.set_params(self.trainer.params)
        phase = {
            "rollout_tokens": float(self.rollouts.last_tokens),
            "reward_mean": float(np.mean(rewards)) if rewards else 0.0,
            "pairs_per_round": tm["pairs_per_round"],
            "train_loss": tm["train_loss"],
        }
        self._publish(phase)
        self.history.append({**phase,
                             "train_loss_first": tm["train_loss_first"],
                             "dpo_margin": tm["dpo_margin"],
                             "n_rollouts": float(len(ros))})
        return phase

    def run(self, rounds: int = 2) -> List[Dict[str, float]]:
        for _ in range(rounds):
            self.round()
        return self.history[-rounds:]

    def retire(self) -> None:
        """Tombstone the "rollout" metric source (loop is done for good)
        so its last snapshot stops skewing fleet aggregates."""
        head = self.cluster.sim.nodes[self.cluster.head_id].agent
        head.retire_source("rollout")
