from repro.data.pipeline import (  # noqa: F401
    MemmapCorpus,
    ShardedLoader,
    SyntheticLM,
    add_modality_stubs,
)
