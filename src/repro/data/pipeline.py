"""Data pipeline: deterministic synthetic streams + memmap corpus, sharded.

Determinism contract (tested with hypothesis): batch(seed, step) is a pure
function, and distinct data-parallel shards draw disjoint slices of it —
so elastic resharding replays identically regardless of cluster size, and a
restarted run resumes the exact stream from its checkpointed step.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.env import Env


def _philox(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.Generator(
        np.random.Philox(key=[seed * 0x9E3779B9 + step, shard]))


@dataclass(frozen=True)
class SyntheticLM:
    """Markov-ish synthetic token stream (not uniform noise: loss can drop)."""
    vocab_size: int
    seq_len: int
    seed: int = 0

    def batch_np(self, step: int, batch: int, shard: int = 0,
                 n_shards: int = 1) -> Dict[str, np.ndarray]:
        assert batch % n_shards == 0
        local = batch // n_shards
        g = _philox(self.seed, step, shard)
        # structured stream: tokens_t+1 = (a*tokens_t + drift) % V with noise
        base = g.integers(0, self.vocab_size, size=(local, 1))
        drift = g.integers(1, 7, size=(local, 1))
        idx = np.arange(self.seq_len + 1)[None, :]
        toks = (base + drift * idx) % self.vocab_size
        noise_mask = g.random((local, self.seq_len + 1)) < 0.1
        noise = g.integers(0, self.vocab_size, size=(local, self.seq_len + 1))
        toks = np.where(noise_mask, noise, toks).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapCorpus:
    """Flat token file + sampled windows (the 'real corpus' path)."""

    def __init__(self, path: str, seq_len: int, seed: int = 0):
        self.path = path
        self.seq_len = seq_len
        self.seed = seed
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        assert len(self.tokens) > seq_len + 1, "corpus too small"

    @staticmethod
    def write(path: str, tokens: np.ndarray) -> "None":
        tokens.astype(np.int32).tofile(path)

    def batch_np(self, step: int, batch: int, shard: int = 0,
                 n_shards: int = 1) -> Dict[str, np.ndarray]:
        assert batch % n_shards == 0
        local = batch // n_shards
        g = _philox(self.seed, step, shard)
        starts = g.integers(0, len(self.tokens) - self.seq_len - 1, size=local)
        rows = np.stack([np.asarray(self.tokens[s:s + self.seq_len + 1])
                         for s in starts])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def add_modality_stubs(batch: Dict[str, np.ndarray], cfg: ModelConfig,
                       step: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """Precomputed frame/patch embeddings per the assignment (stub frontends)."""
    B, S = batch["tokens"].shape
    g = _philox(seed + 7, step, 0)
    if cfg.family == "vlm":
        batch["vision_embeds"] = g.standard_normal(
            (B, cfg.num_vision_embeds, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.is_encdec:
        batch["frames"] = g.standard_normal(
            (B, max(S // cfg.enc_downsample, 1), cfg.d_model)
        ).astype(np.float32) * 0.02
    return batch


class ShardedLoader:
    """Places global batches on the mesh with the input sharding.

    Single-process: materializes the global batch and device_puts it with a
    NamedSharding (jax slices per device); on a multi-host deployment each
    host would build only its addressable shards (same seed/step contract).
    """

    def __init__(self, source, cfg: ModelConfig, shape: ShapeConfig, env: Env,
                 seed: int = 0):
        self.source = source
        self.cfg = cfg
        self.shape = shape
        self.env = env
        self.seed = seed

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        b = self.source.batch_np(step, self.shape.global_batch)
        b = add_modality_stubs(dict(b), self.cfg, step, self.seed)
        env = self.env
        if env.mesh is None:
            return {k: jnp.asarray(v) for k, v in b.items()}
        dp = env.dpx if self.shape.global_batch % max(env.dp, 1) == 0 else None
        out = {}
        for k, v in b.items():
            sh = env.sharding(dp, *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(jnp.asarray(v), sh)
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
