"""Model assembly: one functional implementation drives all 10 architectures.

A model is `embed -> scan(repeating pattern unit) -> tail -> norm -> unembed`.
The pattern unit is a tuple of sub-blocks (cfg.block_pattern), so uniform
archs scan single blocks and recurrentgemma scans (rglru, rglru, local)
units. Whisper adds an encoder stack and cross-attention; qwen2-vl prepends
stubbed vision embeddings and uses M-RoPE.

Modes:
  train/prefill: full-sequence forward. prefill also emits KV/state caches.
  decode:        single-token step with carried caches (cur_len scalar).

Parameters and caches for scanned units are stacked on a leading num_blocks
dim; cost_analysis sees the unit body once (roofline composes the rest —
DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R
from repro.models.env import Env, constrain, head_pad, kv_head_pad, vocab_pad

Pytree = Any

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(kind: str, key, cfg: ModelConfig, env: Env) -> dict:
    ks = jax.random.split(key, 4)
    z = lambda: jnp.zeros((cfg.d_model,), jnp.float32)
    if kind in ("attn", "enc", "local"):
        return {"ln1": z(), "attn": L.init_attention(ks[0], cfg, env),
                "ln2": z(), "mlp": L.init_mlp(ks[1], cfg)}
    if kind == "moe":
        return {"ln1": z(), "attn": L.init_attention(ks[0], cfg, env),
                "ln2": z(), "moe": M.init_moe(ks[1], cfg, env)}
    if kind == "dec":
        return {"ln1": z(), "attn": L.init_attention(ks[0], cfg, env),
                "lnx": z(), "xattn": L.init_attention(ks[1], cfg, env),
                "ln2": z(), "mlp": L.init_mlp(ks[2], cfg)}
    if kind == "rglru":
        return {"ln1": z(), "rec": R.init_rglru_block(ks[0], cfg, env),
                "ln2": z(), "mlp": L.init_mlp(ks[1], cfg)}
    if kind == "rwkv":
        return {"ln1": z(), "ln2": z(), "mix": R.init_rwkv_block(ks[0], cfg, env)}
    raise ValueError(kind)


def init_params(key, cfg: ModelConfig, env: Env) -> Pytree:
    kE, kU, kB, kT, kEnc = jax.random.split(key, 5)
    d, vp = cfg.d_model, vocab_pad(cfg, env)
    pattern = cfg.block_pattern

    def init_unit(k):
        return tuple(
            _init_block(kind, kk, cfg, env)
            for kind, kk in zip(pattern, jax.random.split(k, len(pattern)))
        )

    params: Dict[str, Pytree] = {
        "embed": L.dense_init(kE, vp, d).reshape(vp, d),
        "blocks": jax.vmap(init_unit)(jax.random.split(kB, cfg.num_blocks)),
        "tail": tuple(
            _init_block(kind, kk, cfg, env)
            for kind, kk in zip(cfg.pattern_tail,
                                jax.random.split(kT, max(len(cfg.pattern_tail), 1)))
        ),
        "final_norm": jnp.zeros((d,), jnp.float32),
        "unembed": L.dense_init(kU, d, vp),
    }
    if cfg.is_encdec:
        def init_enc(k):
            return (_init_block("enc", k, cfg, env),)
        params["enc_blocks"] = jax.vmap(init_enc)(
            jax.random.split(kEnc, cfg.encoder_layers))
        params["enc_norm"] = jnp.zeros((d,), jnp.float32)
    return params


def count_params(cfg: ModelConfig, env: Env, padded: bool = True) -> int:
    """Exact parameter count from shapes (via eval_shape — no allocation)."""
    import math
    shapes = jax.eval_shape(
        lambda k: init_params(k, cfg, env), jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))
    if not padded:
        d, hd = cfg.d_model, cfg.head_dim
        dh = head_pad(cfg, env) - cfg.n_heads
        dv = vocab_pad(cfg, env) - cfg.vocab_size
        n_attn = sum(k in ("attn", "moe", "local", "enc") for k in
                     cfg.block_pattern) * cfg.num_blocks
        n_attn += sum(k in ("attn", "moe", "local") for k in cfg.pattern_tail)
        n_attn += cfg.encoder_layers + 2 * (cfg.block_pattern.count("dec")
                                            * cfg.num_blocks)
        total -= n_attn * 2 * dh * hd * d  # padded wq + wo rows
        total -= 2 * dv * d  # padded embed/unembed rows
    return total


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _block_cache(kind: str, cfg: ModelConfig, B: int, smax: int,
                 enc_len: int = 0, env: Env = None) -> Optional[dict]:
    hkv, hd = (kv_head_pad(cfg, env) if env is not None
               else max(cfg.n_kv_heads, 1)), cfg.head_dim
    if kind in ("attn", "moe", "enc"):
        return {"k": jnp.zeros((B, hkv, smax, hd), jnp.bfloat16),
                "v": jnp.zeros((B, hkv, smax, hd), jnp.bfloat16)}
    if kind == "dec":
        return {"k": jnp.zeros((B, hkv, smax, hd), jnp.bfloat16),
                "v": jnp.zeros((B, hkv, smax, hd), jnp.bfloat16),
                "xk": jnp.zeros((B, hkv, enc_len, hd), jnp.bfloat16),
                "xv": jnp.zeros((B, hkv, enc_len, hd), jnp.bfloat16)}
    if kind == "local":
        w = min(cfg.local_window, smax)
        return {"k": jnp.zeros((B, hkv, w, hd), jnp.bfloat16),
                "v": jnp.zeros((B, hkv, w, hd), jnp.bfloat16)}
    if kind == "rglru":
        return R.rglru_init_state(cfg, B)
    if kind == "rwkv":
        return R.rwkv_init_state(cfg, B)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, env: Env, batch: int, max_len: int) -> Pytree:
    """Stacked (scan-compatible) cache pytree."""
    enc_len = max_len // cfg.enc_downsample if cfg.is_encdec else 0

    def unit_cache(_):
        return tuple(_block_cache(k, cfg, batch, max_len, enc_len, env)
                     for k in cfg.block_pattern)

    stacked = jax.vmap(unit_cache)(jnp.arange(cfg.num_blocks))
    tail = tuple(_block_cache(k, cfg, batch, max_len, enc_len, env)
                 for k in cfg.pattern_tail)
    return {"blocks": stacked, "tail": tail}


def _unit_kind(path, cfg: ModelConfig) -> str:
    """Block kind of a cache leaf from its tree path.

    Cache pytrees are {"blocks": (per-kind dicts, stacked), "tail": (...)},
    so path[0] names the group and path[1] is the index into the pattern."""
    top = str(path[0].key) if hasattr(path[0], "key") else ""
    i = getattr(path[1], "idx", None) if len(path) > 1 else None
    pattern = cfg.block_pattern if top == "blocks" else cfg.pattern_tail
    if i is None or i >= len(pattern):
        return ""
    return pattern[i]


def grow_caches(caches: Pytree, extra: int,
                cfg: Optional[ModelConfig] = None) -> Pytree:
    """Extend prefill-emitted KV caches (length == prompt) by `extra` slots
    so decode can append. Cross-attention caches (xk/xv) keep their length;
    recurrent states have no seq dim and pass through. With `cfg`,
    sliding-window ('local') ring caches grow only to the window size
    (min(w, prompt + extra)) — a full ring must never be padded, or the
    slot = pos % w alignment breaks."""
    def grow(path, x):
        leaf = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if leaf not in ("k", "v") or x.ndim < 4 or x.dtype != jnp.bfloat16:
            return x
        pad_n = extra
        if cfg is not None and _unit_kind(path, cfg) == "local":
            cur = x.shape[-2]
            pad_n = max(min(cfg.local_window, cur + extra) - cur, 0)
            if pad_n == 0:
                return x
        pad = [(0, 0)] * x.ndim
        pad[-2] = (0, pad_n)
        return jnp.pad(x, pad)

    return jax.tree_util.tree_map_with_path(grow, caches)


# ---------------------------------------------------------------------------
# slot-pool cache ops (continuous batching: serve/slots.py)
#
# A pooled cache is an ordinary init_cache() pytree whose batch dim is the
# slot dim. Layout (see init_cache): leaves under "blocks" are stacked
# [num_blocks, B, ...]; leaves under "tail" are [B, ...] — so the slot axis
# is 1 for blocks and 0 for tail. All three ops are jit-safe with a traced
# slot index, so admitting a request never re-compiles.
# ---------------------------------------------------------------------------


def cache_insert_slot(pool: Pytree, request: Pytree, slot) -> Pytree:
    """Write a single-request (batch-1) cache pytree into `slot` of a pooled
    cache. The request cache must already be grown to the pool's seq length
    (grow_caches). Every leaf of the slot is overwritten, so freed slots need
    no zeroing before reuse."""
    def ins(axis):
        def f(dst, src):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=axis)
        return f

    return {"blocks": jax.tree.map(ins(1), pool["blocks"], request["blocks"]),
            "tail": jax.tree.map(ins(0), pool["tail"], request["tail"])}


def cache_evict_slot(pool: Pytree, slot) -> Pytree:
    """Zero `slot` of a pooled cache (hygiene / tests; insert fully
    overwrites, so eviction is logically just freeing the slot)."""
    def z(axis):
        def f(x):
            shp = list(x.shape)
            shp[axis] = 1
            return jax.lax.dynamic_update_slice_in_dim(
                x, jnp.zeros(shp, x.dtype), slot, axis=axis)
        return f

    return {"blocks": jax.tree.map(z(1), pool["blocks"]),
            "tail": jax.tree.map(z(0), pool["tail"])}


def cache_read_slot(pool: Pytree, slot) -> Pytree:
    """Extract `slot` as a batch-1 cache pytree (inverse of insert)."""
    def rd(axis):
        def f(x):
            return jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=axis)
        return f

    return {"blocks": jax.tree.map(rd(1), pool["blocks"]),
            "tail": jax.tree.map(rd(0), pool["tail"])}


# ---------------------------------------------------------------------------
# paged cache ops (block tables: serve/blocks.py)
#
# A paged cache replaces the per-slot seq dim with a global pool of
# fixed-size KV blocks: attention k/v leaves are [num_blocks, Hkv, bs, hd]
# (stacked [L, num_blocks, Hkv, bs, hd] under "blocks"), shared by every
# request through per-request block tables; physical block 0 is a null
# block that absorbs the writes of masked rows and is never allocated.
# Recurrent state leaves (rglru/rwkv) have no seq dim and stay
# row-addressed [num_rows, ...] exactly like the slot pool.
# ---------------------------------------------------------------------------


PAGEABLE_KINDS = ("attn", "moe", "local")


def init_paged_cache(cfg: ModelConfig, env: Env, num_rows: int,
                     num_blocks: int, block_size: int,
                     quant: bool = False) -> Pytree:
    """Block-pooled decode cache (same {"blocks","tail"} structure as
    init_cache, so the decode scan consumes it unchanged).

    With quant=True, k/v blocks store int8 values plus per-row f32 dequant
    scales ([NB, hkv, bs] — one scale per (block, head, token) over the
    head dim). Roughly half the bytes per token of the bf16 pool; the
    decode path dispatches on the presence of "k_scale"."""
    hkv, hd = kv_head_pad(cfg, env), cfg.head_dim

    def blk(kind):
        if kind in PAGEABLE_KINDS:
            if quant:
                return {"k": jnp.zeros((num_blocks, hkv, block_size, hd),
                                       jnp.int8),
                        "v": jnp.zeros((num_blocks, hkv, block_size, hd),
                                       jnp.int8),
                        "k_scale": jnp.zeros((num_blocks, hkv, block_size),
                                             jnp.float32),
                        "v_scale": jnp.zeros((num_blocks, hkv, block_size),
                                             jnp.float32)}
            return {"k": jnp.zeros((num_blocks, hkv, block_size, hd),
                                   jnp.bfloat16),
                    "v": jnp.zeros((num_blocks, hkv, block_size, hd),
                                   jnp.bfloat16)}
        if kind == "rglru":
            return R.rglru_init_state(cfg, num_rows)
        if kind == "rwkv":
            return R.rwkv_init_state(cfg, num_rows)
        raise ValueError(f"block kind {kind!r} has no paged-cache layout "
                         "(enc/dec caches carry cross-attention state)")

    stacked = jax.vmap(lambda _: tuple(blk(k) for k in cfg.block_pattern))(
        jnp.arange(cfg.num_blocks))
    tail = tuple(blk(k) for k in cfg.pattern_tail)
    return {"blocks": stacked, "tail": tail}


def _paged_kv_op(pool, cfg: ModelConfig, kv_fn, state_fn):
    """tree-map a paged pool, dispatching k/v leaves (with their table kind)
    vs row-addressed state leaves. kv_fn(dst, is_local, is_scale, axis),
    state_fn(dst, axis) where axis is the leading stacked-layer offset (1
    under "blocks", 0 under "tail") and is_scale marks the quant pool's
    [NB,H,bs] scale leaves (no head_dim axis)."""
    def f(path, dst, *rest):
        kind = _unit_kind(path, cfg)
        axis = 1 if str(path[0].key) == "blocks" else 0
        if kind in PAGEABLE_KINDS:
            leaf = str(getattr(path[-1], "key", ""))
            return kv_fn(dst, kind == "local", leaf.endswith("_scale"),
                         axis, *rest)
        return state_fn(dst, axis, *rest)

    return f


def make_paged_insert(cfg: ModelConfig, block_size: int):
    """Jit-safe insert of a batch-1 prefill cache into a paged pool.

    k/v leaves are chunked into block_size pieces scattered at the slot's
    block-table entries (`tables` for global attention, `tables_local` for
    window rings — ring layout from prefill is preserved verbatim, so the
    pos % w alignment carries over); state leaves land at row `slot`.
    Unallocated table entries are 0, so padding chunks fall into the null
    block."""
    bs = block_size

    def kv(dst, is_local, is_scale, axis, src, slot, tables, tables_local):
        tbl = tables_local if is_local else tables
        sdim = -1 if is_scale else -2  # scale leaves: seq is the last axis
        S = src.shape[sdim]
        nb = -(-S // bs)
        pad = [(0, 0)] * src.ndim
        pad[sdim] = (0, nb * bs - S)
        src = jnp.pad(src, pad).astype(dst.dtype)
        if is_scale:
            if axis == 1:  # [L,1,H,nb*bs] -> chunks [L,nb,H,bs]
                L, _, H, _ = src.shape
                chunks = src.reshape(L, H, nb, bs).transpose(0, 2, 1, 3)
                return dst.at[:, tbl[:nb]].set(chunks)
            _, H, _ = src.shape
            chunks = src.reshape(H, nb, bs).transpose(1, 0, 2)
            return dst.at[tbl[:nb]].set(chunks)
        if axis == 1:  # [L,1,H,nb*bs,hd] -> chunks [L,nb,H,bs,hd]
            L, _, H, _, hd = src.shape
            chunks = src.reshape(L, H, nb, bs, hd).transpose(0, 2, 1, 3, 4)
            return dst.at[:, tbl[:nb]].set(chunks)
        _, H, _, hd = src.shape
        chunks = src.reshape(H, nb, bs, hd).transpose(1, 0, 2, 3)
        return dst.at[tbl[:nb]].set(chunks)

    def state(dst, axis, src, slot, tables, tables_local):
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), slot, axis=axis)

    def insert(pool, request, slot, tables, tables_local):
        f = _paged_kv_op(pool, cfg, kv, state)
        return jax.tree_util.tree_map_with_path(
            lambda p, d, s: f(p, d, s, slot, tables, tables_local),
            pool, request)

    return insert


def make_paged_copy(cfg: ModelConfig):
    """Copy one physical block's K/V (every layer, global and local tables
    alike) from block `src` to block `dst` — the copy-on-write step behind
    prefix sharing (serve/blocks.py): the first divergent write into a
    shared block lands in a fresh copy instead. Row-addressed recurrent
    state has no block dim and is untouched."""
    def kv(dst_pool, is_local, is_scale, axis, src, dst):
        if axis == 1:
            return dst_pool.at[:, dst].set(dst_pool[:, src])
        return dst_pool.at[dst].set(dst_pool[src])

    def state(dst_pool, axis, src, dst):
        return dst_pool

    def copy(pool, src, dst):
        f = _paged_kv_op(pool, cfg, kv, state)
        return jax.tree_util.tree_map_with_path(
            lambda p, d: f(p, d, src, dst), pool)

    return copy


def make_paged_evict(cfg: ModelConfig):
    """Zero a slot's blocks (and state row) in a paged pool — hygiene only;
    allocation hygiene lives in the BlockManager free list."""
    def kv(dst, is_local, is_scale, axis, slot, tables, tables_local):
        tbl = tables_local if is_local else tables
        if axis == 1:
            return dst.at[:, tbl].set(jnp.zeros((), dst.dtype))
        return dst.at[tbl].set(jnp.zeros((), dst.dtype))

    def state(dst, axis, slot, tables, tables_local):
        shp = list(dst.shape)
        shp[axis] = 1
        return jax.lax.dynamic_update_slice_in_dim(
            dst, jnp.zeros(shp, dst.dtype), slot, axis=axis)

    def evict(pool, slot, tables, tables_local):
        f = _paged_kv_op(pool, cfg, kv, state)
        return jax.tree_util.tree_map_with_path(
            lambda p, d: f(p, d, slot, tables, tables_local), pool)

    return evict


def make_paged_read(cfg: ModelConfig):
    """Gather one slot back out of a paged pool as a batch-1 cache pytree
    (inverse of insert, introspection/tests). `valid`/`valid_local` mask
    unallocated table entries so freed slots read as zeros regardless of
    what masked-row writes left in the null block."""
    def kv(dst, is_local, is_scale, axis, slot, tables, tables_local,
           valid, valid_l):
        tbl = tables_local if is_local else tables
        ok = (valid_l if is_local else valid).astype(dst.dtype)
        if is_scale:
            if axis == 1:
                g = dst[:, tbl] * ok[None, :, None, None]  # [L,MB,H,bs]
                L, MB, H, bs = g.shape
                return g.transpose(0, 2, 1, 3).reshape(L, 1, H, MB * bs)
            g = dst[tbl] * ok[:, None, None]  # [MB,H,bs]
            MB, H, bs = g.shape
            return g.transpose(1, 0, 2).reshape(1, H, MB * bs)
        if axis == 1:
            g = dst[:, tbl]  # [L,MB,H,bs,hd]
            g = g * ok[None, :, None, None, None]
            L, MB, H, bs, hd = g.shape
            return g.transpose(0, 2, 1, 3, 4).reshape(L, 1, H, MB * bs, hd)
        g = dst[tbl] * ok[:, None, None, None]  # [MB,H,bs,hd]
        MB, H, bs, hd = g.shape
        return g.transpose(1, 0, 2, 3).reshape(1, H, MB * bs, hd)

    def state(dst, axis, slot, tables, tables_local, valid, valid_l):
        return jax.lax.dynamic_slice_in_dim(dst, slot, 1, axis=axis)

    def read(pool, slot, tables, tables_local, valid, valid_local):
        f = _paged_kv_op(pool, cfg, kv, state)
        return jax.tree_util.tree_map_with_path(
            lambda p, d: f(p, d, slot, tables, tables_local, valid,
                           valid_local), pool)

    return read


def quantize_paged_request(cfg: ModelConfig, request: Pytree) -> Pytree:
    """Expand a batch-1 fp prefill cache ({"k","v"} per pageable unit) into
    the quant pool structure ({"k","v","k_scale","v_scale"}): symmetric
    int8 over the head dim, one f32 scale per (head, position). Makes the
    fp prefill output insertable into a quant pool via the generic
    make_paged_insert (structures become congruent)."""
    from repro.kernels.paged_decode.ops import quantize_kv

    def unit(kind, d):
        if kind in PAGEABLE_KINDS:
            kq, ks = quantize_kv(d["k"])
            vq, vs = quantize_kv(d["v"])
            return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        return d

    return {"blocks": tuple(unit(k, d) for k, d in
                            zip(cfg.block_pattern, request["blocks"])),
            "tail": tuple(unit(k, d) for k, d in
                          zip(cfg.pattern_tail, request["tail"]))}


def dequantize_paged_request(cfg: ModelConfig, request: Pytree) -> Pytree:
    """Inverse of quantize_paged_request (up to quantization error): fold
    the scales back into bf16 {"k","v"} units — what make_paged_read
    returns from a quant pool becomes comparable to an fp read."""
    def unit(kind, d):
        if kind in PAGEABLE_KINDS:
            return {"k": (d["k"].astype(jnp.float32)
                          * d["k_scale"][..., None]).astype(jnp.bfloat16),
                    "v": (d["v"].astype(jnp.float32)
                          * d["v_scale"][..., None]).astype(jnp.bfloat16)}
        return d

    return {"blocks": tuple(unit(k, d) for k, d in
                            zip(cfg.block_pattern, request["blocks"])),
            "tail": tuple(unit(k, d) for k, d in
                          zip(cfg.pattern_tail, request["tail"]))}


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _attn_sublayer(p, h, cfg: ModelConfig, env: Env, mode: str, positions,
                   cache, cur_len, *, window: int = 0, causal: bool = True,
                   x_kv=None, rope: bool = True, cross: bool = False,
                   block_tables=None, row_slots=None):
    """Self/cross attention sub-layer. Returns (out, new_cache_entries)."""
    if mode in ("train", "prefill"):
        q, k, v = L._project_qkv(p, h, h if x_kv is None else x_kv, cfg, env)
        if rope:
            ap = (functools.partial(L.apply_mrope, theta=cfg.rope_theta,
                                    sections=cfg.mrope_sections)
                  if cfg.mrope else
                  functools.partial(L.apply_rope, theta=cfg.rope_theta))
            q = ap(q, positions=positions)
            kpos = positions if x_kv is None else jnp.arange(k.shape[1])
            if cfg.mrope and x_kv is not None:
                kpos = positions  # cross-attn never used with mrope archs
            k = ap(k, positions=kpos)
        impl = env.plan.attn_impl
        Sq = q.shape[1]
        if impl == "xla_chunked" and Sq > env.plan.attn_q_chunk and x_kv is None:
            if window > 0:
                o = L.attention_window_prefill(q, k, v, cfg, env, window=window,
                                               q_chunk=env.plan.attn_q_chunk)
            else:
                o = L.attention_chunked(q, k, v, cfg, env, causal=causal,
                                        window=window,
                                        q_chunk=env.plan.attn_q_chunk,
                                        kv_chunk=env.plan.attn_kv_chunk)
        elif impl == "pallas" and Sq > 128 and x_kv is None:
            from repro.kernels.flash_attention import ops as fa_ops
            o = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                       n_kv_heads=max(cfg.n_kv_heads, 1))
        else:
            o = L.attention_naive(q, k, v, cfg, causal=causal and x_kv is None,
                                  window=window)
        o = constrain(o @ p["wo"], env,
                      *L.out_dims(env, o.shape[1]))
        new_cache = None
        if mode == "prefill" and cache is not None:
            kc = k.transpose(0, 2, 1, 3)  # [B,Hkv,S,hd]
            vc = v.transpose(0, 2, 1, 3)
            if window > 0:  # keep the trailing window, ring-aligned (slot=pos%w)
                S = kc.shape[2]
                w = min(window, S)
                kc = jnp.roll(kc[:, :, -w:], (S - w) % w, axis=2)
                vc = jnp.roll(vc[:, :, -w:], (S - w) % w, axis=2)
            if x_kv is None:
                if env.plan.kv_cache == "seq_sharded" and window == 0:
                    kc = constrain(kc, env, env.dpx, None, env.plan.tp_axis, None)
                    vc = constrain(vc, env, env.dpx, None, env.plan.tp_axis, None)
                new_cache = {"k": kc, "v": vc}
            else:
                new_cache = {"xk": kc, "xv": vc}
        return o, new_cache

    # ---- decode -----------------------------------------------------------
    # cur_len is a scalar (uniform batch) or a [B] vector (continuous
    # batching: every KV slot sits at its own write position).
    assert mode == "decode"
    B = h.shape[0]
    cl = jnp.asarray(cur_len)
    q, k, v = L._project_qkv(p, h, h, cfg, env)
    x_kv = "cached-cross" if cross else None
    if rope:
        pos = jnp.broadcast_to(cl.reshape(-1, 1), (B, 1))
        if cfg.mrope:
            q = L.apply_mrope(q, positions[:, None, :] if positions.ndim == 2
                              else positions, cfg.rope_theta, cfg.mrope_sections)
            k = L.apply_mrope(k, positions[:, None, :] if positions.ndim == 2
                              else positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
    if x_kv is not None:  # cross-attention over precomputed enc cache
        enc_len = cache["xk"].shape[2]
        o = L.attention_decode(q, cache["xk"], cache["xv"],
                               jnp.asarray(enc_len - 1, jnp.int32), cfg, env)
        return (constrain(o @ p["wo"], env, env.dpx, None, None),
                {"xk": cache["xk"], "xv": cache["xv"]})
    kc, vc = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)  # [B,Hkv,1,hd]
    if block_tables is not None:
        # paged cache: k/v live in a global block pool [NB,Hkv,bs,hd]; each
        # row writes one token into its own block (via its table) and
        # attends over the blocks the table names. Window ('local') layers
        # keep a ring of the trailing window — pos % w indexing, masked by
        # valid length; softmax over keys is permutation-invariant, so the
        # ring order needs no unscrambling.
        tbl = block_tables["local"] if window > 0 else block_tables["global"]
        bs = cache["k"].shape[-2]
        idx = cl % window if window > 0 else cl  # [B] write position
        phys = jnp.take_along_axis(tbl, (idx // bs)[:, None], axis=1)[:, 0]
        off = idx % bs
        eff = jnp.minimum(cl, window - 1) if window > 0 else cl
        if "k_scale" in cache:
            # quant pool: quantize-on-insert (this token's K/V row goes in
            # as int8 + per-row scale), dequant fused into the read path
            from repro.kernels.paged_decode import ops as pd_ops
            kq, ks = pd_ops.quantize_kv(kc[:, :, 0])  # [B,Hkv,hd] -> int8
            vq, vs = pd_ops.quantize_kv(vc[:, :, 0])
            new_k = cache["k"].at[phys, :, off].set(kq)
            new_v = cache["v"].at[phys, :, off].set(vq)
            new_ks = cache["k_scale"].at[phys, :, off].set(ks)
            new_vs = cache["v_scale"].at[phys, :, off].set(vs)
            if env.plan.attn_impl == "pallas":
                o = pd_ops.paged_flash_decode_quant(
                    q[:, 0], new_k, new_v, new_ks, new_vs, tbl, eff)
                o = o.reshape(B, 1, -1).astype(h.dtype)
            else:
                o = L.attention_paged_decode_quant(
                    q, new_k, new_v, new_ks, new_vs, tbl, eff, cfg, env)
            o = constrain(o @ p["wo"], env, env.dpx, None, None)
            return o, {"k": new_k, "v": new_v,
                       "k_scale": new_ks, "v_scale": new_vs}
        new_k = cache["k"].at[phys, :, off].set(
            kc[:, :, 0].astype(cache["k"].dtype))
        new_v = cache["v"].at[phys, :, off].set(
            vc[:, :, 0].astype(cache["v"].dtype))
        if env.plan.attn_impl == "pallas":
            from repro.kernels.paged_decode import ops as pd_ops
            o = pd_ops.paged_flash_decode(q[:, 0], new_k, new_v, tbl, eff)
            o = o.reshape(B, 1, -1).astype(h.dtype)
        else:
            o = L.attention_paged_decode(q, new_k, new_v, tbl, eff, cfg, env)
        o = constrain(o @ p["wo"], env, env.dpx, None, None)
        return o, {"k": new_k, "v": new_v}
    Sc = cache["k"].shape[2]
    if row_slots is not None:
        if window > 0:
            raise NotImplementedError(
                "row-slot indirection over a windowed ring cache")
        # row->slot indirection over the contiguous cache: T batch rows
        # write into (and attend over) num_slots cache rows, several rows
        # may share one slot at distinct depths (speculative verify lanes).
        # Masked rows (slot < 0) write at (slot 0, Sc-1): a live request's
        # last real write position is Sc-2 (cur_len = prompt+gen-1 at the
        # final step) and attention depth never reaches Sc-1, so the tail
        # position is the contiguous analogue of the paged null block.
        rs = jnp.asarray(row_slots)
        live = rs >= 0
        slot = jnp.where(live, rs, 0)
        idx = jnp.where(live, cl, Sc - 1)
        new_k = cache["k"].at[slot, :, idx].set(
            kc[:, :, 0].astype(cache["k"].dtype))
        new_v = cache["v"].at[slot, :, idx].set(
            vc[:, :, 0].astype(cache["v"].dtype))
        o = L.attention_decode(q, new_k[slot], new_v[slot], cl, cfg, env)
        o = constrain(o @ p["wo"], env, env.dpx, None, None)
        return o, {"k": new_k, "v": new_v}
    idx = cl % Sc if window > 0 else cl
    if cl.ndim:  # per-row write positions: masked write along the seq dim
        oh = (jax.lax.broadcasted_iota(jnp.int32, (B, 1, Sc, 1), 2)
              == idx[:, None, None, None])
        new_k = jnp.where(oh, kc, cache["k"])
        new_v = jnp.where(oh, vc, cache["v"])
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], kc, idx, axis=2)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], vc, idx, axis=2)
    if env.plan.kv_cache == "seq_sharded":
        new_k = constrain(new_k, env, env.dpx, None, env.plan.tp_axis, None)
        new_v = constrain(new_v, env, env.dpx, None, env.plan.tp_axis, None)
    if window > 0:
        # ring buffer: every stored entry is within the window; mask validity
        w = cache["k"].shape[2]
        valid_up_to = jnp.minimum(cur_len, w - 1)
        o = L.attention_decode(q, new_k, new_v, valid_up_to, cfg, env)
    else:
        o = L.attention_decode(q, new_k, new_v, cur_len, cfg, env)
    o = constrain(o @ p["wo"], env, env.dpx, None, None)
    return o, {"k": new_k, "v": new_v}


def _sp(h, env: Env, mode: str):
    """Sequence-parallel residual constraint: turns the TP all-reduce of the
    preceding row-sharded matmul into reduce-scatter + bf16 all-gather."""
    if (env.plan.seq_shard_acts and mode == "train" and env.tp > 1
            and h.shape[1] % env.tp == 0):
        return constrain(h, env, env.dpx, env.plan.tp_axis, None)
    return h


def _apply_block(kind: str, p, h, cfg: ModelConfig, env: Env, mode: str,
                 positions, cache, cur_len, enc_out=None, block_tables=None,
                 row_slots=None):
    """One sub-block. Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps
    if kind in ("attn", "moe", "local", "enc"):
        window = cfg.local_window if kind == "local" else 0
        causal = kind != "enc"
        a, nc = _attn_sublayer(p["attn"], L.rms_norm(h, p["ln1"], eps), cfg, env,
                               mode if kind != "enc" else "train",
                               positions, cache, cur_len,
                               window=window, causal=causal,
                               block_tables=block_tables,
                               row_slots=row_slots)
        h = _sp(h + a, env, mode)
        hn = L.rms_norm(h, p["ln2"], eps)
        if kind == "moe":
            y, aux = M.moe_layer(p["moe"], hn, cfg, env)
        else:
            y = L.mlp(p["mlp"], hn, env)
        return h + y, nc, aux
    if kind == "dec":
        a, nc1 = _attn_sublayer(p["attn"], L.rms_norm(h, p["ln1"], eps), cfg, env,
                                mode, positions, cache, cur_len)
        h = h + a
        a, nc2 = _attn_sublayer(p["xattn"], L.rms_norm(h, p["lnx"], eps), cfg, env,
                                mode, positions, cache, cur_len,
                                x_kv=enc_out, rope=False, causal=False,
                                cross=True)
        h = h + a
        y = L.mlp(p["mlp"], L.rms_norm(h, p["ln2"], eps), env)
        nc = {**(nc1 or {}), **(nc2 or {})} or None
        return h + y, nc, aux
    if kind == "rglru":
        st = cache if mode == "decode" else None
        y, ns = R.rglru_block(p["rec"], L.rms_norm(h, p["ln1"], eps), cfg, env,
                              st, return_state=(mode == "prefill"))
        h = _sp(h + y, env, mode)
        y = L.mlp(p["mlp"], L.rms_norm(h, p["ln2"], eps), env)
        return h + y, ns, aux
    if kind == "rwkv":
        st = cache if mode == "decode" else None
        rs = mode == "prefill"
        y, ns_tm = R.rwkv_time_mix(p["mix"], L.rms_norm(h, p["ln1"], eps),
                                   cfg, env, st, return_state=rs)
        h = _sp(h + y, env, mode)
        y, ns_cm = R.rwkv_channel_mix(p["mix"], L.rms_norm(h, p["ln2"], eps),
                                      cfg, env, st, return_state=rs)
        h = h + y
        nc = None
        if mode in ("decode", "prefill") and ns_tm is not None:
            nc = {**ns_tm, "cm_prev": ns_cm}
        return h, nc, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack runner
# ---------------------------------------------------------------------------


def _remat_wrap(fn, env: Env):
    if env.plan.remat == "nothing":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if env.plan.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _run_stack(stacked, tail, h, cfg: ModelConfig, env: Env, mode: str,
               positions, caches=None, cur_len=None, enc_out=None,
               pattern: Optional[Tuple[str, ...]] = None, block_tables=None,
               row_slots=None):
    """Scan the repeating unit, then run the unrolled tail.

    Returns (h, new_caches, aux). caches/new_caches structure:
    {"blocks": stacked-per-unit tuple, "tail": tuple} or None.
    """
    pattern = cfg.block_pattern if pattern is None else pattern
    use_cache = mode in ("prefill", "decode")

    def apply_unit(hh, p_unit, c_unit):
        aux = jnp.zeros((), jnp.float32)
        ncs = []
        for i, kind in enumerate(pattern):
            if mode == "decode":
                c = c_unit[i]
            elif mode == "prefill":
                c = {}
            else:
                c = None
            hh, nc, a = _apply_block(kind, p_unit[i], hh, cfg, env, mode,
                                     positions, c, cur_len, enc_out,
                                     block_tables, row_slots)
            aux = aux + a
            ncs.append(nc)
        return hh, (tuple(ncs) if use_cache else 0), aux

    apply_unit_w = _remat_wrap(apply_unit, env) if mode == "train" else apply_unit
    trip = jax.tree.leaves(stacked)[0].shape[0]

    if mode == "decode" and caches is not None:
        xs = (stacked, caches["blocks"])
    else:
        xs = (stacked, jnp.zeros((trip,), jnp.int32))

    sp = (env.plan.seq_shard_acts and mode == "train" and env.tp > 1
          and h.shape[1] % env.tp == 0)

    def body(carry, xs_):
        p_unit, c_unit = xs_
        hh, aux = carry
        hh, ncs, a = apply_unit_w(hh, p_unit,
                                  c_unit if mode == "decode" else None)
        if sp:  # sequence-parallel residual stream between units
            hh = constrain(hh, env, env.dpx, env.plan.tp_axis, None)
        return (hh, aux + a), ncs

    if (env.plan.seq_shard_acts and mode == "train" and env.tp > 1
            and h.shape[1] % env.tp == 0):
        h = constrain(h, env, env.dpx, env.plan.tp_axis, None)
    (h, aux), ys = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), xs,
                                unroll=env.plan.scan_unroll)

    new_tail = []
    tail_caches = (caches or {}).get("tail", ())
    for i, kind in enumerate(pattern if tail is None else
                             cfg.pattern_tail):
        if mode == "decode":
            c = tail_caches[i]
        elif mode == "prefill":
            c = {}
        else:
            c = None
        h, nc, a = _apply_block(kind, tail[i], h, cfg, env, mode, positions, c,
                                cur_len, enc_out, block_tables, row_slots)
        aux = aux + a
        new_tail.append(nc)

    new_caches = {"blocks": ys, "tail": tuple(new_tail)} if use_cache else None
    return h, new_caches, aux


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def build_mrope_positions(S: int, nv: int, cur_len=None):
    """Qwen2-VL 3D positions: vision patches on a (h,w) grid at t=0; text
    continues linearly from grid+index. Returns [1,S,3] (or [1,1,3] decode)."""
    g = max(int(math_isqrt(nv)), 1)
    if cur_len is not None:
        p = g + cur_len - nv
        return jnp.broadcast_to(p, (1, 1, 3)).astype(jnp.int32)
    idx = jnp.arange(S)
    is_vis = idx < nv
    t = jnp.where(is_vis, 0, g + idx - nv)
    hh = jnp.where(is_vis, idx // g, g + idx - nv)
    ww = jnp.where(is_vis, idx % g, g + idx - nv)
    return jnp.stack([t, hh, ww], -1)[None].astype(jnp.int32)


def math_isqrt(n: int) -> int:
    import math
    return math.isqrt(max(n, 0))


def forward(params, tokens, cfg: ModelConfig, env: Env, mode: str = "train",
            caches=None, cur_len=None, vision_embeds=None, frames=None,
            block_tables=None, row_slots=None):
    """tokens: [B,S] int32 (decode: [B,1]).

    vision_embeds: [B,Nv,d] (vlm stub), frames: [B,Se,d] (whisper stub).
    block_tables (decode only): {"global": [B,MB], "local": [B,MBw]} int32
    block tables into a paged cache (init_paged_cache); cur_len must then be
    a [B] vector. row_slots (decode only, contiguous cache): [B] int32
    mapping batch rows to cache slot rows (-1 masks the row) — several rows
    may target one slot at distinct cur_len depths (speculative verify).
    Returns (logits [B,S,Vpad], new_caches, aux).
    """
    h = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    h = constrain(h, env, env.dpx, None, None)
    B, S = tokens.shape

    positions = jnp.arange(S)
    enc_out = None

    if cfg.family == "vlm" and mode != "decode":
        assert vision_embeds is not None
        h = jnp.concatenate([vision_embeds.astype(h.dtype), h], axis=1)
        h = constrain(h, env, env.dpx, None, None)
        S = h.shape[1]
        positions = build_mrope_positions(S, cfg.num_vision_embeds)
    elif cfg.family == "vlm":
        positions = build_mrope_positions(1, cfg.num_vision_embeds,
                                          cur_len=cur_len)
    elif mode == "decode":
        positions = None  # per-sublayer from cur_len

    if cfg.is_encdec and mode != "decode":
        assert frames is not None
        eo = constrain(frames.astype(h.dtype), env, env.dpx, None, None)
        enc_pos = jnp.arange(eo.shape[1])
        eo, _, _ = _run_stack(params["enc_blocks"], (), eo, cfg, env, "train",
                              enc_pos, pattern=("enc",))
        enc_out = L.rms_norm(eo, params["enc_norm"], cfg.norm_eps)

    h, new_caches, aux = _run_stack(params["blocks"], params["tail"], h, cfg,
                                    env, mode, positions, caches, cur_len,
                                    enc_out, block_tables=block_tables,
                                    row_slots=row_slots)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["unembed"]
    logits = constrain(logits, env, env.dpx, None, env.plan.tp_axis)
    return logits, new_caches, aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(params, batch, cfg: ModelConfig, env: Env, aux_weight: float = 0.01):
    """batch: {"tokens": [B,S], "labels": [B,S]} (+ modality stubs).

    Cross-entropy over the (vocab-padded, possibly TP-sharded) logits, with
    padded vocab columns masked via an iota comparison (GSPMD-friendly: no
    gather over the sharded vocab dim).
    """
    logits, _, aux = forward(params, batch["tokens"], cfg, env, mode="train",
                             vision_embeds=batch.get("vision_embeds"),
                             frames=batch.get("frames"))
    labels = batch["labels"]
    if cfg.family == "vlm":  # loss over the text region only
        logits = logits[:, cfg.num_vision_embeds:]
    vp = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    viota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, vp), 2)
    lf = jnp.where(viota < cfg.vocab_size, lf, -1e30)
    logz = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.sum(jnp.where(viota == labels[..., None], lf, 0.0), axis=-1)
    loss = jnp.mean(logz - ll)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}
