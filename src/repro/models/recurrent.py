"""Recurrent layers: Griffin RG-LRU block and RWKV-6 (Finch) time/channel mix.

Training/prefill use parallel forms (associative scan for RG-LRU, chunked
recurrence for RWKV-6); decode uses the exact single-step recurrences with
explicit carried state. The Pallas kernels (kernels/rglru, kernels/rwkv6)
are the TPU-tiled versions of the same math, validated against these.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.env import Env, constrain, out_dims
from repro.models.layers import dense_init

# ---------------------------------------------------------------------------
# Griffin RG-LRU recurrent block
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0
_GATE_BLOCKS = 16  # block-diagonal recurrence gates (Griffin); aligns with TP


def init_rglru_block(key, cfg: ModelConfig, env: Env) -> dict:
    d, w, cw = cfg.d_model, cfg.rglru_width, cfg.conv_width
    g = _GATE_BLOCKS if w % _GATE_BLOCKS == 0 else 1
    bw = w // g
    ks = jax.random.split(key, 7)
    # Lambda init so a = exp(-c*softplus(L)*r) starts near 0.9..0.999
    lam = jnp.log(jnp.expm1(-jnp.log(jax.random.uniform(
        ks[6], (w,), jnp.float32, 0.9, 0.999)) / _RGLRU_C))
    bg = lambda k: (jax.random.normal(k, (g, bw, bw), jnp.float32)
                    / math.sqrt(bw)).astype(jnp.bfloat16)
    return {
        "w_in": dense_init(ks[0], d, w),
        "w_gate_in": dense_init(ks[1], d, w),
        "conv_w": (jax.random.normal(ks[2], (cw, w), jnp.float32) / math.sqrt(cw)
                   ).astype(jnp.bfloat16),
        "w_rgate": bg(ks[3]),  # block-diagonal [G, w/G, w/G]
        "w_igate": bg(ks[4]),
        "lam": lam,
        "w_out": dense_init(ks[5], w, d),
    }


def _block_diag_matmul(u, wb):
    """u [B,S,w] x block-diag wb [G, w/G, w/G] -> [B,S,w] (no cross-block
    terms: each TP shard holds whole blocks -> no collective)."""
    B, S, w = u.shape
    g, bw, _ = wb.shape
    ub = u.reshape(B, S, g, bw)
    return jnp.einsum("bsgi,gij->bsgj", ub, wb).reshape(B, S, w)


def _causal_conv1d(x, conv_w, state=None):
    """Depthwise causal conv. x [B,S,w], conv_w [cw,w]. state [B,cw-1,w]."""
    cw = conv_w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * conv_w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):, :] if cw > 1 else None
    return out.astype(x.dtype), new_state


def _rglru_gates(p, u):
    """u [B,S,w] (f32) -> (a, b): h_t = a*h + b."""
    r = jax.nn.sigmoid(_block_diag_matmul(u, p["w_rgate"].astype(jnp.float32)))
    i = jax.nn.sigmoid(_block_diag_matmul(u, p["w_igate"].astype(jnp.float32)))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i * u)
    return a, b


def rglru_scan(a, b, h0=None):
    """Parallel linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.

    a, b: [B, S, w] f32. h0: [B, w] or None (zeros). Returns h [B,S,w].
    """
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def op(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h


def rglru_block(p, x, cfg: ModelConfig, env: Env, state=None,
                return_state: bool = False):
    """Griffin recurrent block. x [B,S,d] -> (y [B,S,d], new_state).

    state = {"h": [B,w], "conv": [B,cw-1,w]} for decode; None for train.
    return_state=True (prefill): returns the post-prompt state for decoding.
    """
    gate = jax.nn.gelu(x @ p["w_gate_in"])
    u_pre = x @ p["w_in"]
    u_pre = constrain(u_pre, env, env.dpx, None, env.plan.tp_axis)
    conv_state = None if state is None else state["conv"]
    u, new_conv = _causal_conv1d(u_pre, p["conv_w"], conv_state)
    a, b = _rglru_gates(p, u.astype(jnp.float32))
    if state is None:
        h = rglru_scan(a, b)
        new_state = None
        if return_state:
            cw = p["conv_w"].shape[0]
            tail = u_pre[:, -(cw - 1):, :].astype(jnp.bfloat16)
            if tail.shape[1] < cw - 1:  # prompt shorter than conv window
                tail = jnp.pad(tail, ((0, 0), (cw - 1 - tail.shape[1], 0), (0, 0)))
            new_state = {"h": h[:, -1, :], "conv": tail}
    else:
        h = a * state["h"][:, None, :] + b  # S == 1
        new_state = {"h": h[:, -1, :], "conv": new_conv}
    y = (gate * h.astype(gate.dtype)) @ p["w_out"]
    return constrain(y, env, *out_dims(env, y.shape[1])), new_state


def rglru_init_state(cfg: ModelConfig, batch: int) -> dict:
    w, cw = cfg.rglru_width, cfg.conv_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cw - 1, w), jnp.bfloat16),
    }


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------

_DECAY_LORA = 64


def init_rwkv_block(key, cfg: ModelConfig, env: Env) -> dict:
    d, H, hd, ff = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.d_ff
    assert H * hd == d
    ks = jax.random.split(key, 12)
    return {
        # time-mix
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),  # lerp for r,k,v,g,w
        "w_r": dense_init(ks[0], d, d),
        "w_k": dense_init(ks[1], d, d),
        "w_v": dense_init(ks[2], d, d),
        "w_g": dense_init(ks[3], d, d),
        "w_o": dense_init(ks[4], d, d),
        "decay_base": -6.0 + jax.random.normal(ks[5], (d,), jnp.float32) * 0.3,
        "decay_A": dense_init(ks[6], d, _DECAY_LORA, dtype=jnp.float32),
        "decay_B": dense_init(ks[7], _DECAY_LORA, d, dtype=jnp.float32),
        "bonus_u": jax.random.normal(ks[8], (H, hd), jnp.float32) * 0.3,
        "ln_x": jnp.ones((d,), jnp.float32),  # per-head groupnorm scale
        # channel-mix
        "cmu": 0.5 * jnp.ones((2, d), jnp.float32),
        "cm_k": dense_init(ks[9], d, ff),
        "cm_v": dense_init(ks[10], ff, d),
        "cm_r": dense_init(ks[11], d, d),
    }


def _token_shift(x, prev=None):
    """x_{t-1} with x_{-1} = prev (decode) or 0 (train)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    return jnp.concatenate([prev[:, None, :].astype(x.dtype), x[:, :-1, :]], 1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def rwkv_decay(p, xw):
    """Data-dependent decay (the Finch contribution): log w_t, [B,S,d] f32."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["decay_A"]) @ p["decay_B"]
    return -jnp.exp(jnp.clip(p["decay_base"] + lora, -20.0, 8.0))  # log w <= 0


def _group_norm_heads(x, scale, H, eps=1e-5):
    """x [B,S,H,hd] normalized per head."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    B, S = x.shape[0], x.shape[1]
    return (y.reshape(B, S, -1) * scale).astype(x.dtype)


def rwkv_time_mix_chunked(r, k, v, logw, u, chunk: int = 32, s0=None,
                          unroll=1):
    """Exact chunked WKV6 recurrence.

    r,k,v: [B,S,H,hd]; logw: [B,S,H,hd] (<=0); u: [H,hd].
    Returns (o [B,S,H,hd], s_final [B,H,hd,hd]).

    o_t = r_t^T S_{t-1} + (r_t . (u*k_t)) v_t ;  S_t = diag(w_t) S_{t-1} + k_t v_t^T

    Within a chunk the pairwise decay D[t,s,c] = exp(clip(L_{t-1}-L_s)) is
    formed explicitly (stable; the Pallas kernel uses the factorized form
    with per-block rescaling).
    """
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    rf = r.astype(jnp.float32).reshape(B, n, chunk, H, hd)
    kf = k.astype(jnp.float32).reshape(B, n, chunk, H, hd)
    vf = v.astype(jnp.float32).reshape(B, n, chunk, H, hd)
    lw = logw.reshape(B, n, chunk, H, hd)

    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    t_idx = jnp.arange(chunk)
    strict = (t_idx[:, None] > t_idx[None, :]).astype(jnp.float32)  # [t,s]

    def step(S_in, xs):
        rc, kc, vc, lc = xs  # [B,chunk,H,hd] each
        L = jnp.cumsum(lc, axis=1)  # L_t = sum_{u<=t} log w_u
        Lprev = L - lc  # L_{t-1}
        # intra-chunk: A[t,s] = sum_c r[t,c] k[s,c] exp(L_{t-1,c} - L_{s,c}), s<t
        diff = Lprev[:, :, None, :, :] - L[:, None, :, :, :]  # [B,t,s,H,hd]
        D = jnp.exp(jnp.clip(diff, -60.0, 0.0))
        A = jnp.einsum("bthc,bshc,btshc->bhts", rc, kc, D)
        A = A * strict[None, None]
        Au = jnp.einsum("bthc,bthc->bth", rc, u[None, None] * kc)  # diagonal
        o = jnp.einsum("bhts,bshc->bthc", A, vc)
        o = o + Au[..., None] * vc  # diagonal (bonus-u) term
        # inter-chunk: contribution of carried state
        rP = rc * jnp.exp(jnp.clip(Lprev, -60.0, 0.0))
        o = o + jnp.einsum("bthc,bhcd->bthd", rP, S_in)
        # state update: S_out = diag(exp(L_T)) S_in + sum_s diag(exp(L_T - L_s)) k_s v_s^T
        LT = L[:, -1]  # [B,H,hd]
        kT = kc * jnp.exp(jnp.clip(LT[:, None] - L, -60.0, 0.0))
        S_out = jnp.exp(jnp.clip(LT, -60.0, 0.0))[..., None] * S_in + jnp.einsum(
            "bshc,bshd->bhcd", kT, vc)
        return S_out, o

    xs = (rf.transpose(1, 0, 2, 3, 4), kf.transpose(1, 0, 2, 3, 4),
          vf.transpose(1, 0, 2, 3, 4), lw.transpose(1, 0, 2, 3, 4))
    s_fin, outs = jax.lax.scan(step, s0, xs, unroll=unroll)
    o = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return o.astype(r.dtype), s_fin


def rwkv_time_mix_step(r, k, v, logw, u, s):
    """Single decode step. r,k,v,logw: [B,1,H,hd]; s: [B,H,hd,hd]."""
    rf, kf, vf = (a.astype(jnp.float32)[:, 0] for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32)[:, 0])  # [B,H,hd]
    att = s + (u[None] * kf)[..., None] * vf[..., None, :]  # [B,H,hd,hd]
    o = jnp.einsum("bhc,bhcd->bhd", rf, att)
    s_new = w[..., None] * s + kf[..., None] * vf[..., None, :]
    return o[:, None].astype(r.dtype), s_new


def rwkv_init_state(cfg: ModelConfig, batch: int) -> dict:
    H, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {
        "s": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "tm_prev": jnp.zeros((batch, d), jnp.bfloat16),
        "cm_prev": jnp.zeros((batch, d), jnp.bfloat16),
    }


def rwkv_time_mix(p, x, cfg: ModelConfig, env: Env, state=None,
                  return_state: bool = False):
    """x [B,S,d] -> (y [B,S,d], new_state_partial)."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    prev = None if state is None else state["tm_prev"]
    xs = _token_shift(x, prev)
    xr = _lerp(x, xs, p["mu"][0])
    xk = _lerp(x, xs, p["mu"][1])
    xv = _lerp(x, xs, p["mu"][2])
    xg = _lerp(x, xs, p["mu"][3])
    xw = _lerp(x, xs, p["mu"][4])
    r = (xr @ p["w_r"]).reshape(B, S, H, hd)
    k = (xk @ p["w_k"]).reshape(B, S, H, hd)
    v = (xv @ p["w_v"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["w_g"])
    logw = rwkv_decay(p, xw).reshape(B, S, H, hd)
    r = constrain(r, env, env.dpx, None, env.plan.tp_axis, None)
    k = constrain(k, env, env.dpx, None, env.plan.tp_axis, None)
    v = constrain(v, env, env.dpx, None, env.plan.tp_axis, None)
    if state is None:
        o, s_fin = rwkv_time_mix_chunked(
            r, k, v, logw, p["bonus_u"], chunk=env.plan.rwkv_chunk,
            unroll=True if env.plan.inner_unroll else 1)
        new_state = ({"s": s_fin, "tm_prev": x[:, -1, :].astype(jnp.bfloat16)}
                     if return_state else None)
    else:
        o, s_fin = rwkv_time_mix_step(r, k, v, logw, p["bonus_u"], state["s"])
        new_state = {"s": s_fin, "tm_prev": x[:, -1, :]}
    o = _group_norm_heads(o, p["ln_x"], H)
    y = (o * g.astype(o.dtype)) @ p["w_o"]
    return constrain(y, env, *out_dims(env, y.shape[1])), new_state


def rwkv_channel_mix(p, x, cfg: ModelConfig, env: Env, state=None,
                     return_state: bool = False):
    prev = None if state is None else state["cm_prev"]
    xs = _token_shift(x, prev)
    xk = _lerp(x, xs, p["cmu"][0])
    xr = _lerp(x, xs, p["cmu"][1])
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    kk = constrain(kk, env, env.dpx, None, env.plan.tp_axis)
    hv = kk @ p["cm_v"]
    rr = jax.nn.sigmoid(xr @ p["cm_r"])
    y = rr * hv
    new = (x[:, -1, :].astype(jnp.bfloat16)
           if (state is not None or return_state) else None)
    return constrain(y, env, *out_dims(env, y.shape[1])), new
