"""Core layers: norms, RoPE / M-RoPE, GQA attention (all impls), SwiGLU.

All functions are pure; parameters are plain dict pytrees created by the
matching `init_*` functions. dtype policy: params and activations bf16 by
default, softmax/logsumexp statistics in f32.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.env import Env, constrain, head_pad, kv_head_pad, out_dims

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16, scale: float | None = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    i = jnp.arange(head_dim // 2, dtype=jnp.float32)
    return theta ** (-2.0 * i / head_dim)  # [hd/2]


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections: Tuple[int, int, int]):
    """Qwen2-VL multimodal RoPE.

    x: [..., S, H, hd]; positions: [..., S, 3] (temporal, h, w).
    Rotary dims hd/2 are split into `sections` (sum == hd/2), each section
    rotated with its own position component.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)  # [hd/2]
    parts = []
    off = 0
    for c, sec in enumerate(sections):
        ang = positions[..., c:c + 1].astype(jnp.float32) * freqs[off:off + sec]
        parts.append(ang)
        off += sec
    ang = jnp.concatenate(parts, axis=-1)  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, env: Env, cross: bool = False) -> dict:
    hq = head_pad(cfg, env)
    hd, d = cfg.head_dim, cfg.d_model
    hkv = kv_head_pad(cfg, env)
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], d, hq * hd),
        "wk": dense_init(ks[1], d, hkv * hd),
        "wv": dense_init(ks[2], d, hkv * hd),
        "wo": dense_init(ks[3], hq * hd, d),
    }
    if hq != cfg.n_heads:  # zero the padded head slots (DESIGN.md §4)
        mask = (jnp.arange(hq * hd) < cfg.n_heads * hd).astype(p["wq"].dtype)
        p["wq"] = p["wq"] * mask[None, :]
        p["wo"] = p["wo"] * mask[:, None]
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), p["wq"].dtype)
        p["bk"] = jnp.zeros((hkv * hd,), p["wq"].dtype)
        p["bv"] = jnp.zeros((hkv * hd,), p["wq"].dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(p, x, x_kv, cfg: ModelConfig, env: Env):
    """Returns q [B,S,Hq,hd], k/v [B,Skv,Hkv,hd] (no rope yet)."""
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x_kv @ p["wk"]
    v = x_kv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    B, S = x.shape[0], x.shape[1]
    Skv = x_kv.shape[1]
    q = q.reshape(B, S, -1, hd)
    k = k.reshape(B, Skv, -1, hd)
    v = v.reshape(B, Skv, -1, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    # activation layout: batch over dp, heads over tp
    q = constrain(q, env, env.dpx, None, env.plan.tp_axis, None)
    k = constrain(k, env, env.dpx, None, None, None)
    v = constrain(v, env, env.dpx, None, None, None)
    return q, k, v


def _group(q, hkv):
    """[B,S,Hq,hd] -> [B,Hkv,G,S,hd]."""
    B, S, Hq, hd = q.shape
    g = Hq // hkv
    return q.reshape(B, S, hkv, g, hd).transpose(0, 2, 3, 1, 4)


def _ungroup(o):
    """[B,Hkv,G,S,hd] -> [B,S,Hq*hd]."""
    B, Hkv, G, S, hd = o.shape
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, Hkv * G * hd)


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """additive f32 bias [..., Sq, Sk]."""
    ok = jnp.ones(jnp.broadcast_shapes(q_pos[..., :, None].shape,
                                       k_pos[..., None, :].shape), bool)
    if causal:
        ok &= q_pos[..., :, None] >= k_pos[..., None, :]
    if window > 0:
        ok &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def attention_naive(q, k, v, cfg: ModelConfig, *, causal: bool, window: int = 0,
                    q_pos=None, k_pos=None):
    """Full-matrix reference (smoke/tests)."""
    hkv = k.shape[2]
    qg = _group(q, hkv)  # [B,Hkv,G,Sq,hd]
    kk = k.transpose(0, 2, 1, 3)  # [B,Hkv,Sk,hd]
    vv = v.transpose(0, 2, 1, 3)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kk).astype(jnp.float32) * scale
    Sq, Sk = q.shape[1], k.shape[1]
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if k_pos is None:
        k_pos = jnp.arange(Sk)
    s = s + _mask_bias(q_pos, k_pos, causal, window)
    a = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", a, vv)
    return _ungroup(o)


def attention_chunked(q, k, v, cfg: ModelConfig, env: Env, *, causal: bool,
                      window: int = 0, q_chunk: int = 1024, kv_chunk: int = 1024):
    unroll = True if env.plan.inner_unroll else 1
    """Flash-style online-softmax attention in pure XLA.

    Memory-bounded: scans q chunks (outer) and kv chunks (inner), carrying
    (m, l, acc). Masked blocks are still *computed* (static scan lengths) —
    that causal waste is visible in the roofline useful-flops ratio; the
    Pallas TPU kernel (kernels/flash_attention) skips them with pl.when.
    """
    B, Sq, Hq, hd = q.shape
    Sk = k.shape[1]
    hkv = k.shape[2]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = 1.0 / math.sqrt(hd)

    qg = _group(q, hkv)  # [B,Hkv,G,Sq,hd]
    kk = k.transpose(0, 2, 1, 3)  # [B,Hkv,Sk,hd]
    vv = v.transpose(0, 2, 1, 3)
    G = qg.shape[2]

    def q_step(_, qi):
        qc, qpos = qi  # [B,Hkv,G,Cq,hd], [Cq]

        def kv_step(carry, ki):
            m, l, acc = carry
            kc, vc, kpos = ki  # [B,Hkv,Ck,hd] x2, [Ck]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc).astype(jnp.float32) * scale
            s = s + _mask_bias(qpos, kpos, causal, window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new may be -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vc.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, hkv, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, hkv, G, q_chunk, hd), jnp.float32)
        ks = kk.reshape(B, hkv, nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
        vs = vv.reshape(B, hkv, nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
        kpos = jnp.arange(Sk).reshape(nk, kv_chunk)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kpos),
                                      unroll=unroll)
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o.astype(q.dtype)

    qs = qg.reshape(B, hkv, G, nq, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    qpos = jnp.arange(Sq).reshape(nq, q_chunk)
    _, outs = jax.lax.scan(q_step, None, (qs, qpos),
                           unroll=unroll)  # [nq,B,Hkv,G,Cq,hd]
    o = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, hkv, G, Sq, hd)
    return _ungroup(o)


def attention_window_prefill(q, k, v, cfg: ModelConfig, env: Env, *, window: int,
                             q_chunk: int = 1024):
    unroll = True if env.plan.inner_unroll else 1
    """Sliding-window causal attention with an optimal kv span per q chunk.

    For q chunk starting at t0, keys in [t0 - window, t0 + Cq) suffice, so we
    dynamic-slice a (Cq + window)-wide kv span instead of scanning all of Sk.
    """
    B, Sq, Hq, hd = q.shape
    Sk = k.shape[1]
    hkv = k.shape[2]
    q_chunk = min(q_chunk, Sq)
    assert Sq % q_chunk == 0 and Sq == Sk
    nq = Sq // q_chunk
    span = q_chunk + window
    scale = 1.0 / math.sqrt(hd)

    qg = _group(q, hkv)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    # pad keys on the left by `window` so every span slice is in-bounds
    kk = jnp.pad(kk, ((0, 0), (0, 0), (window, 0), (0, 0)))
    vv = jnp.pad(vv, ((0, 0), (0, 0), (window, 0), (0, 0)))
    G = qg.shape[2]

    def q_step(_, i):
        t0 = i * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(qg, t0, q_chunk, axis=3)
        kc = jax.lax.dynamic_slice_in_dim(kk, t0, span, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(vv, t0, span, axis=2)
        qpos = t0 + jnp.arange(q_chunk)
        kpos = t0 - window + jnp.arange(span)  # positions < 0 are padding
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc).astype(jnp.float32) * scale
        bias = _mask_bias(qpos, kpos, True, window)
        bias = jnp.where((kpos < 0)[None, :], -jnp.inf, bias)
        s = s + bias
        a = jax.nn.softmax(s, axis=-1).astype(vc.dtype)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", a, vc)
        return None, o

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq), unroll=unroll)
    o = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, hkv, G, Sq, hd)
    return _ungroup(o)


def attention_decode(q, k_cache, v_cache, cur_len, cfg: ModelConfig, env: Env,
                     *, window: int = 0):
    """Single-token attention over a (possibly seq-sharded) KV cache.

    q: [B,1,Hq,hd]; caches: [B,Hkv,Smax,hd] — sharded over the TP axis on
    Smax when plan.kv_cache == 'seq_sharded' (flash-decoding layout: GSPMD
    emits the partial-softmax collectives; the Pallas kernels/flash_decode
    kernel is the TPU-native version of this merge).
    """
    B, _, Hq, hd = q.shape
    hkv = k_cache.shape[1]
    Smax = k_cache.shape[2]
    scale = 1.0 / math.sqrt(hd)
    qg = _group(q, hkv)[:, :, :, 0]  # [B,Hkv,G,hd]
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache).astype(jnp.float32) * scale
    kpos = jnp.arange(Smax)
    # cur_len: scalar int32, or [B] int32 when rows sit at different write
    # positions (continuous batching: each KV slot decodes independently)
    cl = jnp.asarray(cur_len)
    if cl.ndim:
        cl = cl[:, None, None, None]
        kpos = kpos[None, None, None, :]
    ok = kpos <= cl
    if window > 0:
        ok = ok & (kpos >= cl - window + 1)
    s = jnp.where(ok, s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bhgk,bhkd->bhgd", a, v_cache)
    return o.reshape(B, 1, hkv * qg.shape[2] * hd)


def attention_paged_decode(q, k_pool, v_pool, tables, lengths,
                           cfg: ModelConfig, env: Env):
    """Single-token attention over a block-paged KV pool — the vectorized
    XLA gather fallback for the Pallas paged kernel (kernels/paged_decode).

    q: [B,1,Hq,hd]; k_pool/v_pool: [NB,Hkv,bs,hd]; tables: [B,MB] int32
    physical block ids (0 = null block); lengths: [B] int32 index of the
    last valid gathered position. The gather reconstructs each row's KV in
    logical order, so the math is identical to attention_decode over a
    contiguous cache."""
    from repro.kernels.paged_decode.ops import gather_blocks
    return attention_decode(q, gather_blocks(k_pool, tables),
                            gather_blocks(v_pool, tables), lengths, cfg, env)


def attention_paged_decode_quant(q, k_pool, v_pool, k_scale, v_scale,
                                 tables, lengths, cfg: ModelConfig, env: Env):
    """attention_paged_decode over an int8 quant pool: gather the int8
    blocks and their per-row scales, dequantize to f32, then the same
    masked-softmax math. k_pool/v_pool: [NB,Hkv,bs,hd] int8; k_scale/
    v_scale: [NB,Hkv,bs] f32."""
    from repro.kernels.paged_decode.ops import (gather_block_scales,
                                                gather_blocks)
    kg = (gather_blocks(k_pool, tables).astype(jnp.float32)
          * gather_block_scales(k_scale, tables)[..., None])
    vg = (gather_blocks(v_pool, tables).astype(jnp.float32)
          * gather_block_scales(v_scale, tables)[..., None])
    # back to the activation dtype: the fp pool stores bf16, so its read
    # path hands attention bf16 — the dequantized pool must not leak f32
    # into the residual stream (the layer-scan carry dtype is pinned)
    return attention_decode(q, kg, vg, lengths, cfg, env).astype(q.dtype)


def attention(p, x, cfg: ModelConfig, env: Env, *, positions, causal: bool = True,
              window: int = 0, x_kv=None, rope: bool = True):
    """Full-sequence attention (train/prefill). Returns [B,S,d]."""
    x_kv = x if x_kv is None else x_kv
    q, k, v = _project_qkv(p, x, x_kv, cfg, env)
    if rope:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    impl = env.plan.attn_impl
    if impl == "xla_chunked" and x.shape[1] > env.plan.attn_q_chunk:
        if window > 0 and x_kv is x:
            o = attention_window_prefill(q, k, v, cfg, env, window=window,
                                         q_chunk=env.plan.attn_q_chunk)
        else:
            o = attention_chunked(q, k, v, cfg, env, causal=causal, window=window,
                                  q_chunk=env.plan.attn_q_chunk,
                                  kv_chunk=env.plan.attn_kv_chunk)
    elif impl == "pallas" and x.shape[1] > 128:
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                   n_kv_heads=max(cfg.n_kv_heads, 1))
    else:
        o = attention_naive(q, k, v, cfg, causal=causal, window=window)
    o = o @ p["wo"]
    return constrain(o, env, env.dpx, None, None)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, ff),
        "w_up": dense_init(k2, d, ff),
        "w_down": dense_init(k3, ff, d),
    }


def mlp(p, x, env: Env):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = constrain(h, env, env.dpx, None, env.plan.tp_axis)
    o = h @ p["w_down"]
    return constrain(o, env, *out_dims(env, o.shape[1]))
