"""Execution environment threaded through model code.

Models are mesh-agnostic: they receive an `Env` describing the mesh (or None
for single-device smoke runs) and the ParallelPlan, and use `constrain()` to
place intermediate activations. Axis names not present in the mesh are
silently dropped (so the same specs work on single-pod and multi-pod meshes).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelPlan


@dataclass(frozen=True)
class Env:
    mesh: Optional[Mesh]
    plan: ParallelPlan

    # ---- axis helpers ------------------------------------------------------
    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.plan.dp_axes if a in self.axis_names)

    @property
    def tp_axis(self) -> Optional[str]:
        return self.plan.tp_axis if self.plan.tp_axis in self.axis_names else None

    @property
    def tp(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    def spec(self, *dims) -> P:
        """Build a PartitionSpec, dropping axis names absent from the mesh.

        Each dim is None, an axis name, or a tuple of axis names.
        """
        out = []
        for d in dims:
            if d is None:
                out.append(None)
            elif isinstance(d, (tuple, list)):
                kept = tuple(a for a in d if a in self.axis_names)
                out.append(kept if kept else None)
            else:
                out.append(d if d in self.axis_names else None)
        return P(*out)

    def sharding(self, *dims) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*dims))

    # "dp" shorthand usable inside spec dims
    @property
    def dpx(self) -> Tuple[str, ...]:
        return self.dp_axes


def constrain(x, env: Env, *dims):
    """with_sharding_constraint that no-ops without a mesh."""
    if env.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, env.sharding(*dims))


def head_pad(cfg: ModelConfig, env: Env) -> int:
    """Padded query-head count for the current TP degree (DESIGN.md §4)."""
    tp = env.tp
    if tp <= 1:
        return cfg.n_heads
    return ((cfg.n_heads + tp - 1) // tp) * tp


def out_dims(env: Env, seq_len: int):
    """Layer-output sharding: sequence-parallel over tp when enabled (turns
    the preceding row-matmul all-reduce into reduce-scatter)."""
    if (env.plan.seq_shard_acts and env.tp > 1 and seq_len % env.tp == 0
            and seq_len >= env.tp):
        return (env.dpx, env.plan.tp_axis, None)
    return (env.dpx, None, None)


def kv_head_pad(cfg: ModelConfig, env: Env) -> int:
    """MHA (kv == q heads) pads KV heads alongside Q so GQA grouping stays
    integral; GQA keeps its true KV head count (replicated across TP)."""
    hkv = max(cfg.n_kv_heads, 1)
    if cfg.n_kv_heads == cfg.n_heads:
        return head_pad(cfg, env)
    return hkv


def vocab_pad(cfg: ModelConfig, env: Env) -> int:
    tp = max(env.tp, 1)
    m = max(128, tp) if tp > 1 else 8
    return ((cfg.vocab_size + m - 1) // m) * m
