"""Mixture-of-Experts layer: sort-based (dropless-style, capacity-padded)
dispatch with two production sharding modes (DESIGN.md §4):

  "ep": experts sharded over the TP axis (E % tp == 0, e.g. llama4-scout
        16e/16): tokens move to their expert's shard via lax.all_to_all
        inside shard_map — GShard-faithful expert parallelism.
  "tp": TP-within-expert (ff sharded over the TP axis; e.g. grok-1 8e on a
        16-way axis, where EP is inapplicable): every shard computes all
        experts on its ff slice; psum after the down-projection.

Dispatch is sort-based (argsort by expert id + capacity-clipped scatter),
not the one-hot [G,S,E,C] einsum — the one-hot mask alone would be ~20 TB
for grok-1 train_4k. Overflowing tokens are dropped (pass through the
residual), standard GShard behavior at capacity_factor 1.25.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models.env import Env
from repro.models.layers import dense_init


def init_moe(key, cfg: ModelConfig, env: Env) -> dict:
    m = cfg.moe
    d, ff, E = cfg.d_model, cfg.d_ff, m.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, E, dtype=jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d, ff))(jax.random.split(ks[1], E)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, ff))(jax.random.split(ks[2], E)),
        "w_down": jax.vmap(lambda k: dense_init(k, ff, d))(jax.random.split(ks[3], E)),
    }


def _capacity(n_tokens: int, E: int, k: int, cf: float) -> int:
    if n_tokens * k <= E:  # decode-scale dispatch: dropless worst case,
        return n_tokens * k  # no MXU alignment padding
    c = int(n_tokens * k * cf / E) + 1
    return max(8, -(-c // 8) * 8)  # multiple of 8, >= 8


def _route(x_flat, router_w, E: int, k: int):
    """Returns (e_sorted, tok_sorted, gate_sorted, keep_rank, aux_loss)."""
    n = x_flat.shape[0]
    logits = (x_flat.astype(jnp.float32) @ router_w)  # [N,E]
    gates = jax.nn.softmax(logits, axis=-1)
    gval, gidx = jax.lax.top_k(gates, k)  # [N,k]
    gval = gval / jnp.maximum(jnp.sum(gval, -1, keepdims=True), 1e-9)
    e_flat = gidx.reshape(-1)
    g_flat = gval.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(e_flat, stable=True)
    e_s, tok_s, g_s = e_flat[order], tok_flat[order], g_flat[order]
    counts = jnp.bincount(e_flat, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n * k) - starts[e_s]
    # Switch-style load-balance aux loss: E * sum(frac_tokens * frac_prob)
    frac_tok = counts.astype(jnp.float32) / (n * k)
    frac_prob = jnp.mean(gates, axis=0)
    aux = E * jnp.sum(frac_tok * frac_prob)
    return e_s, tok_s, g_s, rank, aux


def _dispatch(x_flat, router_w, E: int, k: int, capacity: int):
    e_s, tok_s, g_s, rank, aux = _route(x_flat, router_w, E, k)
    keep = rank < capacity
    dest = jnp.where(keep, e_s * capacity + rank, E * capacity)
    vals = x_flat[tok_s] * keep[:, None].astype(x_flat.dtype)
    buf = jnp.zeros((E * capacity + 1, x_flat.shape[1]), x_flat.dtype)
    buf = buf.at[dest].set(vals)
    return buf[: E * capacity], (tok_s, g_s, dest, keep), aux


def _combine(expert_out, meta, n_tokens: int):
    """expert_out [E*C, d] -> y [N, d]."""
    tok_s, g_s, dest, keep = meta
    padded = jnp.concatenate(
        [expert_out, jnp.zeros((1, expert_out.shape[1]), expert_out.dtype)], 0
    )
    rows = padded[dest] * (g_s * keep).astype(expert_out.dtype)[:, None]
    y = jnp.zeros((n_tokens, expert_out.shape[1]), expert_out.dtype)
    return y.at[tok_s].add(rows)


def _expert_ffn(buf, wg, wu, wd):
    """buf [E, C, d]; weights [E, d, ff]/[E, ff, d] (possibly ff-sharded)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _gather_fsdp(w, axes, dim: int):
    for a in axes:
        w = jax.lax.all_gather(w, a, axis=dim, tiled=True)
    return w


def moe_layer(p, x, cfg: ModelConfig, env: Env):
    """x: [B, S, d] (global). Returns (y, aux_loss)."""
    m = cfg.moe
    E, k, cf = m.num_experts, m.top_k, m.capacity_factor
    B, S, d = x.shape

    if env.mesh is None:
        flat = x.reshape(B * S, d)
        cap = _capacity(B * S, E, k, cf)
        buf, meta, aux = _dispatch(flat, p["router"], E, k, cap)
        out = _expert_ffn(buf.reshape(E, cap, d), p["w_gate"], p["w_up"], p["w_down"])
        y = _combine(out.reshape(E * cap, d), meta, B * S)
        return y.reshape(B, S, d), aux

    mode = env.plan.resolve_moe(cfg, env.tp)
    dpx = env.dpx if (env.dpx and B % env.dp == 0) else ()
    dp_local = env.dp if dpx else 1
    tp_axis = env.tp_axis
    # EP: also split tokens over the TP axis before dispatch — otherwise every
    # model shard dispatches the SAME token set and each expert receives tp
    # redundant copies (measured 12x wasted expert FLOPs; EXPERIMENTS §Perf).
    seq_split = (mode == "ep" and tp_axis is not None and S % max(env.tp, 1) == 0
                 and S >= env.tp)
    n_local = (B // dp_local) * (S // (env.tp if seq_split else 1))
    cap = _capacity(n_local, E, k, cf)
    fsdp_axes = tuple(a for a in dpx) if env.plan.fsdp else ()

    xspec = env.spec(dpx or None, tp_axis if seq_split else None, None)
    rspec = env.spec(None, None)

    if mode == "ep":
        # experts sharded over tp_axis; weight d-dim FSDP-sharded over data
        wspec_in = env.spec(tp_axis, fsdp_axes or None, None)
        wspec_out = env.spec(tp_axis, None, fsdp_axes or None)

        def body(xl, wr, wg, wu, wd):
            Bl, Sl, _ = xl.shape
            flat = xl.reshape(Bl * Sl, d)
            buf, meta, aux = _dispatch(flat, wr, E, k, cap)
            buf = buf.reshape(E, cap, d)
            # route tokens to their expert's shard
            buf = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=1,
                                     tiled=True)  # [E/tp, tp*cap, d]
            if fsdp_axes:
                wg = _gather_fsdp(wg, fsdp_axes, 1)
                wu = _gather_fsdp(wu, fsdp_axes, 1)
                wd = _gather_fsdp(wd, fsdp_axes, 2)
            out = _expert_ffn(buf, wg, wu, wd)  # [E/tp, tp*cap, d]
            out = jax.lax.all_to_all(out, tp_axis, split_axis=1, concat_axis=0,
                                     tiled=True)  # [E, cap, d]
            y = _combine(out.reshape(E * cap, d), meta, Bl * Sl)
            return y.reshape(Bl, Sl, d), aux

    else:  # "tp": ff sharded; all shards compute all experts on their slice
        wspec_in = env.spec(None, fsdp_axes or None, tp_axis)
        wspec_out = env.spec(None, tp_axis, fsdp_axes or None)

        def body(xl, wr, wg, wu, wd):
            Bl, Sl, _ = xl.shape
            flat = xl.reshape(Bl * Sl, d)
            buf, meta, aux = _dispatch(flat, wr, E, k, cap)
            if fsdp_axes:
                wg = _gather_fsdp(wg, fsdp_axes, 1)
                wu = _gather_fsdp(wu, fsdp_axes, 1)
                wd = _gather_fsdp(wd, fsdp_axes, 2)  # d dim (ff stays sharded)
            out = _expert_ffn(buf.reshape(E, cap, d), wg, wu, wd)
            if tp_axis is not None:
                out = jax.lax.psum(out, tp_axis)  # ff was sharded
            y = _combine(out.reshape(E * cap, d), meta, Bl * Sl)
            return y.reshape(Bl, Sl, d), aux

    fn = shard_map(
        body,
        mesh=env.mesh,
        in_specs=(xspec, rspec, wspec_in, wspec_in, wspec_out),
        out_specs=(xspec, env.spec()),
        check_rep=False,
    )
    y, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, jnp.mean(aux)


def moe_param_specs(cfg: ModelConfig, env: Env, mode: str):
    """PartitionSpecs for the stored MoE weights (matches moe_layer in_specs)."""
    fsdp = env.plan.fsdp
    if mode == "ep":
        return {
            "router": env.spec(None, None),
            "w_gate": env.spec(env.plan.tp_axis, "data" if fsdp else None, None),
            "w_up": env.spec(env.plan.tp_axis, "data" if fsdp else None, None),
            "w_down": env.spec(env.plan.tp_axis, None, "data" if fsdp else None),
        }
    return {
        "router": env.spec(None, None),
        "w_gate": env.spec(None, "data" if fsdp else None, env.plan.tp_axis),
        "w_up": env.spec(None, "data" if fsdp else None, env.plan.tp_axis),
        "w_down": env.spec(None, env.plan.tp_axis, "data" if fsdp else None),
    }
