"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (layout adaptation, interpret fallback)
  ref.py    — pure-jnp oracle used by tests/benchmarks

On this CPU container kernels run with interpret=True; on a TPU backend the
same pallas_call lowers through Mosaic.
"""


def default_interpret() -> bool:
    import jax

    return jax.default_backend() != "tpu"


def tpu_compiler_params(**kwargs):
    """Version-compat constructor: pltpu.CompilerParams on current JAX,
    pltpu.TPUCompilerParams on jax<=0.4.x (the name was changed upstream)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
