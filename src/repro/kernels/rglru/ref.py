"""Pure-jnp oracle for the RG-LRU recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t via associative scan. a,b: [B,S,W] f32."""
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def op(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h


def rglru_ref_loop(a, b, h0=None):
    """Sequential-scan oracle (independent derivation for tests)."""
    B, S, W = a.shape
    h = jnp.zeros((B, W), a.dtype) if h0 is None else h0

    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h, (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
