from repro.kernels.rglru.ops import rglru_scan  # noqa: F401
