"""jit'd wrapper for the RG-LRU Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.rglru.kernel import rglru_scan_kernel


@partial(jax.jit, static_argnames=("block_t", "block_w", "interpret"))
def rglru_scan(a, b, h0=None, *, block_t: int = 64, block_w: int = 512,
               interpret: bool | None = None):
    """Linear recurrence h_t = a_t h_{t-1} + b_t. a,b: [B,S,W]."""
    if interpret is None:
        interpret = default_interpret()
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    return rglru_scan_kernel(a.astype(jnp.float32), b.astype(jnp.float32),
                             h0.astype(jnp.float32), block_t=block_t,
                             block_w=block_w, interpret=interpret)
