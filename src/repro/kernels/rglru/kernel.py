"""RG-LRU linear-recurrence Pallas TPU kernel (Griffin recurrent block).

h_t = a_t * h_{t-1} + b_t, per channel. TPU adaptation: instead of a
sequential scan (hostile to the VPU) the sequence is tiled into (bt, wt)
VMEM blocks; within a block the recurrence closes in parallel via the
bounded decay matrix D[t,s,c] = exp(clip(L_{t-1..t}-L_s)) (<= 1, no
under/overflow), and a [1, wt] VMEM scratch carries the state across time
blocks (grid dim 2, sequential).

Grid: (B, n_w_tiles, n_t_blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, carry_sc, *, bt: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        carry_sc[...] = h0_ref[...].astype(jnp.float32)  # [1, wt]

    a = a_ref[0].astype(jnp.float32)  # [bt, wt]
    b = b_ref[0].astype(jnp.float32)
    log_a = jnp.log(jnp.maximum(a, 1e-37))
    L = jnp.cumsum(log_a, axis=0)  # L_t = sum_{u<=t} log a_u  (inclusive)
    # h_t = exp(L_t) * h_in + sum_{s<=t} exp(L_t - L_s) * b_s
    diff = L[:, None, :] - L[None, :, :]  # [t, s, wt]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 1))
    D = jnp.where(mask[..., None], jnp.exp(jnp.clip(diff, -60.0, 0.0)), 0.0)
    h = jnp.einsum("tsw,sw->tw", D, b) + jnp.exp(L) * carry_sc[...]
    o_ref[0] = h.astype(o_ref.dtype)
    carry_sc[...] = h[-1:, :]


def rglru_scan_kernel(a, b, h0, *, block_t: int = 64, block_w: int = 512,
                      interpret: bool = False):
    """a, b: [B, S, W] (f32); h0: [B, W]. Returns h: [B, S, W] f32."""
    B, S, W = a.shape
    bt = min(block_t, S)
    wt = min(block_w, W)
    assert S % bt == 0 and W % wt == 0
    kern = functools.partial(_rglru_kernel, bt=bt)
    return pl.pallas_call(
        kern,
        grid=(B, W // wt, S // bt),
        in_specs=[
            pl.BlockSpec((1, bt, wt), lambda b_, w_, t_: (b_, t_, w_)),
            pl.BlockSpec((1, bt, wt), lambda b_, w_, t_: (b_, t_, w_)),
            pl.BlockSpec((1, wt), lambda b_, w_, t_: (b_, w_)),
        ],
        out_specs=pl.BlockSpec((1, bt, wt), lambda b_, w_, t_: (b_, t_, w_)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, wt), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b, h0)
