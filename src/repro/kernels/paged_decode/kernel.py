"""Paged flash-decode Pallas TPU kernel.

One-token attention where K/V live in a global block pool [NB, Hkv, bs, hd]
indexed by per-row block tables (serve/blocks.py). Both the table and the
per-row valid lengths ride in via scalar prefetch (SMEM): the table entry
feeds the K/V BlockSpec index maps directly — the gather IS the DMA
schedule, no materialized [B, MB*bs] copy of the cache. Blocks past a
row's current length skip both compute (pl.when) and HBM traffic: their
index map clamps to the row's last valid block, and the Pallas pipeline
elides the copy when consecutive grid steps name the same block — so a
row that has decoded 40 tokens reads ceil(40/bs) blocks no matter how
wide its table is.

Grid: (B, Hq, MB) — blocks innermost/sequential; scratch carries the
online-softmax (m, l, acc) like kernels/flash_decode.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _pd_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
               m_sc, l_sc, acc_sc, *, scale: float, bs: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    cur_len = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    k_start = j * bs

    @pl.when(k_start <= cur_len)
    def _compute():
        q = q_ref[...].reshape(1, -1).astype(jnp.float32)  # [1, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bs, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(kpos <= cur_len, s, NEG_INF)
        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(
            o_ref.dtype).reshape(o_ref.shape)


def _pd_quant_kernel(tbl_ref, len_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref,
                     o_ref, m_sc, l_sc, acc_sc, *, scale: float, bs: int,
                     g: int):
    """Quantized variant: K/V blocks arrive as int8 and are dequantized in
    registers right after the DMA lands — the per-row scales ([NB,Hkv,bs]
    f32) ride scalar prefetch next to the block table, so the dequant
    multiply is fused into the same pipeline step as the attention math
    (no fp copy of the pool ever exists)."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)
    cur_len = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    k_start = j * bs

    @pl.when(k_start <= cur_len)
    def _compute():
        # same clamp as the BlockSpec index map: dead blocks re-read the
        # last live one, so the scales must be looked up the same way
        j_live = jnp.maximum(jnp.minimum(j, cur_len // bs), 0)
        blk = tbl_ref[b, j_live]
        ks = ks_ref[blk, h // g, :]  # [bs] f32, from SMEM
        vs = vs_ref[blk, h // g, :]
        q = q_ref[...].reshape(1, -1).astype(jnp.float32)  # [1, hd]
        k = k_ref[0, 0].astype(jnp.float32) * ks[:, None]  # [bs, hd]
        v = v_ref[0, 0].astype(jnp.float32) * vs[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(kpos <= cur_len, s, NEG_INF)
        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(
            o_ref.dtype).reshape(o_ref.shape)


def paged_flash_decode_kernel(q, k_pool, v_pool, tables, lengths, *,
                              interpret: bool = False):
    """q: [B,Hq,hd]; k_pool/v_pool: [NB,Hkv,bs,hd]; tables: [B,MB] int32
    physical block ids; lengths: [B] int32 last valid logical position
    (-1 = row fully masked -> zero output).

    Returns o [B,Hq,hd] f32.
    """
    B, Hq, hd = q.shape
    _, Hkv, bs, _ = k_pool.shape
    MB = tables.shape[1]
    g = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(_pd_kernel, scale=scale, bs=bs)

    def kv_index(b, h, j, tbl, L, g=g):
        # clamp dead blocks (j past the row's length) to the last live one:
        # revisiting the same block index makes the pipeline skip the copy
        j_live = jnp.maximum(jnp.minimum(j, L[b] // bs), 0)
        return (tbl[b, j_live], h // g, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block table + lengths land in SMEM
        grid=(B, Hq, MB),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, j, tbl, L: (b, h, 0)),
            pl.BlockSpec((1, 1, bs, hd), kv_index),
            pl.BlockSpec((1, 1, bs, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, j, tbl, L: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, hd), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      q, k_pool, v_pool)


def paged_flash_decode_quant_kernel(q, k_pool, v_pool, k_scale, v_scale,
                                    tables, lengths, *,
                                    interpret: bool = False):
    """Quantized paged decode. q: [B,Hq,hd]; k_pool/v_pool: [NB,Hkv,bs,hd]
    int8; k_scale/v_scale: [NB,Hkv,bs] f32 per-row dequant scales; tables:
    [B,MB] int32; lengths: [B] int32 (-1 = fully masked).

    Scales ride scalar prefetch (SMEM) with the table/lengths; int8 blocks
    ride the same BlockSpec DMA schedule as the fp kernel and are
    dequantized in-register. Returns o [B,Hq,hd] f32.
    """
    B, Hq, hd = q.shape
    _, Hkv, bs, _ = k_pool.shape
    MB = tables.shape[1]
    g = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(_pd_quant_kernel, scale=scale, bs=bs, g=g)

    def kv_index(b, h, j, tbl, L, ks, vs, g=g):
        j_live = jnp.maximum(jnp.minimum(j, L[b] // bs), 0)
        return (tbl[b, j_live], h // g, 0, 0)

    def q_index(b, h, j, tbl, L, ks, vs):
        return (b, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,  # table, lengths, k_scale, v_scale -> SMEM
        grid=(B, Hq, MB),
        in_specs=[
            pl.BlockSpec((1, 1, hd), q_index),
            pl.BlockSpec((1, 1, bs, hd), kv_index),
            pl.BlockSpec((1, 1, bs, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), q_index),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, hd), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      jnp.asarray(k_scale, jnp.float32), jnp.asarray(v_scale, jnp.float32),
      q, k_pool, v_pool)
