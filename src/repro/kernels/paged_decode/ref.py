"""Pure-jnp oracle for paged decode attention (tests/benchmarks)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_decode_ref(q, k_pool, v_pool, tables, lengths):
    """q: [B,Hq,hd]; k_pool/v_pool: [NB,Hkv,bs,hd]; tables: [B,MB] int32;
    lengths: [B] int32 (last valid logical position, -1 = fully masked).

    Gathers each row's blocks in table order and runs masked softmax
    attention in f32. Returns [B,Hq,hd] f32.
    """
    B, Hq, hd = q.shape
    _, Hkv, bs, _ = k_pool.shape
    MB = tables.shape[1]
    g = Hq // Hkv
    kg = k_pool[tables].transpose(0, 2, 1, 3, 4).reshape(B, Hkv, MB * bs, hd)
    vg = v_pool[tables].transpose(0, 2, 1, 3, 4).reshape(B, Hkv, MB * bs, hd)
    qg = q.reshape(B, Hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, kg.astype(jnp.float32))
    s = s / math.sqrt(hd)
    kpos = jnp.arange(MB * bs)[None, None, None, :]
    ok = kpos <= lengths[:, None, None, None]
    s = jnp.where(ok, s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    a = jnp.where(jnp.isfinite(a), a, 0.0)  # fully-masked rows -> zeros
    o = jnp.einsum("bhgk,bhkd->bhgd", a, vg.astype(jnp.float32))
    return o.reshape(B, Hq, hd)


def paged_decode_quant_ref(q, k_pool, v_pool, k_scale, v_scale, tables,
                           lengths):
    """Quantized oracle: dequantize the whole int8 pool up front
    (values * per-row scales), then run the fp reference. k_pool/v_pool
    [NB,Hkv,bs,hd] int8; k_scale/v_scale [NB,Hkv,bs] f32."""
    kf = k_pool.astype(jnp.float32) * k_scale[..., None]
    vf = v_pool.astype(jnp.float32) * v_scale[..., None]
    return paged_decode_ref(q, kf, vf, tables, lengths)
