"""Paged flash-decode: one-token attention over a block-paged KV pool.

The KV cache is a global pool of fixed-size blocks [NB, Hkv, bs, hd];
each batch row names its blocks through a [B, MB] block table (vLLM-style
paged attention). The Pallas kernel scalar-prefetches the table and the
per-row valid lengths so block DMA addresses come straight from SMEM and
blocks past a row's current length are skipped entirely.
"""
from repro.kernels.paged_decode.ops import (  # noqa: F401
    paged_flash_decode,
    paged_gather_decode,
)
