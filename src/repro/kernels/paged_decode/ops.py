"""Public paged-decode ops: Pallas kernel + vectorized XLA gather fallback.

SERVE_PLAN (serve/scheduler.py) picks the Pallas path on TPU; on CPU the
serving hot loop runs layers.attention_paged_decode, which uses
gather_blocks() below to rebuild each row's contiguous KV view and then
the same attention_decode math as the slot pool — that shared fp path is
what keeps the greedy token-exact equivalence tests meaningful without
paying interpret-mode overhead. paged_gather_decode is the standalone
(cfg/env-free) composition of the same gather + masked softmax, used to
cross-check the kernel in tests.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.paged_decode.kernel import paged_flash_decode_kernel


def gather_blocks(pool, tables):
    """[NB,Hkv,bs,hd] pool + [B,MB] tables -> contiguous [B,Hkv,MB*bs,hd]
    per-row KV view (logical order == table order). The one gather
    implementation every XLA paged path shares."""
    B, MB = tables.shape
    _, Hkv, bs, hd = pool.shape
    return pool[tables].transpose(0, 2, 1, 3, 4).reshape(B, Hkv, MB * bs, hd)


@partial(jax.jit, static_argnames=("interpret",))
def paged_flash_decode(q, k_pool, v_pool, tables, lengths, *,
                       interpret: bool | None = None):
    """Paged decode attention via the Pallas kernel.

    q [B,Hq,hd]; k_pool/v_pool [NB,Hkv,bs,hd]; tables [B,MB]; lengths [B].
    Returns [B,Hq,hd] f32 (callers cast)."""
    if interpret is None:
        interpret = default_interpret()
    return paged_flash_decode_kernel(q, k_pool, v_pool, tables, lengths,
                                     interpret=interpret)


@jax.jit
def paged_gather_decode(q, k_pool, v_pool, tables, lengths):
    """XLA composition: gather_blocks + masked softmax attention — same
    math as the kernel, one materialized copy of the gathered KV."""
    B, Hq, hd = q.shape
    Hkv = k_pool.shape[1]
    g = Hq // Hkv
    kg = gather_blocks(k_pool, tables)
    vg = gather_blocks(v_pool, tables)
    qg = q.reshape(B, Hkv, g, hd)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, kg).astype(jnp.float32)
    s = s / math.sqrt(hd)
    kpos = jnp.arange(kg.shape[2])[None, None, None, :]
    s = jnp.where(kpos <= lengths[:, None, None, None], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    a = jnp.where(jnp.isfinite(a), a, 0.0)
    o = jnp.einsum("bhgk,bhkd->bhgd", a, vg.astype(jnp.float32))
    return o.reshape(B, Hq, hd)
