"""Public paged-decode ops: Pallas kernel + vectorized XLA gather fallback.

SERVE_PLAN (serve/scheduler.py) picks the Pallas path on TPU; on CPU the
serving hot loop runs layers.attention_paged_decode, which uses
gather_blocks() below to rebuild each row's contiguous KV view and then
the same attention_decode math as the slot pool — that shared fp path is
what keeps the greedy token-exact equivalence tests meaningful without
paying interpret-mode overhead. paged_gather_decode is the standalone
(cfg/env-free) composition of the same gather + masked softmax, used to
cross-check the kernel in tests.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.paged_decode.kernel import (
    paged_flash_decode_kernel,
    paged_flash_decode_quant_kernel,
)


def gather_blocks(pool, tables):
    """[NB,Hkv,bs,hd] pool + [B,MB] tables -> contiguous [B,Hkv,MB*bs,hd]
    per-row KV view (logical order == table order). The one gather
    implementation every XLA paged path shares."""
    B, MB = tables.shape
    _, Hkv, bs, hd = pool.shape
    return pool[tables].transpose(0, 2, 1, 3, 4).reshape(B, Hkv, MB * bs, hd)


def gather_block_scales(scales, tables):
    """[NB,Hkv,bs] scale pool + [B,MB] tables -> [B,Hkv,MB*bs] per-row
    scale view in the same logical order as gather_blocks."""
    B, MB = tables.shape
    _, Hkv, bs = scales.shape
    return scales[tables].transpose(0, 2, 1, 3).reshape(B, Hkv, MB * bs)


def quantize_kv(x, axis=-1, eps=1e-8):
    """Symmetric int8 quantization along `axis` (head_dim): returns
    (int8 values, f32 scales with `axis` reduced). scale = absmax/127,
    floored at eps so all-zero rows round-trip to zeros."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=axis) / 127.0, eps)
    q = jnp.clip(jnp.round(xf / jnp.expand_dims(scale, axis)), -127, 127)
    return q.astype(jnp.int8), scale


@partial(jax.jit, static_argnames=("interpret",))
def paged_flash_decode(q, k_pool, v_pool, tables, lengths, *,
                       interpret: bool | None = None):
    """Paged decode attention via the Pallas kernel.

    q [B,Hq,hd]; k_pool/v_pool [NB,Hkv,bs,hd]; tables [B,MB]; lengths [B].
    Returns [B,Hq,hd] f32 (callers cast)."""
    if interpret is None:
        interpret = default_interpret()
    return paged_flash_decode_kernel(q, k_pool, v_pool, tables, lengths,
                                     interpret=interpret)


@jax.jit
def paged_gather_decode(q, k_pool, v_pool, tables, lengths):
    """XLA composition: gather_blocks + masked softmax attention — same
    math as the kernel, one materialized copy of the gathered KV."""
    B, Hq, hd = q.shape
    Hkv = k_pool.shape[1]
    g = Hq // Hkv
    kg = gather_blocks(k_pool, tables)
    vg = gather_blocks(v_pool, tables)
    qg = q.reshape(B, Hkv, g, hd)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, kg).astype(jnp.float32)
    s = s / math.sqrt(hd)
    kpos = jnp.arange(kg.shape[2])[None, None, None, :]
    s = jnp.where(kpos <= lengths[:, None, None, None], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    a = jnp.where(jnp.isfinite(a), a, 0.0)
    o = jnp.einsum("bhgk,bhkd->bhgd", a, vg.astype(jnp.float32))
    return o.reshape(B, Hq, hd)


@partial(jax.jit, static_argnames=("interpret",))
def paged_flash_decode_quant(q, k_pool, v_pool, k_scale, v_scale, tables,
                             lengths, *, interpret: bool | None = None):
    """Quantized paged decode attention via the Pallas kernel.

    q [B,Hq,hd]; k_pool/v_pool [NB,Hkv,bs,hd] int8; k_scale/v_scale
    [NB,Hkv,bs] f32; tables [B,MB]; lengths [B]. Returns [B,Hq,hd] f32."""
    if interpret is None:
        interpret = default_interpret()
    return paged_flash_decode_quant_kernel(q, k_pool, v_pool, k_scale,
                                           v_scale, tables, lengths,
                                           interpret=interpret)


@jax.jit
def paged_gather_decode_quant(q, k_pool, v_pool, k_scale, v_scale, tables,
                              lengths):
    """XLA composition for the quant backend: gather int8 blocks + scales,
    dequantize, then the same masked softmax as paged_gather_decode."""
    B, Hq, hd = q.shape
    Hkv = k_pool.shape[1]
    g = Hq // Hkv
    kg = (gather_blocks(k_pool, tables).astype(jnp.float32)
          * gather_block_scales(k_scale, tables)[..., None])
    vg = (gather_blocks(v_pool, tables).astype(jnp.float32)
          * gather_block_scales(v_scale, tables)[..., None])
    qg = q.reshape(B, Hkv, g, hd)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, kg).astype(jnp.float32)
    s = s / math.sqrt(hd)
    kpos = jnp.arange(kg.shape[2])[None, None, None, :]
    s = jnp.where(kpos <= lengths[:, None, None, None], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    a = jnp.where(jnp.isfinite(a), a, 0.0)
    o = jnp.einsum("bhgk,bhkd->bhgd", a, vg)
    return o.reshape(B, Hq, hd)
