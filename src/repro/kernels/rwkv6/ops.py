"""jit'd wrapper for the RWKV-6 WKV Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import default_interpret
from repro.kernels.rwkv6.kernel import wkv6_kernel


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, *, chunk: int = 64, interpret: bool | None = None):
    """r,k,v,logw: [B,S,H,hd] (model layout); u: [H,hd]."""
    if interpret is None:
        interpret = default_interpret()
    rt, kt, vt, lt = (a.transpose(0, 2, 1, 3) for a in (r, k, v, logw))
    o, s_fin = wkv6_kernel(rt, kt, vt, lt, u, chunk=chunk, interpret=interpret)
    return o.transpose(0, 2, 1, 3), s_fin
