from repro.kernels.rwkv6.ops import wkv6  # noqa: F401
