"""RWKV-6 (Finch) WKV Pallas TPU kernel: chunked state recurrence.

o_t = r_t^T S_{t-1} + (r_t . (u*k_t)) v_t ;  S_t = diag(w_t) S_{t-1} + k_t v_t^T

TPU adaptation: the per-head [hd, hd] state lives in VMEM scratch across the
chunk grid dimension; each chunk closes the intra-chunk interaction with the
bounded pairwise-decay tensor (same stability trick as kernels/rglru) and two
MXU matmuls against the carried state.

Grid: (B, H, n_chunks) — chunks innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sfin_ref, s_sc,
                *, ct: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        s_sc[...] = jnp.zeros_like(s_sc)

    r = r_ref[0, 0].astype(jnp.float32)  # [ct, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)  # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)  # [1, hd]

    L = jnp.cumsum(lw, axis=0)  # [ct, hd]
    Lprev = L - lw
    # intra-chunk strictly-lower interactions
    diff = Lprev[:, None, :] - L[None, :, :]  # [t, s, hd]
    strict = (jax.lax.broadcasted_iota(jnp.int32, (ct, ct), 0)
              > jax.lax.broadcasted_iota(jnp.int32, (ct, ct), 1))
    D = jnp.where(strict[..., None], jnp.exp(jnp.clip(diff, -60.0, 0.0)), 0.0)
    A = jnp.einsum("tc,sc,tsc->ts", r, k, D)
    Au = jnp.sum(r * (u * k), axis=-1)  # diagonal bonus term [ct]
    o = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o = o + Au[:, None] * v
    # carried-state contribution
    rP = r * jnp.exp(jnp.clip(Lprev, -60.0, 0.0))
    o = o + jax.lax.dot_general(rP, s_sc[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0, 0] = o.astype(o_ref.dtype)
    # state update
    LT = L[-1]  # [hd]
    kT = k * jnp.exp(jnp.clip(LT[None, :] - L, -60.0, 0.0))
    s_sc[...] = (jnp.exp(jnp.clip(LT, -60.0, 0.0))[:, None] * s_sc[...]
                 + jax.lax.dot_general(kT, v, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32))

    @pl.when(c == n_chunks - 1)
    def _flush():
        sfin_ref[0, 0] = s_sc[...]


def wkv6_kernel(r, k, v, logw, u, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,logw: [B,H,S,hd]; u: [H,hd]. Returns (o [B,H,S,hd] f32,
    s_final [B,H,hd,hd] f32)."""
    B, H, S, hd = r.shape
    ct = min(chunk, S)
    assert S % ct == 0
    n = S // ct
    kern = functools.partial(_wkv_kernel, ct=ct, n_chunks=n)
    return pl.pallas_call(
        kern,
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, ct, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ct, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ct, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ct, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, ct, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, logw, u)
