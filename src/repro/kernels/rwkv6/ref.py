"""Pure-jnp sequential oracle for the RWKV-6 WKV recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u, s0=None):
    """Sequential scan. r,k,v,logw: [B,H,S,hd]; u: [H,hd].

    Returns (o [B,H,S,hd] f32, s_final [B,H,hd,hd] f32)."""
    B, H, S, hd = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    s = jnp.zeros((B, H, hd, hd), jnp.float32) if s0 is None else s0

    def step(s, xs):
        rt, kt, vt, wt = xs  # [B,H,hd]
        att = s + (u[None] * kt)[..., None] * vt[..., None, :]
        o = jnp.einsum("bhc,bhcd->bhd", rt, att)
        s = wt[..., None] * s + kt[..., None] * vt[..., None, :]
        return s, o

    xs = tuple(a.transpose(2, 0, 1, 3) for a in (rf, kf, vf, w))
    s_fin, os = jax.lax.scan(step, s, xs)
    return os.transpose(1, 2, 0, 3), s_fin
