"""Flash attention Pallas TPU kernel (causal / sliding-window / GQA).

TPU adaptation of the FlashAttention blocking (DESIGN.md §2): q/k/v tiles are
DMA'd HBM->VMEM per BlockSpec; the online-softmax statistics (m, l) and the
f32 accumulator live in VMEM scratch across the kv grid dimension; the MXU
consumes (bq, hd) x (hd, bk) tiles (hd and block sizes multiples of 128 on
real configs). Causally-masked kv blocks are *skipped* via pl.when — the
XLA fallback path cannot skip them, which is exactly the gap the kernel
closes on hardware.

Grid: (B, Hq, n_q_blocks, n_kv_blocks), kv innermost ("arbitrary" semantics,
sequential) so scratch carries across kv steps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *, scale: float,
               causal: bool, window: int, bq: int, bk: int, n_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = iq * bq
    k_start = ik * bk

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= qpos >= kpos
        if window > 0:
            ok &= (qpos - kpos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_sc[...]
        l_prev = l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_sc[...] = m_new
        l_sc[...] = l_new

    if causal or window > 0:
        needed = jnp.ones((), jnp.bool_)
        if causal:
            needed &= k_start <= q_start + bq - 1
        if window > 0:
            needed &= (k_start + bk - 1) >= (q_start - window + 1)
        pl.when(needed)(_compute)
    else:
        _compute()

    # flush on the last kv step for this q block
    if causal:
        last = jnp.minimum(n_kv - 1, (q_start + bq - 1) // bk)
    else:
        last = n_kv - 1

    @pl.when(ik == last)
    def _flush():
        o_ref[0, 0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(
            o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: [B,Hq,Sq,hd]; k,v: [B,Hkv,Sk,hd]. Returns [B,Hq,Sq,hd]."""
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(_fa_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, n_kv=nk)
    return pl.pallas_call(
        kern,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
