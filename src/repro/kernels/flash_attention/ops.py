"""jit'd public wrapper around the flash attention kernel.

Accepts the model's [B,S,H,hd] layout, handles GQA head mapping, picks
hardware-aligned block sizes, and falls back to interpret mode off-TPU.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_kernel


@partial(jax.jit, static_argnames=("causal", "window", "n_kv_heads",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    n_kv_heads: int | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """q: [B,Sq,Hq,hd], k/v: [B,Sk,Hkv,hd] -> [B,Sq,Hq*hd] (model layout)."""
    if interpret is None:
        interpret = default_interpret()
    B, Sq, Hq, hd = q.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_kernel(qt, kt, vt, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return o.transpose(0, 2, 1, 3).reshape(B, Sq, Hq * hd)
