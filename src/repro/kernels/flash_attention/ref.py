"""Pure-jnp oracle for flash attention (exact softmax attention)."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B,Hq,Sq,hd]; k,v: [B,Hkv,Sk,hd] (GQA broadcast). f32 math."""
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, Sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= qpos >= kpos
    if window > 0:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", a, vf)
    return o.reshape(B, Hq, Sq, hd).astype(q.dtype)
