"""Flash-decoding Pallas TPU kernel: one-token attention over a long KV cache.

Used for the sequence-sharded KV cache layout (DESIGN.md §4): each TP shard
runs this kernel over its cache slice producing a partial (o, m, l); the
shard_map wrapper in ops.py merges partials with logsumexp weights across the
TP axis. cur_len arrives via scalar prefetch (SMEM) so masked cache blocks
past the current length are skipped entirely.

Grid: (B, Hq, n_kv_blocks) — kv innermost/sequential; scratch carries (m,l,acc).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _fd_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
               m_sc, l_sc, acc_sc, *, scale: float, bk: int):
    j = pl.program_id(2)
    cur_len = len_ref[0]

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    k_start = j * bk

    @pl.when(k_start <= cur_len)
    def _compute():
        q = q_ref[...].reshape(1, -1).astype(jnp.float32)  # [1, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kpos <= cur_len, s, NEG_INF)
        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    n_kv = pl.num_programs(2)

    @pl.when(j == n_kv - 1)
    def _flush():
        o_ref[...] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(
            o_ref.dtype).reshape(o_ref.shape)
        m_ref[...] = m_sc[...].reshape(m_ref.shape)
        l_ref[...] = l_sc[...].reshape(l_ref.shape)


def flash_decode_kernel(q, k, v, cur_len, *, block_k: int = 512,
                        interpret: bool = False):
    """q: [B,Hq,hd]; k,v: [B,Hkv,S,hd]; cur_len: scalar int32 (local index of
    the last valid cache entry; -1 for an all-masked shard).

    Returns (o [B,Hq,hd], m [B,Hq,1], l [B,Hq,1]) — partial softmax stats for
    the cross-shard merge."""
    B, Hq, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bk = min(block_k, S)
    assert S % bk == 0
    nk = S // bk
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(_fd_kernel, scale=scale, bk=bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, j, L: (b, h, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, L, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, L, g=g: (b, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, j, L: (b, h, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, j, L: (b, h, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, j, L: (b, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    q3 = q.reshape(B, Hq, 1, hd)[:, :, 0]  # ensure contiguous [B,Hq,hd]
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(cur_len, jnp.int32).reshape(1), q3, k, v)


def merge_partials(o, m, l, axis_name: str):
    """LSE-merge partial attention outputs across a sharded cache axis.

    o: [B,Hq,hd] f32 (already normalized per shard), m/l: [B,Hq,1].
    """
    m_g = jax.lax.pmax(m, axis_name)
    w = l * jnp.exp(m - m_g)  # effective weight of each shard
    denom = jax.lax.psum(w, axis_name)
    num = jax.lax.psum(o * w, axis_name)
    return num / jnp.maximum(denom, 1e-30)
