"""Public flash-decoding ops: single-shard kernel + TP-sharded cache merge."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import default_interpret
from repro.kernels.flash_decode.kernel import flash_decode_kernel, merge_partials


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q, k, v, cur_len, *, block_k: int = 512,
                 interpret: bool | None = None):
    """Unsharded decode attention. q [B,Hq,hd]; k,v [B,Hkv,S,hd]."""
    if interpret is None:
        interpret = default_interpret()
    o, _, _ = flash_decode_kernel(q, k, v, cur_len, block_k=block_k,
                                  interpret=interpret)
    return o.astype(q.dtype)


def flash_decode_seq_sharded(mesh, tp_axis: str, q, k, v, cur_len, *,
                             block_k: int = 512, interpret: bool | None = None):
    """Flash-decoding over a cache whose seq dim is sharded over `tp_axis`.

    Each shard runs the kernel on its slice; partials merge with LSE weights
    (collective = one pmax + two psums of [B,Hq,hd] — tiny vs the cache read,
    which is the point of the layout).
    """
    if interpret is None:
        interpret = default_interpret()
    S = k.shape[2]
    tp = mesh.shape[tp_axis]
    s_local = S // tp

    def body(q_l, k_l, v_l):
        idx = jax.lax.axis_index(tp_axis)
        local_len = jnp.clip(cur_len - idx * s_local, -1, s_local - 1)
        o, m, l = flash_decode_kernel(q_l, k_l, v_l, local_len,
                                      block_k=min(block_k, s_local),
                                      interpret=interpret)
        # an all-masked shard produces l=0 -> zero weight in the merge
        return merge_partials(o, m, l, tp_axis)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), P(None, None, tp_axis, None),
                             P(None, None, tp_axis, None)),
                   out_specs=P(), check_rep=False)
    return fn(q, k, v).astype(q.dtype)
