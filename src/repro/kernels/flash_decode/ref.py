"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_ref(q, k, v, cur_len):
    """q: [B,Hq,hd]; k,v: [B,Hkv,S,hd]; attends to positions <= cur_len."""
    B, Hq, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, hd)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32)) / math.sqrt(hd)
    ok = jnp.arange(S) <= cur_len
    s = jnp.where(ok, s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", a, v.astype(jnp.float32))
    return o.reshape(B, Hq, hd)
