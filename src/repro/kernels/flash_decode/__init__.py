from repro.kernels.flash_decode.ops import (  # noqa: F401
    flash_decode,
    flash_decode_seq_sharded,
)
