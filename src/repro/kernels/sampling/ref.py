"""Sort-based oracle for the fused top-k/top-p mask.

Semantics (per row, over the valid vocab):
  top_k > 0:  keep logits >= the k-th largest logit (value ties all kept)
  top_p < 1:  keep probs >= the prob of the last token in the minimal
              descending-prob prefix whose mass reaches top_p (ties kept)
Dropped entries become NEG_INF so a downstream argmax / Gumbel-max can
never pick them. The row's argmax always survives both filters.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def topk_topp_mask_ref(logits, top_k, top_p):
    """logits [T,V] f32; top_k [T] int32 (<=0 off); top_p [T] f32 (>=1 off).

    Returns [T,V] f32: kept logits unchanged, dropped entries NEG_INF.
    """
    logits = logits.astype(jnp.float32)
    T, V = logits.shape
    desc = jnp.sort(logits, axis=-1)[:, ::-1]
    k = jnp.clip(top_k, 1, V)
    kth = jnp.take_along_axis(desc, (k - 1)[:, None], axis=-1)  # [T,1]
    keep_k = jnp.where(top_k[:, None] > 0, logits >= kth, True)

    lmax = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - lmax)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / denom
    # softmax is monotone, so the descending probs are the softmax of the
    # already-sorted logits — no second sort of the [T,V] matrix
    p_desc = jnp.exp(desc - lmax) / denom
    csum = jnp.cumsum(p_desc, axis=-1)
    # first index where the running mass reaches top_p = the minimal prefix
    idx = jnp.argmax(csum >= top_p[:, None], axis=-1)
    cutoff = jnp.take_along_axis(p_desc, idx[:, None], axis=-1)  # [T,1]
    keep_p = jnp.where(top_p[:, None] < 1.0, probs >= cutoff, True)

    return jnp.where(keep_k & keep_p, logits, NEG_INF)
