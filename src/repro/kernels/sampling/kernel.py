"""Pallas TPU kernel: per-row top-k / top-p logit mask without a sort.

A vocab sort is the classic way to find the top-k boundary and the nucleus
cutoff, but sorting 32-128k lanes per row per decode step is exactly the
memory traffic the fused serving step exists to avoid. Both thresholds are
monotone predicates of a scalar, so the kernel bisects instead:

  top-k:  largest t with count(logits >= t) >= k      (t -> k-th logit)
  top-p:  largest t with mass({prob >= t}) >= top_p   (t -> nucleus cutoff)

Each bisection is ITERS vectorized compare+reduce passes over the row held
in VMEM — no gather, no sort, no extra HBM round trip. The converged
threshold sits within (range / 2^ITERS) *below* the exact boundary, so
boundary ties are kept (same semantics as the sort-based oracle in ref.py);
an entry is misclassified only if it lies within that epsilon strictly
below the true cutoff.

Grid: (T,) — one program per batch row; per-row k and top_p ride in via
scalar prefetch (SMEM), like the block tables in kernels/paged_decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30
ITERS = 30  # f32 bisection: range/2^30 of slack at the boundary


def _mask_kernel(k_ref, p_ref, x_ref, o_ref, *, V: int):
    t = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # [1, Vp]
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = col < V
    x = jnp.where(valid, x, NEG_INF)
    k = k_ref[t]
    top_p = p_ref[t]

    xmax = jnp.max(x)
    xmin = jnp.min(jnp.where(valid, x, xmax))

    def k_body(_, lo_hi):
        lo, hi = lo_hi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(jnp.where(valid & (x >= mid), 1, 0))
        ok = cnt >= k
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    k_thr, _ = jax.lax.fori_loop(0, ITERS, k_body, (xmin, xmax + 1.0))
    keep = jnp.where(k > 0, x >= k_thr, True)

    e = jnp.where(valid, jnp.exp(x - xmax), 0.0)
    probs = e / jnp.sum(e)

    def p_body(_, lo_hi):
        lo, hi = lo_hi
        mid = 0.5 * (lo + hi)
        mass = jnp.sum(jnp.where(probs >= mid, probs, 0.0))
        ok = mass >= top_p
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    p_thr, _ = jax.lax.fori_loop(0, ITERS, p_body,
                                 (jnp.float32(0.0), jnp.float32(1.0)))
    keep = keep & jnp.where(top_p < 1.0, probs >= p_thr, True)

    o_ref[...] = jnp.where(keep, x, NEG_INF).astype(o_ref.dtype)


def topk_topp_mask_kernel(logits, top_k, top_p, *, interpret: bool = False):
    """logits [T,V] (any float dtype); top_k [T] int32; top_p [T] f32.

    Returns [T,V] f32 with dropped entries at NEG_INF. V is padded to the
    lane width internally; padded columns never survive the mask.
    """
    T, V = logits.shape
    Vp = -(-V // 128) * 128
    if Vp != V:
        pad = jnp.full((T, Vp - V), NEG_INF, logits.dtype)
        logits = jnp.concatenate([logits, pad], axis=1)

    kern = functools.partial(_mask_kernel, V=V)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # per-row k and top_p land in SMEM
        grid=(T,),
        in_specs=[pl.BlockSpec((1, Vp), lambda t, k, p: (t, 0))],
        out_specs=pl.BlockSpec((1, Vp), lambda t, k, p: (t, 0)),
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, Vp), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(jnp.asarray(top_k, jnp.int32), jnp.asarray(top_p, jnp.float32), logits)
    return out[:, :V]
