"""Public fused top-k/top-p mask op.

The serving decode step (launch/steps.py make_sample_fn) calls this inside
its jit: on TPU it lowers to the Pallas bisection kernel, elsewhere to the
sort-based XLA reference — the same keep-set semantics either way, so the
seeded-sampling reproducibility tests are meaningful on every backend
(interpret-mode Pallas is reserved for the kernel-vs-oracle tests; running
it in the CPU serving hot loop would pay interpreter overhead per step).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.sampling.kernel import topk_topp_mask_kernel
from repro.kernels.sampling.ref import topk_topp_mask_ref


def _default_impl() -> str:
    try:
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    except Exception:  # pragma: no cover - backend probe failure
        return "xla"


@partial(jax.jit, static_argnames=("impl",))
def topk_topp_mask(logits, top_k, top_p, *, impl: str | None = None):
    """logits [T,V]; top_k [T] int32 (<=0 off); top_p [T] f32 (>=1 off).

    Returns [T,V] f32: kept logits unchanged, dropped entries at NEG_INF.
    impl: "pallas" | "interpret" (Pallas in interpreter mode) | "xla";
    None picks pallas on TPU, xla elsewhere.
    """
    impl = impl or _default_impl()
    if impl == "pallas":
        return topk_topp_mask_kernel(logits, top_k, top_p, interpret=False)
    if impl == "interpret":
        return topk_topp_mask_kernel(logits, top_k, top_p, interpret=True)
    assert impl == "xla", impl
    return topk_topp_mask_ref(logits, top_k, top_p)
