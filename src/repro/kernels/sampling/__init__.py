"""Fused top-k / top-p (nucleus) logit masking for the serving sample step.

The decode step keeps logits on device: after the forward pass each row's
logits are masked to its request's top-k count and top-p mass, then
Gumbel-max sampled (launch/steps.py). On TPU the mask is a Pallas kernel
(one VMEM-resident pass per row, thresholds found by bisection — no sort);
elsewhere the same semantics run as the sort-based XLA reference.
"""
from repro.kernels.sampling.ops import topk_topp_mask  # noqa: F401
from repro.kernels.sampling.ref import topk_topp_mask_ref  # noqa: F401
