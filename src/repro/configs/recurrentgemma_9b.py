"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 2:1.
[arXiv:2402.19427; unverified]

38 layers = 12 x (rglru, rglru, local) + tail (rglru, rglru).
Sub-quadratic (local window 2048) -> runs the long_500k shape.
MQA (kv=1): KV replicated across TP, Q heads sharded.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    rope_theta=10_000.0,
    block_pattern=("rglru", "rglru", "local"),
    pattern_tail=("rglru", "rglru"),
    local_window=2048,
    lru_width=4096,
    conv_width=4,
    source="arXiv:2402.19427; unverified",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        block_pattern=("rglru", "rglru", "local"),
        pattern_tail=("rglru", "rglru"),
        local_window=16,
        lru_width=64,
        conv_width=4,
    )
