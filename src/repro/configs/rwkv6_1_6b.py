"""rwkv6-1.6b — "Finch": attention-free RNN with data-dependent decay.
[arXiv:2404.05892; unverified]

head_size 64 -> 32 time-mix heads. Attention-free -> runs long_500k.
RWKV-6 channel-mix uses d_ff = 7168 (the assignment's d_ff).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,  # time-mix heads (d_model / head_dim)
    n_kv_heads=0,  # attention-free
    d_ff=7168,
    vocab_size=65536,
    head_dim=64,
    block_pattern=("rwkv",),
    source="arXiv:2404.05892; unverified",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=0,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        block_pattern=("rwkv",),
    )
