"""qwen2-1.5b — dense GQA LM with QKV bias. [arXiv:2407.10671; hf]

12 query heads are not divisible by the 16-way TP axis; padded_heads pads the
Q projection to 16 heads (4 zero heads) for the production mesh. The waste is
visible in the MODEL_FLOPS/HLO_FLOPS ratio (EXPERIMENTS.md §Roofline).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    block_pattern=("attn",),
    source="arXiv:2407.10671; hf",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=3,  # deliberately non-power-of-two: exercises head padding
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        qkv_bias=True,
        block_pattern=("attn",),
    )
