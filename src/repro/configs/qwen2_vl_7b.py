"""qwen2-vl-7b — VLM backbone with M-RoPE; vision frontend STUBBED.
[arXiv:2409.12191; hf]

Per the assignment the patch embedder is a stub: input_specs() provides
precomputed patch embeddings (num_vision_embeds x d_model) prepended to the
token stream. M-RoPE splits each head's rotary dims into (temporal, h, w)
sections (16, 24, 24 pairs). 28 heads pad to 32 for the 16-way TP axis.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    num_vision_embeds=256,
    block_pattern=("attn",),
    source="arXiv:2409.12191; hf",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        qkv_bias=True,
        mrope=True,
        mrope_sections=(2, 3, 3),
        num_vision_embeds=8,
        block_pattern=("attn",),
    )
