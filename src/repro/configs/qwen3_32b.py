"""qwen3-32b — dense GQA LM with qk-norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=("attn",),
    source="hf:Qwen/Qwen3-8B; hf",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        qk_norm=True,
        block_pattern=("attn",),
    )
