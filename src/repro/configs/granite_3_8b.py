"""granite-3-8b — dense GQA LM. [hf:ibm-granite/granite-3.0-2b-base; hf]

vocab 49155 is not divisible by the 16-way TP axis; ModelConfig.padded_vocab
pads it to 49280 (multiple of 128) for the embedding/unembedding shards.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    head_dim=128,
    rope_theta=10_000.0,
    block_pattern=("attn",),
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=515,  # deliberately odd: exercises vocab padding
        head_dim=16,
        block_pattern=("attn",),
    )
