"""Config dataclasses for models, input shapes, and parallelism plans.

Every assigned architecture is a `ModelConfig`; every assigned input shape is a
`ShapeConfig`; the sharding strategy for an (arch x shape x mesh) cell is a
`ParallelPlan`.  Configs are frozen and content-hashable — the `ClusterImage`
(core/image.py) digests them, which is the JAX analogue of the paper's Docker
image encapsulation.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# --------------------------------------------------------------------------
# Block kinds (the repeating unit of a model is a tuple of these):
#   "attn"   dense GQA attention + SwiGLU MLP (pre-RMSNorm, residual)
#   "moe"    dense GQA attention + mixture-of-experts MLP
#   "local"  sliding-window GQA attention + MLP (Griffin local block)
#   "rglru"  Griffin recurrent block (conv1d + RG-LRU) + MLP
#   "rwkv"   RWKV-6 time-mix + channel-mix
#   "enc"    bidirectional encoder attention + MLP (whisper encoder)
#   "dec"    causal self-attn + cross-attn + MLP (whisper decoder)
# --------------------------------------------------------------------------

VALID_KINDS = ("attn", "moe", "local", "rglru", "rwkv", "enc", "dec")


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | audio | ssm | vlm
    n_layers: int  # decoder layers (repeating pattern + tail)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False  # Qwen2-VL multimodal rope
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # block structure: the repeating unit & optional non-repeating tail.
    block_pattern: Tuple[str, ...] = ("attn",)
    pattern_tail: Tuple[str, ...] = ()
    # hybrid / ssm extras
    local_window: int = 0  # sliding window for "local" blocks
    lru_width: int = 0  # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4  # Griffin temporal conv
    # moe
    moe: Optional[MoEConfig] = None
    # enc-dec
    encoder_layers: int = 0
    enc_downsample: int = 1  # stub frontend downsample factor (whisper conv =2)
    # vlm
    num_vision_embeds: int = 0  # prepended precomputed patch embeds (stub)
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act_dtype: str = "bfloat16"
    source: str = ""  # provenance tag from the assignment table

    def __post_init__(self):
        for k in self.block_pattern + self.pattern_tail:
            if k not in VALID_KINDS:
                raise ValueError(f"unknown block kind {k!r}")
        n_pat = self.n_layers - len(self.pattern_tail)
        if len(self.block_pattern) == 0 or n_pat % len(self.block_pattern):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} incompatible with "
                f"pattern {self.block_pattern} + tail {self.pattern_tail}"
            )

    # ---- derived ---------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        """Scan trip count over the repeating unit."""
        return (self.n_layers - len(self.pattern_tail)) // len(self.block_pattern)

    @property
    def attn_free(self) -> bool:
        kinds = set(self.block_pattern) | set(self.pattern_tail)
        return kinds <= {"rwkv"}

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends over the full (unbounded) context."""
        kinds = set(self.block_pattern) | set(self.pattern_tail)
        full_attn = {"attn", "moe", "enc", "dec"}
        return not (kinds & full_attn)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    # TP divisibility padding (model axis = 16): see DESIGN.md §4
    def padded_vocab(self, tp: int = 16) -> int:
        return _round_up(self.vocab_size, max(128, tp))

    def padded_heads(self, tp: int = 16) -> int:
        return _round_up(self.n_heads, tp)

    @property
    def rglru_width(self) -> int:
        return self.lru_width or self.d_model

    # ---- parameter count (for MODEL_FLOPS = 6*N*D) ------------------------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (unpadded). active_only: MoE top-k only."""
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads

        def attn_params() -> int:
            p = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
            if self.qkv_bias:
                p += (nq + 2 * nkv) * hd
            if self.qk_norm:
                p += 2 * hd
            return p

        def mlp_params() -> int:
            return 3 * d * ff  # SwiGLU: gate, up, down

        def moe_params() -> int:
            assert self.moe is not None
            e = self.moe.num_experts if not active_only else self.moe.top_k
            return e * 3 * d * ff + d * self.moe.num_experts  # experts + router

        def rglru_params() -> int:
            w = self.rglru_width
            # in/gate proj, conv1d, lru gates (input+rec), out proj + mlp
            return 2 * d * w + self.conv_width * w + 2 * w * w // 1 + w * d + mlp_params()

        def local_params() -> int:
            return attn_params() + mlp_params()

        def rwkv_params() -> int:
            # time-mix: r,k,v,g,o projections + decay lora + tokenshift lerps
            tm = 5 * d * d + 2 * d * 64 + 6 * d
            cm = 2 * d * self.d_ff // 2 + d * d  # rwkv channel mix (k->ff, v->d)
            return tm + cm

        per_kind = {
            "attn": lambda: attn_params() + mlp_params(),
            "moe": lambda: attn_params() + moe_params(),
            "local": local_params,
            "rglru": rglru_params,
            "rwkv": rwkv_params,
            "enc": lambda: attn_params() + mlp_params(),
            "dec": lambda: 2 * attn_params() + mlp_params(),
        }
        total = 0
        for k in self.block_pattern:
            total += per_kind[k]() * self.num_blocks
        for k in self.pattern_tail:
            total += per_kind[k]()
        total += self.encoder_layers * per_kind["enc"]()
        total += d * self.vocab_size * (1 if self.tie_embeddings else 2)
        return total

    def digest(self) -> str:
        payload = json.dumps(dataclasses.asdict(self), sort_keys=True, default=str)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    long_context: bool = False

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode", long_context=True),
}


@dataclass(frozen=True)
class ParallelPlan:
    """How an (arch x shape) cell is laid out on the mesh.

    Axis names must exist in the mesh ("pod" is silently dropped on the
    single-pod mesh).
    """
    dp_axes: Tuple[str, ...] = ("pod", "data")
    tp_axis: str = "model"
    fsdp: bool = True  # shard weights/opt-state over dp_axes[-1] too
    remat: str = "nothing"  # nothing | dots | full(=no remat)
    attn_impl: str = "xla_chunked"  # naive | xla_chunked | pallas
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    kv_cache: str = "seq_sharded"  # replicated | seq_sharded (over tp_axis)
    moe_mode: str = "auto"  # auto | ep | tp  (ep needs E % tp_size == 0)
    scan_unroll: int = 1
    seq_shard_acts: bool = True  # Megatron-SP style: residual stream
    # sequence-sharded over tp between blocks (cuts saved-activation
    # residency ~tp x; the extra all-gather/reduce-scatter shows up in
    # the collective term)
    inner_unroll: bool = False  # unroll attention/rwkv chunk scans (roofline
    # unit lowerings need exact per-unit HLO costs; see launch/roofline.py)
    rwkv_chunk: int = 64
    # gradient sync
    grad_compression: str = "none"  # none | int8_ef (cross-pod)

    def resolve_moe(self, cfg: ModelConfig, tp_size: int) -> str:
        if self.moe_mode != "auto":
            return self.moe_mode
        if cfg.moe and cfg.moe.num_experts % tp_size == 0:
            return "ep"
        return "tp"


def default_plan(cfg: ModelConfig, shape: ShapeConfig) -> ParallelPlan:
    """Baseline (paper-faithful-era) plan; hillclimbs override fields."""
    big = cfg.param_count() > 3e9
    return ParallelPlan(
        fsdp=big or cfg.moe is not None,
        remat="nothing" if shape.kind == "train" else "full",
        attn_impl="xla_chunked",
        kv_cache="seq_sharded" if shape.kind in ("decode", "prefill")
        else "replicated",
    )
