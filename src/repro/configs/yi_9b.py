"""yi-9b — llama-arch dense GQA LM. [arXiv:2403.04652; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    rope_theta=10_000.0,
    block_pattern=("attn",),
    source="arXiv:2403.04652; hf",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-9b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        block_pattern=("attn",),
    )
