"""Architecture config registry: --arch <id> resolution."""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Tuple

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    MoEConfig,
    ParallelPlan,
    ShapeConfig,
    default_plan,
)

# arch id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "yi-9b": "yi_9b",
    "granite-3-8b": "granite_3_8b",
    "qwen3-32b": "qwen3_32b",
    "qwen2-1.5b": "qwen2_1_5b",
    "grok-1-314b": "grok_1_314b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-small": "whisper_small",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "paper-demo": "paper_demo",
}

ARCH_IDS: Tuple[str, ...] = tuple(k for k in _ARCH_MODULES if k != "paper-demo")


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).smoke()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(include_long_skips: bool = False):
    """Yield every assigned (arch, shape) cell.

    long_500k needs sub-quadratic attention: only hybrid/ssm archs run it
    (DESIGN.md §5); pure full-attention archs are skipped unless
    include_long_skips (which yields them tagged for the skip table).
    """
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if shape.long_context and not cfg.sub_quadratic:
                if include_long_skips:
                    yield arch, sname, "skip:full-attention"
                continue
            yield arch, sname, "run"
