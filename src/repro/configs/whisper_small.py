"""whisper-small — encoder-decoder backbone; conv frontend STUBBED.
[arXiv:2212.04356; unverified]

Per the assignment, the modality frontend is a stub: input_specs() provides
precomputed frame embeddings (post-conv, 2x downsampled). Decode shapes lower
the decoder serve_step with self- and cross-attention KV caches. The assigned
sequence lengths are mechanical, not speech-realistic (DESIGN.md §5).
12 heads pad to 16 for the TP axis; vocab 51865 pads to 51968.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,  # decoder layers
    encoder_layers=12,
    enc_downsample=2,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,  # MHA
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    block_pattern=("dec",),
    source="arXiv:2212.04356; unverified",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke",
        family="audio",
        n_layers=2,
        encoder_layers=2,
        enc_downsample=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=515,
        head_dim=16,
        block_pattern=("dec",),
    )
