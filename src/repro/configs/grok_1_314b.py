"""grok-1-314b — MoE LM, 8 experts top-2. [hf:xai-org/grok-1; unverified]

8 experts are not divisible by the 16-way TP axis, so EP-over-model is
inapplicable (DESIGN.md §7): experts use TP-within-expert (ff over "model")
with FSDP over "data". Optimizer states are int8-blockwise to fit one pod.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=8, top_k=2),
    block_pattern=("moe",),
    source="hf:xai-org/grok-1; unverified",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2),
        block_pattern=("moe",),
    )
