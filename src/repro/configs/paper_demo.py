"""paper-demo — the paper's own experiment, transcribed.

Yu & Huang ran a 3-node virtual cluster (1 head + 2 compute containers on
Dell M620 blades, 10GbE) and a 16-rank MPI job (Fig. 8). This config captures
that scenario for the faithful-reproduction tests and benchmarks: a 3-node
VirtualCluster running a 16-domain SPMD job, plus a tiny LM standing in for
"the application" so the elastic runtime has real state to reshard.
"""
from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class PaperClusterSpec:
    n_head_nodes: int = 1
    n_compute_nodes: int = 2
    mpi_ranks: int = 16  # the paper's 16-domain MPI job
    interconnect_gbps: float = 10.0  # 10GbE in Table I
    consul_ttl_s: float = 1.0  # health-check TTL (sim time)


CLUSTER = PaperClusterSpec()

# A ~100M-param LM used by the end-to-end examples (examples/quickstart.py):
# the modern analogue of the paper's MPI application.
CONFIG = ModelConfig(
    name="paper-demo-110m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    head_dim=64,
    block_pattern=("attn",),
    source="paper §IV scaled to a ~100M LM",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="paper-demo-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        block_pattern=("attn",),
    )
