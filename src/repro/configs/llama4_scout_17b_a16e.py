"""llama4-scout-17b-a16e — MoE LM, 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

16 experts / 16-way TP axis -> expert-parallel (EP) sharding: one expert per
model shard, tokens routed via all_to_all inside shard_map.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=1),
    block_pattern=("moe",),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=1),
        block_pattern=("moe",),
    )
