"""Error-feedback int8 gradient compression for the cross-pod sync axis.

Real deployments compress the DCN-crossing gradient traffic; XLA collectives
have no int8-allreduce wire format, so this module reproduces the *numerics*
(per-row int8 quantization with error feedback accumulating the residual)
inside shard_map — the convergence behavior is faithful, the wire saving is
modeled in the roofline collective term (launch/roofline.py applies the 4x
byte discount when plan.grad_compression == "int8_ef").
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _q(x):
    if x.ndim < 2:
        return x, None
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq(q, scale):
    return q if scale is None else q.astype(jnp.float32) * scale


def compress_decompress(x, err):
    """One EF-compression round on a local tensor.

    Returns (decompressed, new_err): decompressed is what the wire would
    carry (int8-quantized view of x+err); new_err is the residual."""
    xf = x.astype(jnp.float32) + err
    q, s = _q(xf)
    dq = _dq(q, s)
    return dq, xf - dq


def ef_psum_grads(grads: Pytree, err: Pytree, axis_name: str
                  ) -> Tuple[Pytree, Pytree]:
    """Error-feedback compressed psum over `axis_name` (use inside shard_map).

    Each shard quantizes (grad + carried error) to int8, the quantized views
    are summed across the axis, and the local quantization residual feeds
    back next step."""
    def one(g, e):
        dq, new_e = compress_decompress(g, e)
        return jax.lax.pmean(dq, axis_name), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def init_error(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
