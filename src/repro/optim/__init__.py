from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_lr,
    global_norm,
    make_train_state,
)
from repro.optim.compress import ef_psum_grads, init_error  # noqa: F401
