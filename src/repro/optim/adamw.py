"""AdamW with fp32 or int8-blockwise moment states + global-norm clipping.

int8 states (per-row dynamic quantization, error visible as slightly noisy
moments) cut optimizer memory from 8 to ~2.1 bytes/param — the difference
between grok-1-314b fitting one 256-chip pod or not (DESIGN.md §4).
Convergence of the int8 path is exercised in tests/test_optim.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "fp32"  # fp32 | int8
    # schedule
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


# ---- int8 blockwise (per-row) quantization ---------------------------------


def _quantize(x):
    """f32 -> (int8, f32 scale over all-but-last dim). 1D tensors pass through."""
    if x.ndim < 2:
        return x, None
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def _dequantize(q, scale):
    if scale is None:
        return q
    return q.astype(jnp.float32) * scale[..., None]


# ---- state ------------------------------------------------------------------


def adamw_init(params: Pytree, cfg: AdamWConfig) -> Dict[str, Pytree]:
    def init_m(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.state_dtype == "int8":
            q, s = _quantize(z)
            return {"q": q, "s": s} if s is not None else {"q": z, "s": None}
        return z

    # copy=True: fp32 leaves would otherwise alias params (breaks donation)
    master = jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": master,
        "m": jax.tree.map(init_m, params),
        "v": jax.tree.map(init_m, params),
    }


def global_norm(tree: Pytree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads: Pytree, state: Dict[str, Pytree], cfg: AdamWConfig
                 ) -> Tuple[Pytree, Dict[str, Pytree]]:
    """Returns (new_params_bf16, new_state). grads match param structure."""
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    is_q = cfg.state_dtype == "int8"

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * clip
        mm = _dequantize(m["q"], m["s"]) if is_q else m
        vv = _dequantize(v["q"], v["s"]) if is_q else v
        mm = cfg.b1 * mm + (1 - cfg.b1) * g
        vv = cfg.b2 * vv + (1 - cfg.b2) * jnp.square(g)
        mhat = mm / b1c
        vhat = vv / b2c
        newp = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                              + cfg.weight_decay * master)
        if is_q:
            mq, ms = _quantize(mm)
            vq, vs = _quantize(vv)
            return newp, ({"q": mq, "s": ms}, {"q": vq, "s": vs})
        return newp, (mm, vv)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_master = jax.tree.leaves(state["master"])
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, ma, m, v)
           for g, ma, m, v in zip(flat_g, flat_master, flat_m, flat_v)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1][0] for o in out])
    new_v = treedef.unflatten([o[1][1] for o in out])
    new_params = jax.tree.map(
        lambda ma, old: ma.astype(old.dtype), new_master,
        treedef.unflatten(flat_g))
    return new_params, {"step": step, "master": new_master,
                        "m": new_m, "v": new_v}


def make_train_state(params: Pytree, cfg: AdamWConfig) -> Dict[str, Pytree]:
    return {"params": params, "opt": adamw_init(params, cfg)}
