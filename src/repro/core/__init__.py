"""The paper's contribution (P1-P4, DESIGN.md §2) as a composable runtime."""
from repro.core.agent import NodeAgent  # noqa: F401
from repro.core.autoscaler import (  # noqa: F401
    AutoScaler,
    LatencyPolicy,
    QueueDepthPolicy,
    ScalePlan,
    StepTimePolicy,
    StragglerPolicy,
    TargetSizePolicy,
)
from repro.core.clock import ManualClock, RealClock  # noqa: F401
from repro.core.cluster import VirtualCluster  # noqa: F401
from repro.core.elastic import ElasticTrainer  # noqa: F401
from repro.core.image import ClusterImage, ImageHub  # noqa: F401
from repro.core.membership import HPC_SERVICE, ClusterView, ViewTracker  # noqa: F401
from repro.core.registry import ReplicatedRegistry, ServiceRegistry  # noqa: F401
from repro.core.simnet import SimCluster  # noqa: F401
from repro.core.template import MeshTemplate, render_hostfile  # noqa: F401
