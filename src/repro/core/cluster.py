"""VirtualCluster — the paper's Fig. 4 system as one facade.

Wires together: ReplicatedRegistry (Consul trio) + SimCluster (blades &
containers) + MeshTemplate (consul-template) + AutoScaler + ElasticTrainer.
`submit()` is the `mpirun` analogue: run an SPMD function over the currently
rendered mesh (hostfile).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.core.agent import NodeAgent
from repro.core.autoscaler import (AutoScaler, Policy, ScalePlan,
                                   TargetSizePolicy)
from repro.core.clock import Clock, ManualClock
from repro.core.image import ClusterImage, ImageHub
from repro.core.membership import HPC_SERVICE
from repro.core.registry import ReplicatedRegistry
from repro.core.simnet import SimCluster
from repro.core.template import MeshTemplate, Rendering


class VirtualCluster:
    def __init__(self, *, n_compute: int = 2, devices_per_node: int = 1,
                 n_registry_replicas: int = 3, ttl: float = 2.0,
                 clock: Optional[Clock] = None,
                 image: Optional[ClusterImage] = None,
                 policy: Optional[Policy] = None,
                 cooldown_s: float = 0.0,
                 metrics_ttl_s: Optional[float] = None):
        self.clock = clock or ManualClock()
        self.registry = ReplicatedRegistry(n_registry_replicas, self.clock)
        self.hub = ImageHub()
        self.image = image
        digest = ""
        if image is not None:
            digest = self.hub.push(image)
        self.sim = SimCluster(self.registry, clock=self.clock,
                              devices_per_node=devices_per_node, ttl=ttl,
                              image_digest=digest)
        self.template = MeshTemplate(self.registry, clock=self.clock)
        self.scaler = AutoScaler(policy or TargetSizePolicy(n_compute),
                                 provisioner=self.sim, clock=self.clock,
                                 cooldown_s=cooldown_s,
                                 metrics_ttl_s=metrics_ttl_s)
        self.head_id = self.sim.add_head()
        self.sim.add_nodes(n_compute)
        self.pump()

    # -- control-plane pump ------------------------------------------------------
    def pump(self, dt: float = 0.0, autoscale: bool = False) -> Rendering:
        self.sim.pump(dt)
        if autoscale:
            view = self.current_view()
            metrics = self.scaler.read_metrics(self.registry)
            self.scaler.step(view, metrics)
            self.sim.pump()
        return self.template.poll() or self.template.rendering

    def current_view(self):
        self.template.poll()
        return self.template.tracker.view

    @property
    def rendering(self) -> Rendering:
        r = self.template.rendering
        assert r is not None
        return r

    @property
    def hostfile(self) -> str:
        return self.rendering.hostfile

    # -- image checks (paper §III-A: no version-skew clusters) ---------------------
    def verify_images(self) -> bool:
        entries = self.registry.catalog(HPC_SERVICE)
        digests = {e.meta.get("image", "") for e in entries}
        return len(digests) <= 1

    # -- the mpirun analogue --------------------------------------------------------
    def submit(self, spmd_fn: Callable, *args, **kwargs):
        """Run an SPMD function over the current mesh (jit under mesh ctx)."""
        r = self.rendering
        assert r.mesh is not None, "cluster has no devices"
        with r.mesh:
            return spmd_fn(r.mesh, *args, **kwargs)

    # -- scaling API -------------------------------------------------------------------
    def scale_to(self, n: int) -> Rendering:
        """Operator-issued one-shot resize. Applies a single plan directly;
        a metric-driven autoscaling policy stays in charge of subsequent
        reconcile iterations (it is NOT replaced). A TargetSizePolicy —
        including the constructor default — is retargeted to `n` so later
        autoscale pumps (e.g. straggler healing) hold the operator's size
        instead of reverting to the old pin."""
        if isinstance(self.scaler.policy, TargetSizePolicy):
            self.scaler.policy.target = n
        view = self.current_view()
        self.scaler.apply_plan(view, ScalePlan(n, reason=f"scale_to({n})"))
        self.sim.pump()
        return self.template.poll() or self.rendering

    # -- long-running serving (continuous batching; serve/scheduler.py) ------------------
    def serve(self, engine, requests=(), *, dt=0.05, autoscale: bool = True,
              max_steps: int = 100_000, on_step=None):
        """Drive a serving engine to completion against this cluster —
        a single ServingEngine, or a multi-replica ReplicaSet
        (serve/router.py), detected by its reconcile/metric_sources
        surface.

        Each iteration: one scheduler step (admit / mixed-batch decode +
        prefill lanes / retire), publish the engine's metrics through the
        head node's agent into the registry KV, then pump the control
        plane with autoscaling — so the installed policy
        (QueueDepthPolicy, LatencyPolicy, ...) resizes the cluster
        *mid-serve* from live load. The snapshot carries whatever load
        signals the engine's KVBackend reports (the paged BlockManager
        adds kv_block_occupancy — committed blocks, the signal that
        actually gates admission) plus deadline_misses, which an EDF
        scheduler feeds back into LatencyPolicy scale-up votes.

        With a ReplicaSet the loop closes all the way through the data
        plane: each replica's snapshot is published as its own metric
        source (the autoscaler aggregates per replica), released replicas
        have their keys tombstoned immediately, and after every pump the
        fleet is reconciled to the applied plan's compute-node count —
        a scale-up spawns a cold replica, a scale-down drains one for
        real (serve/router.py has the lifecycle).

        `dt` is the simulated wall time of one decode step: a float, or a
        callable (n_compute -> seconds). With a single engine the callable
        models data-parallel speedup (more nodes drain the shared queue
        faster); a ReplicaSet's speedup is real — every live replica
        decodes its own batch within the tick — so a constant dt is the
        honest choice there. The engine must share this cluster's clock.

        Returns engine.results() (rid -> tokens).
        """
        assert engine.clock is self.clock, \
            "engine must be built with clock=cluster.clock"
        engine.submit(requests)
        head_agent = self.sim.nodes[self.head_id].agent
        reconcile = getattr(engine, "reconcile", None)
        sources = getattr(engine, "metric_sources", None)
        steps = 0
        while not engine.drained() and steps < max_steps:
            snap = engine.step()
            if sources is not None:
                for src, m in sources().items():
                    head_agent.report_serving(m, source=src)
                for src in engine.pop_retired_sources():
                    head_agent.retire_source(src)
            else:
                head_agent.report_serving(snap)
            n = max(len(self.current_view().compute), 1)
            step_dt = dt(n) if callable(dt) else dt
            self.pump(dt=step_dt, autoscale=autoscale)
            if reconcile is not None:
                reconcile(max(len(self.current_view().compute), 1))
            if on_step is not None:
                on_step(steps, snap, self)
            steps += 1
        if not engine.drained():
            raise RuntimeError(f"serve did not drain in {max_steps} steps")
        return engine.results()

    # -- fault injection passthrough -----------------------------------------------------
    def crash_node(self, node_id: str) -> None:
        self.sim.crash(node_id)

    def compute_nodes(self) -> List[str]:
        view = self.current_view()
        return [m.node_id for m in view.compute]

    def shutdown(self) -> None:
        self.sim.remove_nodes(list(self.sim.nodes))
