"""MeshTemplate — the consul-template analogue (paper §IV, Fig. 5).

consul-template watched the Consul catalog and re-rendered the MPI hostfile.
Here the rendered artifacts are (a) the hostfile text (kept for fidelity and
published to the KV store like the template's output file), and (b) the
**jax.sharding.Mesh** built from the devices the live members contribute —
"the device mesh is the hostfile" (DESIGN.md §2). Re-rendering is triggered
by registry-index watches and debounced.

Single-CPU containers run "oversubscribed": many simulated nodes map onto
the one real device; with --xla_force_host_platform_device_count (subprocess
tests, dry-run) members own disjoint real host devices and the mesh is a
genuine multi-device mesh.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh
import numpy as np

from repro.core.membership import (HPC_SERVICE, ClusterView, ViewDiff,
                                   ViewTracker)

HOSTFILE_KEY = "rendered/hostfile"


@dataclass(frozen=True)
class Rendering:
    epoch: int
    hostfile: str
    mesh: Optional[Mesh]
    oversubscribed: bool
    view: ClusterView


def render_hostfile(view: ClusterView) -> str:
    """The paper's hostfile, one line per live node (mpirun format)."""
    lines = [f"# epoch {view.epoch}; rendered from {HPC_SERVICE} catalog"]
    for m in view.members:
        lines.append(f"{m.node_id} slots={m.n_devices}  # {m.address} "
                     f"role={m.role}")
    return "\n".join(lines) + "\n"


def default_mesh_rule(n: int, max_model: int = 16) -> Tuple[Tuple[int, int],
                                                            Tuple[str, str]]:
    """Factor n devices into ("data","model") with the largest model degree
    <= max_model that divides n."""
    model = 1
    for cand in range(min(max_model, n), 0, -1):
        if n % cand == 0:
            model = cand
            break
    return (n // model, model), ("data", "model")


def render_mesh(view: ClusterView,
                devices: Optional[Sequence] = None,
                mesh_rule: Callable = default_mesh_rule
                ) -> Tuple[Optional[Mesh], bool]:
    """Build the Mesh from member-contributed device ids (hostfile order).

    Returns (mesh, oversubscribed). Falls back to the available real devices
    when members reference overlapping/out-of-range ids (single-CPU sim).
    """
    devices = list(devices if devices is not None else jax.devices())
    if not view.members:
        return None, False
    want: List[int] = []
    for m in view.members:
        ids = [int(x) for x in
               (m.address.split("devices=")[-1].split(",") if "devices=" in
                m.address else []) if x != ""]
        want.extend(ids if ids else [-1] * m.n_devices)
    usable = [devices[i] for i in want if 0 <= i < len(devices)]
    oversub = False
    if len(set(id(d) for d in usable)) != len(want):
        # overlapping or missing ids -> oversubscribed simulation
        oversub = True
        usable = devices[: max(1, min(len(devices), view.total_devices))]
    shape, axes = default_mesh_rule(len(usable)) if mesh_rule is None else \
        mesh_rule(len(usable))
    arr = np.array(usable, dtype=object).reshape(shape)
    return Mesh(arr, axes), oversub


class MeshTemplate:
    """Watches the registry; re-renders (hostfile, mesh) on membership change."""

    def __init__(self, registry, devices: Optional[Sequence] = None,
                 mesh_rule: Callable = default_mesh_rule,
                 min_render_interval: float = 0.0, clock=None):
        self.registry = registry
        self.devices = devices
        self.mesh_rule = mesh_rule
        self.tracker = ViewTracker()
        self.min_render_interval = min_render_interval
        self.clock = clock
        self._last_render_t = -1e30
        self._last_index = -1
        self._rendering: Optional[Rendering] = None
        self._callbacks: List[Callable[[Rendering, ViewDiff], None]] = []
        self._lock = threading.RLock()

    def on_change(self, fn: Callable[[Rendering, ViewDiff], None]) -> None:
        self._callbacks.append(fn)

    @property
    def rendering(self) -> Optional[Rendering]:
        with self._lock:
            return self._rendering

    def poll(self, force: bool = False) -> Optional[Rendering]:
        """One watch iteration: sweep TTLs, diff the catalog, re-render on
        change. Returns the new Rendering if one was produced."""
        with self._lock:
            self.registry.sweep()
            idx = self.registry.index
            if not force and idx == self._last_index:
                return None
            self._last_index = idx
            entries = self.registry.catalog(HPC_SERVICE)
            view, d = self.tracker.update(entries)
            if not force and not d.changed and self._rendering is not None:
                return None
            if self.clock is not None and self.min_render_interval > 0:
                now = self.clock.now()
                if now - self._last_render_t < self.min_render_interval:
                    return None  # debounced; next poll retries
                self._last_render_t = now
            mesh, oversub = render_mesh(view, self.devices, self.mesh_rule)
            hostfile = render_hostfile(view)
            r = Rendering(epoch=view.epoch, hostfile=hostfile, mesh=mesh,
                          oversubscribed=oversub, view=view)
            self._rendering = r
            # publish like consul-template writing the file
            self.registry.kv_put(HOSTFILE_KEY, hostfile)
            self._last_index = self.registry.index
            for fn in self._callbacks:
                fn(r, d)
            return r

    def wait_for_epoch(self, epoch: int, timeout: float = 5.0,
                       poll_interval: float = 0.01) -> Rendering:
        """Blocking-query loop (threaded mode)."""
        import time
        # replint: ignore[R001] -- host-side blocking wait for threaded mode; never on a replayed sim path
        deadline = time.monotonic() + timeout
        while True:
            r = self.poll() or self.rendering
            if r is not None and r.epoch >= epoch:
                return r
            # replint: ignore[R001] -- host-side blocking wait for threaded mode; never on a replayed sim path
            if time.monotonic() > deadline:
                raise TimeoutError(f"epoch {epoch} not reached")
            self.registry.wait(self.registry.index, timeout=poll_interval)
