"""Cluster membership views: epochs, diffs, quorum (paper §III-C).

A ClusterView is an immutable snapshot of the healthy HPC-service catalog.
The epoch increments whenever the member *set* changes — it is the version
number the elastic runtime keys resharding off.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.core.registry import ServiceEntry

HPC_SERVICE = "hpc-node"


@dataclass(frozen=True)
class Member:
    node_id: str
    address: str
    n_devices: int
    role: str = "compute"  # head | compute

    @staticmethod
    def from_entry(e: ServiceEntry) -> "Member":
        return Member(
            node_id=e.node_id,
            address=e.address,
            n_devices=int(e.meta.get("n_devices", "1")),
            role=e.meta.get("role", "compute"),
        )


@dataclass(frozen=True)
class ClusterView:
    epoch: int
    members: Tuple[Member, ...]  # sorted by node_id

    @property
    def node_ids(self) -> FrozenSet[str]:
        return frozenset(m.node_id for m in self.members)

    @property
    def total_devices(self) -> int:
        return sum(m.n_devices for m in self.members)

    @property
    def head(self) -> Optional[Member]:
        heads = [m for m in self.members if m.role == "head"]
        return heads[0] if heads else None

    @property
    def compute(self) -> Tuple[Member, ...]:
        return tuple(m for m in self.members if m.role == "compute")

    def has_quorum(self, expected: int) -> bool:
        return len(self.members) > expected // 2


@dataclass(frozen=True)
class ViewDiff:
    joined: Tuple[str, ...]
    left: Tuple[str, ...]

    @property
    def changed(self) -> bool:
        return bool(self.joined or self.left)


def diff(old: Optional[ClusterView], new: ClusterView) -> ViewDiff:
    old_ids = old.node_ids if old else frozenset()
    return ViewDiff(
        joined=tuple(sorted(new.node_ids - old_ids)),
        left=tuple(sorted(old_ids - new.node_ids)),
    )


class ViewTracker:
    """Builds monotonically-epoched views from catalog snapshots."""

    def __init__(self):
        self._view: Optional[ClusterView] = None

    @property
    def view(self) -> Optional[ClusterView]:
        return self._view

    def update(self, entries: List[ServiceEntry]) -> Tuple[ClusterView, ViewDiff]:
        members = tuple(sorted((Member.from_entry(e) for e in entries),
                               key=lambda m: m.node_id))
        if self._view is not None and members == self._view.members:
            return self._view, ViewDiff((), ())
        epoch = (self._view.epoch + 1) if self._view else 1
        new = ClusterView(epoch=epoch, members=members)
        d = diff(self._view, new)
        self._view = new
        return new, d
