"""ServiceRegistry — the Consul analogue (paper §III-C).

Implements the subset of Consul semantics the paper relies on, plus the HA
behavior Consul provides and the paper cites:

  * service catalog with register/deregister and TTL health checks
    (a node that stops heartbeating is marked critical and reaped),
  * a versioned KV store (ModifyIndex per key, monotonically increasing
    global index),
  * blocking queries ("watches"): wait until the global index passes a
    given value — this is what consul-template (core/template.py) uses,
  * replicated deployment with leader election and failover
    (ReplicatedRegistry): writes need a quorum ack; a partitioned or killed
    leader triggers election of the next healthy replica.

Everything is clock-injected so tests drive TTL expiry deterministically.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.clock import Clock, RealClock


@dataclass(frozen=True)
class ServiceEntry:
    node_id: str
    service: str
    address: str  # opaque locator; here: "simnet://<node>" + device ids
    meta: Dict[str, str]
    ttl: float
    registered_at: float
    last_heartbeat: float
    create_index: int

    def healthy(self, now: float) -> bool:
        return (now - self.last_heartbeat) <= self.ttl


@dataclass(frozen=True)
class KVEntry:
    value: str
    modify_index: int


class RegistryError(RuntimeError):
    pass


class NotLeader(RegistryError):
    pass


class ServiceRegistry:
    """Single-replica registry (see ReplicatedRegistry for the HA wrapper)."""

    def __init__(self, clock: Optional[Clock] = None, name: str = "consul-0"):
        self.name = name
        self.clock = clock or RealClock()
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._index = 0
        self._services: Dict[Tuple[str, str], ServiceEntry] = {}
        self._kv: Dict[str, KVEntry] = {}
        self.alive = True  # fault injection: a dead replica raises

    # -- internals ----------------------------------------------------------
    def _bump(self) -> int:
        self._index += 1
        self._cond.notify_all()
        return self._index

    def _check_alive(self):
        if not self.alive:
            raise RegistryError(f"{self.name} is down")

    # -- catalog ------------------------------------------------------------
    def register(self, service: str, node_id: str, address: str,
                 ttl: float = 2.0, meta: Optional[Dict[str, str]] = None) -> int:
        with self._lock:
            self._check_alive()
            now = self.clock.now()
            idx = self._bump()
            self._services[(service, node_id)] = ServiceEntry(
                node_id=node_id, service=service, address=address,
                meta=dict(meta or {}), ttl=ttl, registered_at=now,
                last_heartbeat=now, create_index=idx)
            return idx

    def deregister(self, service: str, node_id: str) -> int:
        with self._lock:
            self._check_alive()
            if self._services.pop((service, node_id), None) is not None:
                return self._bump()
            return self._index

    def heartbeat(self, service: str, node_id: str) -> bool:
        """TTL check-in. Returns False if the entry is gone (must re-register)."""
        with self._lock:
            self._check_alive()
            e = self._services.get((service, node_id))
            if e is None:
                return False
            self._services[(service, node_id)] = replace(
                e, last_heartbeat=self.clock.now())
            return True

    def sweep(self) -> List[ServiceEntry]:
        """Reap entries whose TTL lapsed (Consul's critical->dereg path).
        Returns the reaped entries; bumps the index if any."""
        with self._lock:
            self._check_alive()
            now = self.clock.now()
            dead = [k for k, e in self._services.items() if not e.healthy(now)]
            reaped = [self._services.pop(k) for k in dead]
            if reaped:
                self._bump()
            return reaped

    def catalog(self, service: str, healthy_only: bool = True
                ) -> List[ServiceEntry]:
        with self._lock:
            self._check_alive()
            now = self.clock.now()
            out = [e for (s, _), e in self._services.items() if s == service
                   and (not healthy_only or e.healthy(now))]
            return sorted(out, key=lambda e: (e.create_index, e.node_id))

    # -- kv -----------------------------------------------------------------
    def kv_put(self, key: str, value: str) -> int:
        with self._lock:
            self._check_alive()
            idx = self._bump()
            self._kv[key] = KVEntry(value, idx)
            return idx

    def kv_get(self, key: str) -> Optional[KVEntry]:
        with self._lock:
            self._check_alive()
            return self._kv.get(key)

    def kv_prefix(self, prefix: str) -> Dict[str, KVEntry]:
        with self._lock:
            self._check_alive()
            return {k: v for k, v in self._kv.items() if k.startswith(prefix)}

    # -- blocking query -----------------------------------------------------
    @property
    def index(self) -> int:
        with self._lock:
            return self._index

    def wait(self, after_index: int, timeout: float = 0.0) -> int:
        """Block until global index > after_index (or timeout). Returns the
        current index. With a ManualClock this only polls once (tests pump
        state explicitly)."""
        with self._cond:
            if self._index > after_index or timeout <= 0:
                return self._index
            self._cond.wait(timeout)
            return self._index

    # -- snapshot (for replica catch-up) -------------------------------------
    def _snapshot(self):
        with self._lock:
            return (self._index, dict(self._services), dict(self._kv))

    def _install(self, snap):
        with self._lock:
            self._index, self._services, self._kv = (
                snap[0], dict(snap[1]), dict(snap[2]))
            self._cond.notify_all()


class ReplicatedRegistry:
    """Quorum-replicated registry with leader failover (Consul server trio).

    Writes go through the leader and are applied synchronously to every
    *reachable* replica; a write needs acks from a majority or it raises.
    `failover()` elects the lowest-indexed healthy replica. A revived
    stale replica catches up from the leader snapshot before serving.
    """

    def __init__(self, n_replicas: int = 3, clock: Optional[Clock] = None):
        assert n_replicas >= 1
        self.clock = clock or RealClock()
        self.replicas = [ServiceRegistry(self.clock, name=f"consul-{i}")
                         for i in range(n_replicas)]
        self._leader_idx = 0
        self._lock = threading.RLock()

    @property
    def leader(self) -> ServiceRegistry:
        with self._lock:
            return self.replicas[self._leader_idx]

    @property
    def quorum(self) -> int:
        return len(self.replicas) // 2 + 1

    def _replicate(self, op: Callable[[ServiceRegistry], object]):
        with self._lock:
            leader = self.replicas[self._leader_idx]
            if not leader.alive:
                raise NotLeader(f"{leader.name} (leader) is down")
            acks = 0
            result = None
            for r in self.replicas:
                try:
                    res = op(r)
                    acks += 1
                    if r is leader:
                        result = res
                except RegistryError:
                    continue
            if acks < self.quorum:
                raise RegistryError(
                    f"no quorum: {acks}/{len(self.replicas)} acks")
            return result

    # mirrored write API
    def register(self, *a, **kw):
        return self._replicate(lambda r: r.register(*a, **kw))

    def deregister(self, *a, **kw):
        return self._replicate(lambda r: r.deregister(*a, **kw))

    def heartbeat(self, *a, **kw):
        return self._replicate(lambda r: r.heartbeat(*a, **kw))

    def sweep(self):
        return self._replicate(lambda r: r.sweep())

    def kv_put(self, *a, **kw):
        return self._replicate(lambda r: r.kv_put(*a, **kw))

    # reads from leader
    def catalog(self, *a, **kw):
        return self.leader.catalog(*a, **kw)

    def kv_get(self, *a, **kw):
        return self.leader.kv_get(*a, **kw)

    def kv_prefix(self, *a, **kw):
        return self.leader.kv_prefix(*a, **kw)

    @property
    def index(self) -> int:
        return self.leader.index

    def wait(self, *a, **kw):
        return self.leader.wait(*a, **kw)

    # -- failover -------------------------------------------------------------
    def kill_leader(self):
        with self._lock:
            self.replicas[self._leader_idx].alive = False

    def failover(self) -> str:
        """Elect the first healthy replica as leader; it must hold the most
        recent state among healthy replicas (synchronous replication makes
        any healthy replica current)."""
        with self._lock:
            healthy = [i for i, r in enumerate(self.replicas) if r.alive]
            if len(healthy) < self.quorum:
                raise RegistryError("cannot elect: no quorum of replicas")
            # choose the healthy replica with the highest index (raft-ish)
            best = max(healthy, key=lambda i: self.replicas[i].index)
            self._leader_idx = best
            return self.replicas[best].name

    def revive(self, i: int):
        with self._lock:
            r = self.replicas[i]
            r.alive = True
            r._install(self.leader._snapshot())
