"""ElasticRuntime — auto-scaling made safe for stateful SPMD jobs.

The paper scales by powering up machines whose containers self-register; the
MPI hostfile re-renders and the *next* job uses the new size. A training job
cannot wait for "the next job": this runtime reacts to membership-epoch
changes *mid-run*:

  planned change (scale up/down, drain):  checkpoint -> re-render mesh ->
      reshard state onto the new topology -> re-jit -> continue (no progress
      lost)
  unplanned loss (crash/partition, TTL reap): restore the last durable
      checkpoint on the survivors (progress since that checkpoint is lost —
      honest restart semantics, accounted in `steps_lost`)
  stragglers: per-node step-time metrics feed StragglerPolicy -> the slow
      node is drained & replaced like a planned change.

The data plane is real JAX throughout: state lives as sharded arrays on the
currently-rendered mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ParallelPlan, ShapeConfig
from repro.core.template import MeshTemplate, Rendering
from repro.data import ShardedLoader, SyntheticLM
from repro.launch import steps as St
from repro.models.env import Env
from repro.models import model as Mo
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel import rules

Pytree = Any


@dataclass
class ElasticStats:
    epoch_changes: int = 0
    reshards: int = 0
    restores: int = 0
    steps_lost: int = 0
    scale_events: list = field(default_factory=list)


class ElasticTrainer:
    def __init__(self, template: MeshTemplate, cfg: ModelConfig,
                 shape: ShapeConfig, ckpt_dir: str, *,
                 opt: Optional[AdamWConfig] = None,
                 plan: Optional[ParallelPlan] = None,
                 ckpt_every: int = 10, seed: int = 0,
                 data_source=None):
        self.template = template
        self.cfg = cfg
        self.shape = shape
        self.opt = opt or AdamWConfig()
        self.base_plan = plan or ParallelPlan(
            fsdp=False, remat="nothing", attn_impl="naive",
            kv_cache="replicated")
        self.ckpt = CheckpointManager(ckpt_dir, keep=3)
        self.ckpt_every = ckpt_every
        self.seed = seed
        self.data_source = data_source or SyntheticLM(
            cfg.vocab_size, shape.seq_len, seed)
        self.stats = ElasticStats()
        self.step = 0
        self._epoch = -1
        self._last_ckpt_step = 0
        self.env: Optional[Env] = None
        self.state: Optional[Pytree] = None
        self._jit_step = None
        self._loader: Optional[ShardedLoader] = None

    # -- (re)build ------------------------------------------------------------
    def _specs(self, env: Env):
        struct = St.state_struct(self.cfg, env, self.opt)
        return struct, rules.state_specs(struct, self.cfg, env)

    def _build(self, rendering: Rendering, *, planned: bool) -> None:
        """Re-render the data plane for a new membership epoch."""
        new_env = Env(mesh=rendering.mesh, plan=self.base_plan)
        first = self.state is None
        if not first:
            if planned:
                # planned change: persist *current* progress synchronously
                self.ckpt.wait()
                self.ckpt.save(self.step, self.state,
                               {"epoch": self._epoch})
                self._last_ckpt_step = self.step
            else:
                # unplanned loss: roll back to last durable checkpoint
                self.ckpt.wait()
                last = self.ckpt.latest_step()
                lost = self.step - (last if last is not None else 0)
                self.stats.steps_lost += max(lost, 0)
                self.stats.restores += 1
        struct, specs = self._specs(new_env)
        if first and self.ckpt.latest_step() is None:
            params = Mo.init_params(jax.random.PRNGKey(self.seed), self.cfg,
                                    new_env)
            state = {"params": params, "opt": adamw_init(params, self.opt)}
            self.state = rules.apply_shardings(state, specs, new_env)
        else:
            shardings = rules.to_shardings(specs, new_env)
            self.state = self.ckpt.restore(struct, shardings=shardings)
            self.step = int(self.ckpt.metadata().get("step",
                                                     self.ckpt.latest_step()))
            self.step = self.ckpt.latest_step()
            self.stats.reshards += 1
        self.env = new_env
        self._loader = ShardedLoader(self.data_source, self.cfg, self.shape,
                                     new_env, self.seed)
        fn = St.make_train_step(self.cfg, new_env, self.opt)
        self._jit_step = jax.jit(fn, donate_argnums=(0,))
        self._epoch = rendering.epoch
        self.stats.epoch_changes += 1

    # -- run loop ----------------------------------------------------------------
    def ensure_ready(self, planned: bool = True) -> None:
        r = self.template.poll() or self.template.rendering
        assert r is not None and r.mesh is not None, "no rendered mesh"
        if r.epoch != self._epoch:
            self._build(r, planned=planned)

    def run_steps(self, n: int, on_step: Optional[Callable] = None,
                  planned_changes: bool = True) -> Dict[str, float]:
        metrics = {}
        for _ in range(n):
            self.ensure_ready(planned=planned_changes)
            batch = self._loader.batch(self.step)
            self.state, m = self._jit_step(self.state, batch)
            self.step += 1
            metrics = {k: float(v) for k, v in m.items()}
            if self.step - self._last_ckpt_step >= self.ckpt_every:
                self.ckpt.save_async(self.step, self.state,
                                     {"epoch": self._epoch})
                self._last_ckpt_step = self.step
            if on_step:
                on_step(self.step, metrics)
        return metrics

    def finalize(self) -> None:
        self.ckpt.wait()
        if self.state is not None:
            self.ckpt.save(self.step, self.state, {"epoch": self._epoch})
