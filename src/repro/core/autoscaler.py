"""AutoScaler — the paper's scaling loop (§III/§IV) plus the policies its
conclusion defers: "power up more machines, deploy new HPC containers, they
register themselves and become part of the computing cluster."

Policies compute a desired compute-node count (or replacement set) from the
current view + metrics; the controller applies plans through a provisioner
(simnet in this repo; a cloud/cluster API in production) under cooldowns and
min/max bounds. Straggler mitigation (deadline on reported step times) is a
replacement policy — the paper's future-work item made concrete.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Tuple

from repro.core.clock import Clock, RealClock
from repro.core.membership import ClusterView

# How read_metrics() folds per-source serving metrics into one fleet
# value, keyed by metric name (every name must be in serve/metrics.py's
# METRIC_SCHEMA — replint R005 checks, tests/test_metric_schema.py holds
# the three tables to the schema set):
#   max  — worst-source passthrough: fleet latency is the worst replica's
#          (a single overloaded replica is a scale-up case even when the
#          mean looks healthy); replicas_live/replica_warmups come from
#          the router source only, so max is identity
#   sum  — volume counters: throughput, misses, preemptions, prefill and
#          recompute work, swap traffic, post-training phase volume
#   mean — levels: occupancies, hit/acceptance rates, reward and loss
SERVING_MAX_METRICS = (
    "latency_p50_ms", "latency_p95_ms", "ttft_p95_ms",
    "replicas_live", "replica_warmups")
SERVING_SUM_METRICS = (
    "tokens_per_s", "deadline_misses", "preemptions", "prefill_tokens",
    "recomputed_tokens", "swapped_blocks", "swap_out_bytes",
    "swap_in_bytes", "rollout_tokens", "pairs_per_round")
SERVING_MEAN_METRICS = (
    "slot_occupancy", "kv_block_occupancy", "prefix_hit_rate",
    "kv_shared_occupancy", "kv_quant_divergence", "accepted_per_step",
    "spec_acceptance_rate", "reward_mean", "train_loss")


@dataclass(frozen=True)
class ScalePlan:
    target: int  # desired compute-node count
    replace: Tuple[str, ...] = ()  # node_ids to drain & replace (stragglers)
    reason: str = ""

    def is_noop(self, current: int) -> bool:
        return self.target == current and not self.replace


class Policy(Protocol):
    def decide(self, view: ClusterView, metrics: Dict[str, float]) -> ScalePlan:
        ...


@dataclass
class TargetSizePolicy:
    """Operator-pinned size (the paper's manual 'power up more machines')."""
    target: int

    def decide(self, view, metrics):
        return ScalePlan(self.target, reason=f"target-size={self.target}")


@dataclass
class QueueDepthPolicy:
    """Scale so each node holds ~target_per_node queued work items."""
    target_per_node: int = 4
    min_nodes: int = 1
    max_nodes: int = 64

    def decide(self, view, metrics):
        depth = metrics.get("queue_depth", 0.0)
        want = max(self.min_nodes,
                   min(self.max_nodes,
                       int(-(-depth // self.target_per_node)) or self.min_nodes))
        return ScalePlan(want, reason=f"queue_depth={depth}")


@dataclass
class StepTimePolicy:
    """Scale up while the measured step time exceeds the target (assumes
    near-linear DP scaling; the increment is one node per decision)."""
    target_step_s: float
    min_nodes: int = 1
    max_nodes: int = 64
    headroom: float = 0.85  # scale down if faster than headroom*target

    def decide(self, view, metrics):
        n = len(view.compute)
        st = metrics.get("step_time", None)
        if st is None:
            return ScalePlan(n, reason="no-data")
        if st > self.target_step_s and n < self.max_nodes:
            return ScalePlan(n + 1, reason=f"slow step {st:.3f}s")
        if st < self.headroom * self.target_step_s and n > self.min_nodes:
            return ScalePlan(n - 1, reason=f"fast step {st:.3f}s")
        return ScalePlan(n, reason="in-band")


@dataclass
class LatencyPolicy:
    """Serve-driven scaling: grow while p95 request latency exceeds the
    target OR completed requests are blowing their deadlines; shrink only
    once latency is comfortably inside the target AND the arrival queue is
    empty (draining a backlog at low latency still needs the capacity).

    deadline_misses is the cumulative counter an EDF scheduler feeds back
    through ServingMetrics: EDF reorders admissions within a node, but once
    requests miss anyway the node is simply oversubscribed — each *new*
    miss since the last decision is a scale-up vote that outranks a
    healthy-looking p95 (misses lead completions, p95 trails them).

    kv_shared_occupancy (the paged backend's fraction of blocks currently
    referenced by >= 2 live requests) is a *scale-hold* signal: a replica
    actively deduplicating shared prefixes would make every one of those
    in-flight tenants pay cold prefill again if it were drained — so the
    latency-headroom shrink is held while shared occupancy is at/above
    hold_shared_above. The signal decays to zero as sharing traffic
    drains, so idle clusters still shrink."""
    target_p95_ms: float
    min_nodes: int = 1
    max_nodes: int = 64
    headroom: float = 0.5  # scale down below headroom*target
    scale_on_misses: bool = True
    # hold shrink while >= this fraction of the pool is actively shared.
    # The signal's ceiling is (shared prefix blocks)/(pool size) — a pool
    # sized for many requests holds a handful of shared template blocks —
    # so the threshold must sit well below 1.0 to be reachable (the smoke
    # bench peaks at ~0.13 with one template on a 32-block pool)
    hold_shared_above: float = 0.05
    _seen_misses: float = field(default=0.0, init=False)

    def decide(self, view, metrics):
        n = len(view.compute)
        p95 = metrics.get("latency_p95_ms", None)
        depth = metrics.get("queue_depth", 0.0)
        # paged KV publishes block occupancy (the signal that actually
        # gates admission); fall back to slot occupancy
        occ = max(metrics.get("slot_occupancy", 0.0),
                  metrics.get("kv_block_occupancy", 0.0))
        misses = metrics.get("deadline_misses", 0.0)
        new_misses = misses - self._seen_misses
        self._seen_misses = max(self._seen_misses, misses)
        if (self.scale_on_misses and new_misses > 0 and n < self.max_nodes):
            return ScalePlan(n + 1, reason=f"deadline misses +"
                                           f"{new_misses:.0f} ({misses:.0f}"
                                           " total)")
        if p95 is None:
            # no completions in the metrics window: hold while anything is
            # queued or in flight (mid-burst warmup), shrink once truly idle
            if depth == 0 and occ == 0 and n > self.min_nodes:
                return ScalePlan(n - 1, reason="idle")
            return ScalePlan(n, reason="no-data")
        if p95 > self.target_p95_ms and n < self.max_nodes:
            return ScalePlan(n + 1, reason=f"p95 {p95:.0f}ms > "
                                           f"{self.target_p95_ms:.0f}ms")
        if (p95 < self.headroom * self.target_p95_ms and depth == 0
                and n > self.min_nodes):
            shared = metrics.get("kv_shared_occupancy", 0.0)
            if shared >= self.hold_shared_above:
                return ScalePlan(n, reason=f"prefix cache hot "
                                           f"({shared:.2f} shared)")
            return ScalePlan(n - 1, reason=f"p95 {p95:.0f}ms in headroom")
        return ScalePlan(n, reason="in-band")


@dataclass
class StragglerPolicy:
    """Replace nodes whose reported step time exceeds factor x median."""
    factor: float = 2.0
    min_samples: int = 2

    def decide(self, view, metrics):
        times = {k[len("node_step_time/"):]: v for k, v in metrics.items()
                 if k.startswith("node_step_time/")}
        n = len(view.compute)
        if len(times) < self.min_samples:
            return ScalePlan(n, reason="insufficient samples")
        med = statistics.median(times.values())
        bad = tuple(sorted(nid for nid, t in times.items()
                           if med > 0 and t > self.factor * med))
        return ScalePlan(n, replace=bad,
                         reason=f"median={med:.3f}s stragglers={bad}")


class Provisioner(Protocol):
    def add_nodes(self, n: int) -> List[str]: ...
    def remove_nodes(self, node_ids: List[str]) -> None: ...


@dataclass
class AutoScaler:
    policy: Policy
    provisioner: Provisioner
    cooldown_s: float = 0.0
    min_nodes: int = 1
    max_nodes: int = 256
    # serving-metrics liveness TTL: skip sources whose last report
    # (metrics/<src>/__ts, stamped by NodeAgent.report_serving) is older
    # than this many sim seconds — a crashed replica never tombstones its
    # keys, and without the filter its final snapshot would skew fleet
    # aggregates forever. None disables the filter; sources without a
    # stamp (plain step_time / queue_depth publishers) are always fresh.
    metrics_ttl_s: Optional[float] = None
    clock: Clock = field(default_factory=RealClock)
    _last_action_t: float = field(default=-1e30, init=False)
    history: List[Tuple[float, str]] = field(default_factory=list, init=False)

    def read_metrics(self, registry) -> Dict[str, float]:
        kv = registry.kv_prefix("metrics/")
        stale = set()
        if self.metrics_ttl_s is not None:
            now = self.clock.now()
            for key, entry in kv.items():
                _, node, name = key.split("/", 2)
                if name != "__ts" or not entry.value:
                    continue
                try:
                    ts = float(entry.value)
                except ValueError:
                    continue
                if now - ts > self.metrics_ttl_s:
                    stale.add(node)
        out: Dict[str, float] = {}
        for key, entry in kv.items():
            _, node, name = key.split("/", 2)
            if name == "__ts" or node in stale:
                continue  # liveness stamp itself / source past its TTL
            val = entry.value.split(":")[-1]
            if not val:  # tombstone: metric's window lapsed (report_serving)
                continue
            try:
                out[f"node_{name}/{node}"] = float(val)
            except ValueError:
                continue
        steps = [v for k, v in out.items() if k.startswith("node_step_time/")]
        if steps:
            out["step_time"] = statistics.median(steps)
        depths = [v for k, v in out.items() if k.startswith("node_queue_depth/")]
        if depths:
            out["queue_depth"] = sum(depths)
        # serving metrics (NodeAgent.report_serving snapshots — one source
        # per node, or one per serving *replica* when a ReplicaSet head
        # publishes on the fleet's behalf) fold by the module-level
        # SERVING_* tables above — every published name must appear in
        # exactly one of them, or the fleet value silently never exists
        for names, agg in ((SERVING_MAX_METRICS, max),
                           (SERVING_SUM_METRICS, sum)):
            for name in names:
                vals = [v for k, v in out.items()
                        if k.startswith(f"node_{name}/")]
                if vals:
                    out[name] = agg(vals)
        for name in SERVING_MEAN_METRICS:
            occ = [v for k, v in out.items()
                   if k.startswith(f"node_{name}/")]
            if occ:
                out[name] = sum(occ) / len(occ)
        return out

    def apply_plan(self, view: ClusterView, plan: ScalePlan
                   ) -> Optional[ScalePlan]:
        """Clamp and apply one plan through the provisioner (no cooldown
        check — callers gate). Returns the applied plan, or None if noop.
        This is also the one-shot path for operator actions
        (VirtualCluster.scale_to), which must not disturb the installed
        policy."""
        target = max(self.min_nodes, min(self.max_nodes, plan.target))
        plan = ScalePlan(target, plan.replace, plan.reason)
        current = len(view.compute)
        if plan.is_noop(current):
            return None
        if plan.replace:
            self.provisioner.remove_nodes(list(plan.replace))
            self.provisioner.add_nodes(len(plan.replace))
        if target > current:
            self.provisioner.add_nodes(target - current)
        elif target < current:
            victims = [m.node_id for m in view.compute[target:]]
            self.provisioner.remove_nodes(victims)
        self._last_action_t = self.clock.now()
        self.history.append((self._last_action_t, plan.reason))
        return plan

    def step(self, view: ClusterView, metrics: Dict[str, float]
             ) -> Optional[ScalePlan]:
        """One reconcile iteration. Returns the applied plan (or None)."""
        now = self.clock.now()
        if now - self._last_action_t < self.cooldown_s:
            return None
        return self.apply_plan(view, self.policy.decide(view, metrics))
