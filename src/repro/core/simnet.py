"""simnet — the simulated multi-node control plane (DESIGN.md §2, assumption 1).

The paper ran 3 physical blades; this container is one process, so physical
nodes are simulated: each SimNode owns a NodeAgent plus a slice of the
available jax devices, and all registry traffic flows through a Network that
can inject partitions, delays, and crashes. The *data plane* stays real JAX.

Deterministic by construction: tests drive a ManualClock and call pump().
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

import jax

from repro.core.agent import NodeAgent
from repro.core.clock import Clock, ManualClock
from repro.core.registry import RegistryError


class Network:
    """Interposes agent->registry calls; injects partitions/outages."""

    def __init__(self):
        self._partitioned: Set[str] = set()
        self._lock = threading.Lock()

    def partition(self, node_id: str) -> None:
        with self._lock:
            self._partitioned.add(node_id)

    def heal(self, node_id: str) -> None:
        with self._lock:
            self._partitioned.discard(node_id)

    def reachable(self, node_id: str) -> bool:
        with self._lock:
            return node_id not in self._partitioned


class _GuardedRegistry:
    """Registry proxy enforcing network reachability for one node."""

    def __init__(self, registry, network: Network, node_id: str):
        self._r = registry
        self._net = network
        self._id = node_id

    def _check(self):
        if not self._net.reachable(self._id):
            raise RegistryError(f"{self._id} partitioned from registry")

    def register(self, *a, **kw):
        self._check()
        return self._r.register(*a, **kw)

    def deregister(self, *a, **kw):
        self._check()
        return self._r.deregister(*a, **kw)

    def heartbeat(self, *a, **kw):
        self._check()
        return self._r.heartbeat(*a, **kw)

    def kv_put(self, *a, **kw):
        self._check()
        return self._r.kv_put(*a, **kw)


@dataclass
class SimNode:
    node_id: str
    agent: NodeAgent
    device_ids: Sequence[int]
    step_time_bias: float = 0.0  # injected slowness (straggler simulation)
    alive: bool = True


class SimCluster:
    """Provisioner + world: creates/destroys SimNodes against a registry.

    Device assignment: round-robins the real device pool across nodes
    (devices_per_node each). When the pool is exhausted, ids repeat and the
    MeshTemplate falls back to the oversubscribed single-host mesh.
    """

    def __init__(self, registry, *, clock: Optional[Clock] = None,
                 devices_per_node: int = 1, ttl: float = 2.0,
                 image_digest: str = "", n_devices: Optional[int] = None):
        self.registry = registry
        self.clock = clock or ManualClock()
        self.network = Network()
        self.devices_per_node = devices_per_node
        self.ttl = ttl
        self.image_digest = image_digest
        self.nodes: Dict[str, SimNode] = {}
        self._counter = itertools.count()
        self._n_devices = (n_devices if n_devices is not None
                           else len(jax.devices()))
        self._next_dev = 0

    # -- provisioner interface (AutoScaler) -----------------------------------
    def add_nodes(self, n: int, role: str = "compute",
                  devices_per_node: int | None = None) -> List[str]:
        dpn = (self.devices_per_node if devices_per_node is None
               else devices_per_node)
        out = []
        for _ in range(n):
            nid = f"{role}{next(self._counter):03d}"
            ids = [(self._next_dev + i) % max(self._n_devices, 1)
                   for i in range(dpn)]
            self._next_dev += dpn
            agent = NodeAgent(
                nid, _GuardedRegistry(self.registry, self.network, nid),
                n_devices=dpn, role=role, ttl=self.ttl,
                device_ids=ids, clock=self.clock,
                image_digest=self.image_digest)
            agent.start()
            self.nodes[nid] = SimNode(nid, agent, ids)
            out.append(nid)
        return out

    def add_head(self) -> str:
        # the head coordinates (renders the hostfile, submits jobs); it
        # contributes no accelerators to the mesh
        return self.add_nodes(1, role="head", devices_per_node=0)[0]

    def remove_nodes(self, node_ids: List[str]) -> None:
        for nid in node_ids:
            node = self.nodes.pop(nid, None)
            if node is not None:
                node.agent.drain()
                node.alive = False

    # -- fault injection --------------------------------------------------------
    def crash(self, node_id: str) -> None:
        """Hard kill: no dereg; TTL reaps it (paper's unplanned-loss case)."""
        node = self.nodes.pop(node_id)
        node.agent.crash()
        node.alive = False

    def partition(self, node_id: str) -> None:
        self.network.partition(node_id)

    def heal(self, node_id: str) -> None:
        self.network.heal(node_id)

    def make_straggler(self, node_id: str, bias_s: float) -> None:
        self.nodes[node_id].step_time_bias = bias_s

    # -- simulation pump ---------------------------------------------------------
    def pump(self, dt: float = 0.0) -> None:
        """Advance time and deliver one heartbeat round (manual mode)."""
        if dt and isinstance(self.clock, ManualClock):
            self.clock.advance(dt)
        for node in list(self.nodes.values()):
            if node.alive:
                try:
                    node.agent.tick()
                except RegistryError:
                    pass  # partitioned: heartbeat lost

    def report_step_times(self, step: int, base_s: float) -> None:
        """Publish per-node step metrics (straggler bias applied)."""
        for node in self.nodes.values():
            if node.alive and node.agent.role == "compute":
                try:
                    node.agent.report_step_time(step,
                                                base_s + node.step_time_bias)
                except RegistryError:
                    pass
