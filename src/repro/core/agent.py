"""NodeAgent — the per-container Consul agent (paper §III-C / Fig. 5).

Each (simulated) node runs an agent that registers its HPC service in the
registry, heartbeats its TTL check, publishes metrics (step times for the
straggler policy), and deregisters on graceful drain. A crashed node simply
stops heartbeating and is reaped by TTL expiry — exactly the paper's
auto-deregistration behavior.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence

from repro.core.clock import Clock, RealClock
from repro.core.membership import HPC_SERVICE


class NodeAgent:
    def __init__(self, node_id: str, registry, *, n_devices: int = 1,
                 role: str = "compute", ttl: float = 2.0,
                 device_ids: Optional[Sequence[int]] = None,
                 clock: Optional[Clock] = None, image_digest: str = ""):
        self.node_id = node_id
        self.registry = registry
        self.n_devices = n_devices
        self.role = role
        self.ttl = ttl
        self.device_ids = tuple(device_ids or ())
        self.clock = clock or RealClock()
        self.image_digest = image_digest
        self._running = False
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._serving_keys: set = set()  # serving metric names last published

    # -- lifecycle ------------------------------------------------------------
    @property
    def address(self) -> str:
        devs = ",".join(map(str, self.device_ids))
        return f"simnet://{self.node_id}?devices={devs}"

    def start(self) -> None:
        meta = {"n_devices": str(self.n_devices), "role": self.role,
                "image": self.image_digest,
                "devices": ",".join(map(str, self.device_ids))}
        self.registry.register(HPC_SERVICE, self.node_id, self.address,
                               ttl=self.ttl, meta=meta)
        self._running = True

    def tick(self) -> bool:
        """One heartbeat (manual-clock mode). Returns registration liveness."""
        if not self._running:
            return False
        return self.registry.heartbeat(HPC_SERVICE, self.node_id)

    def drain(self) -> None:
        """Graceful leave (scale-down path)."""
        self._running = False
        self._stop_evt.set()
        try:
            self.registry.deregister(HPC_SERVICE, self.node_id)
        except Exception:
            pass

    def crash(self) -> None:
        """Fault injection: vanish without deregistering (TTL will reap)."""
        self._running = False
        self._stop_evt.set()

    # -- metrics ----------------------------------------------------------------
    def report_step_time(self, step: int, seconds: float) -> None:
        if not self._running:
            return
        self.registry.kv_put(f"metrics/{self.node_id}/step_time",
                             f"{step}:{seconds:.6f}")

    def report_queue_depth(self, depth: int) -> None:
        if not self._running:
            return
        self.registry.kv_put(f"metrics/{self.node_id}/queue_depth", str(depth))

    def report_serving(self, metrics: Dict[str, float]) -> None:
        """Publish a ServingMetrics snapshot (queue depth, tokens/s,
        latency percentiles, slot occupancy) — the signals the serving-aware
        scaling policies consume.

        Keys the snapshot omits (ServingMetrics' "no data in window"
        contract) are tombstoned with an empty value so stale readings
        can't keep driving the policy after their window lapses —
        AutoScaler.read_metrics skips non-numeric values."""
        if not self._running:
            return
        for name in self._serving_keys - set(metrics):
            self.registry.kv_put(f"metrics/{self.node_id}/{name}", "")
        for name, val in metrics.items():
            self.registry.kv_put(f"metrics/{self.node_id}/{name}",
                                 f"{float(val):.6f}")
        self._serving_keys = set(metrics)

    # -- threaded mode (examples/benchmarks; tests use tick()) -------------------
    def run_threaded(self, interval: Optional[float] = None) -> None:
        interval = interval if interval is not None else self.ttl / 3.0

        def loop():
            while not self._stop_evt.wait(interval):
                if not self._running:
                    break
                try:
                    self.tick()
                except Exception:
                    break

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name=f"agent-{self.node_id}")
        self._thread.start()

    def stop(self) -> None:
        self.drain()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
