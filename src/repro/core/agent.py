"""NodeAgent — the per-container Consul agent (paper §III-C / Fig. 5).

Each (simulated) node runs an agent that registers its HPC service in the
registry, heartbeats its TTL check, publishes metrics (step times for the
straggler policy), and deregisters on graceful drain. A crashed node simply
stops heartbeating and is reaped by TTL expiry — exactly the paper's
auto-deregistration behavior.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence

from repro.core.clock import Clock, RealClock
from repro.core.membership import HPC_SERVICE


class NodeAgent:
    def __init__(self, node_id: str, registry, *, n_devices: int = 1,
                 role: str = "compute", ttl: float = 2.0,
                 device_ids: Optional[Sequence[int]] = None,
                 clock: Optional[Clock] = None, image_digest: str = ""):
        self.node_id = node_id
        self.registry = registry
        self.n_devices = n_devices
        self.role = role
        self.ttl = ttl
        self.device_ids = tuple(device_ids or ())
        self.clock = clock or RealClock()
        self.image_digest = image_digest
        self._running = False
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        # serving metric names last published, per source (this node's own
        # snapshot plus any replica sources it publishes on behalf of)
        self._serving_keys: Dict[str, set] = {}
        self._plain_keys: set = set()  # step_time / queue_depth published

    # -- lifecycle ------------------------------------------------------------
    @property
    def address(self) -> str:
        devs = ",".join(map(str, self.device_ids))
        return f"simnet://{self.node_id}?devices={devs}"

    def start(self) -> None:
        meta = {"n_devices": str(self.n_devices), "role": self.role,
                "image": self.image_digest,
                "devices": ",".join(map(str, self.device_ids))}
        self.registry.register(HPC_SERVICE, self.node_id, self.address,
                               ttl=self.ttl, meta=meta)
        self._running = True

    def tick(self) -> bool:
        """One heartbeat (manual-clock mode). Returns registration liveness."""
        if not self._running:
            return False
        return self.registry.heartbeat(HPC_SERVICE, self.node_id)

    def drain(self) -> None:
        """Graceful leave (scale-down path). Tombstones every metric key
        this agent ever published — its own step_time/queue_depth and all
        serving sources — *immediately*: registry KV entries have no TTL,
        so without this a departed node's last readings linger forever and
        keep skewing the fleet aggregates (the straggler policy's median,
        the summed queue depth) long after the node left the catalog.

        A crash() cannot clean up after itself, and a node partitioned
        mid-drain loses its tombstone writes — for those cases every
        report_serving stamps a __ts liveness key and
        AutoScaler.read_metrics (metrics_ttl_s) skips sources whose stamp
        went stale, so a ghost's last serving snapshot ages out of the
        fleet aggregates instead of lingering. Plain step_time /
        queue_depth keys still rely on the drain tombstones (their
        publishers die with the node the catalog TTL-reaps)."""
        self._running = False
        self._stop_evt.set()
        try:
            for src in list(self._serving_keys):
                self.retire_source(src)
            for name in sorted(self._plain_keys):
                self.registry.kv_put(f"metrics/{self.node_id}/{name}", "")
            self._plain_keys = set()
        except Exception:
            pass  # partitioned mid-drain: keys linger (see docstring)
        try:
            self.registry.deregister(HPC_SERVICE, self.node_id)
        except Exception:
            pass

    def crash(self) -> None:
        """Fault injection: vanish without deregistering (TTL will reap)."""
        self._running = False
        self._stop_evt.set()

    # -- metrics ----------------------------------------------------------------
    def report_step_time(self, step: int, seconds: float) -> None:
        if not self._running:
            return
        self.registry.kv_put(f"metrics/{self.node_id}/step_time",
                             f"{step}:{seconds:.6f}")
        self._plain_keys.add("step_time")

    def report_queue_depth(self, depth: int) -> None:
        if not self._running:
            return
        self.registry.kv_put(f"metrics/{self.node_id}/queue_depth", str(depth))
        self._plain_keys.add("queue_depth")

    def report_serving(self, metrics: Dict[str, float],
                       source: Optional[str] = None) -> None:
        """Publish a ServingMetrics snapshot (queue depth, tokens/s,
        latency percentiles, slot occupancy) — the signals the serving-aware
        scaling policies consume.

        `source` namespaces the keys (metrics/<source>/<name>) so one
        agent can publish on behalf of several serving replicas (the
        multi-replica head does); it defaults to this node's id. The
        autoscaler aggregates across sources exactly as across nodes.

        Keys the snapshot omits (ServingMetrics' "no data in window"
        contract) are tombstoned with an empty value so stale readings
        can't keep driving the policy after their window lapses —
        AutoScaler.read_metrics skips non-numeric values.

        Every report also stamps metrics/<source>/__ts with the agent's
        clock: the liveness signal AutoScaler.read_metrics (metrics_ttl_s)
        uses to skip sources that stopped reporting without a drain — a
        crashed replica can't tombstone its own keys, so its last snapshot
        would otherwise skew fleet aggregates forever."""
        if not self._running:
            return
        src = source or self.node_id
        seen = self._serving_keys.get(src, set())
        for name in sorted(seen - set(metrics) - {"__ts"}):
            self.registry.kv_put(f"metrics/{src}/{name}", "")
        for name, val in metrics.items():
            self.registry.kv_put(f"metrics/{src}/{name}",
                                 f"{float(val):.6f}")
        self.registry.kv_put(f"metrics/{src}/__ts",
                             f"{self.clock.now():.6f}")
        # __ts is tracked so drain()/retire_source tombstone it too
        self._serving_keys[src] = set(metrics) | {"__ts"}

    def retire_source(self, source: str) -> None:
        """A serving source left for good (replica drained + released):
        tombstone ALL its keys *now*. Waiting for the next report_serving
        diff can't work — a departed source never reports again — and
        waiting for a TTL window to lapse leaves its last snapshot
        skewing every fleet aggregate in the meantime."""
        for name in self._serving_keys.pop(source, ()):  # idempotent
            self.registry.kv_put(f"metrics/{source}/{name}", "")

    # -- threaded mode (examples/benchmarks; tests use tick()) -------------------
    def run_threaded(self, interval: Optional[float] = None) -> None:
        interval = interval if interval is not None else self.ttl / 3.0

        def loop():
            while not self._stop_evt.wait(interval):
                if not self._running:
                    break
                try:
                    self.tick()
                except Exception:
                    break

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name=f"agent-{self.node_id}")
        self._thread.start()

    def stop(self) -> None:
        self.drain()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
