"""Clock abstraction: tests drive a ManualClock deterministically; examples
and benchmarks use the RealClock."""
from __future__ import annotations

import threading
import time


class Clock:
    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, s: float) -> None:
        raise NotImplementedError


class RealClock(Clock):
    def now(self) -> float:
        # replint: ignore[R001] -- RealClock IS the sanctioned wall-clock boundary; everything else injects a Clock
        return time.monotonic()

    def sleep(self, s: float) -> None:
        # replint: ignore[R001] -- RealClock IS the sanctioned wall-clock boundary; everything else injects a Clock
        time.sleep(s)


class ManualClock(Clock):
    def __init__(self, start: float = 0.0):
        self._t = start
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._t

    def advance(self, s: float) -> None:
        with self._lock:
            self._t += s

    def sleep(self, s: float) -> None:  # cooperative: sleeping advances time
        self.advance(s)
