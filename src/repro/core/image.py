"""ClusterImage — the Docker image/Dockerfile analogue (paper §III-A).

The paper's remedy for HPC software-dependency hell is encapsulation: the
node environment is a content-addressed image built from a declarative spec
and shared through a hub. The JAX analogue: a frozen, hashable spec of
everything that determines a worker's behavior — model config digest,
parallelism plan, software pins, entrypoint — so any node that pulls the
same digest is bit-identical in behavior. Agents advertise their image
digest in the catalog; the head node refuses mixed-digest clusters (the
exact class of version-skew failure the paper motivates with).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import platform
import sys
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig, ParallelPlan


def software_pins() -> Dict[str, str]:
    import jax
    import numpy

    return {
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "numpy": numpy.__version__,
        "platform": platform.platform(),
    }


@dataclass(frozen=True)
class ClusterImage:
    """FROM repro:base / RUN pin deps / CMD entrypoint — as data."""
    name: str
    arch: str  # ModelConfig digest
    plan: str  # ParallelPlan repr
    entrypoint: str  # "train" | "serve" | custom
    pins: Tuple[Tuple[str, str], ...]  # sorted software pins
    labels: Tuple[Tuple[str, str], ...] = ()

    @staticmethod
    def build(name: str, cfg: ModelConfig, plan: ParallelPlan,
              entrypoint: str = "train",
              pins: Optional[Dict[str, str]] = None,
              labels: Optional[Dict[str, str]] = None) -> "ClusterImage":
        return ClusterImage(
            name=name,
            arch=cfg.digest(),
            plan=json.dumps(dataclasses.asdict(plan), sort_keys=True),
            entrypoint=entrypoint,
            pins=tuple(sorted((pins or software_pins()).items())),
            labels=tuple(sorted((labels or {}).items())),
        )

    @property
    def digest(self) -> str:
        payload = json.dumps(dataclasses.asdict(self), sort_keys=True)
        return "sha256:" + hashlib.sha256(payload.encode()).hexdigest()[:24]

    def dockerfile(self) -> str:
        """Render the equivalent Dockerfile (paper Fig. 2), for humans."""
        lines = ["FROM repro:base",
                 f"LABEL image.name={self.name} arch={self.arch}"]
        for k, v in self.pins:
            lines.append(f"RUN pin {k}=={v}")
        lines.append(f"ADD plan.json /etc/repro/plan.json  # {self.plan[:48]}…")
        lines.append(f'CMD ["repro-launch", "{self.entrypoint}"]')
        return "\n".join(lines) + "\n"


class ImageHub:
    """Local Docker-Hub analogue: digest-addressed image store."""

    def __init__(self):
        self._by_digest: Dict[str, ClusterImage] = {}
        self._tags: Dict[str, str] = {}

    def push(self, image: ClusterImage, tag: Optional[str] = None) -> str:
        d = image.digest
        self._by_digest[d] = image
        self._tags[tag or image.name] = d
        return d

    def pull(self, ref: str) -> ClusterImage:
        digest = self._tags.get(ref, ref)
        if digest not in self._by_digest:
            raise KeyError(f"image {ref!r} not found in hub")
        return self._by_digest[digest]

    def tags(self) -> Dict[str, str]:
        return dict(self._tags)
