import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x shape) cell on the
production meshes; record memory/cost/collective analysis for §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi ...

Reports land in reports/dryrun/<mesh>/<arch>__<shape>.json.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_cell


def run_cell(arch: str, shape: str, mesh, mesh_tag: str, outdir: str,
             with_units: bool = True) -> dict:
    t0 = time.time()
    with mesh:
        rep = analyze_cell(arch, shape, mesh, with_units=with_units)
    rep["lower_compile_s"] = round(time.time() - t0, 2)
    os.makedirs(os.path.join(outdir, mesh_tag), exist_ok=True)
    path = os.path.join(outdir, mesh_tag, f"{arch}__{shape}.json")
    with open(path, "w") as f:
        json.dump(rep, f, indent=1)
    return rep


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-units", action="store_true",
                    help="skip unit lowerings (faster; multi-pod pass)")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    todo = []
    if args.all:
        todo = [(a, s) for a, s, tag in cells() if tag == "run"]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    failures = []
    for mesh_tag, mesh in meshes:
        for arch, shape in todo:
            cfg = get_config(arch)
            if SHAPES[shape].long_context and not cfg.sub_quadratic:
                print(f"SKIP {mesh_tag} {arch} {shape}: full attention "
                      f"(DESIGN.md §5)")
                continue
            try:
                rep = run_cell(arch, shape, mesh, mesh_tag, args.out,
                               with_units=not args.no_units)
                mem = rep["memory"]
                print(f"OK   {mesh_tag} {arch:24s} {shape:12s} "
                      f"compute={rep['compute_s']*1e3:8.2f}ms "
                      f"mem={rep['memory_s']*1e3:8.2f}ms "
                      f"coll={rep['collective_s']*1e3:8.2f}ms "
                      f"dom={rep['dominant']:10s} "
                      f"fit={mem['fits_16GB']} "
                      f"t={rep['lower_compile_s']:.0f}s", flush=True)
            except Exception as e:
                failures.append((mesh_tag, arch, shape, repr(e)))
                print(f"FAIL {mesh_tag} {arch} {shape}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", *f)
        return 1
    print("\nALL CELLS PASSED")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
