"""Elastic training driver.

  PYTHONPATH=src python -m repro.launch.train --arch paper-demo --steps 100 \
      --nodes 3 --scale-to 4@50          # scale to 4 nodes at step 50
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --dry-run

On this container the cluster is simulated (core/simnet); on real hardware
the same VirtualCluster wiring points agents at a real Consul/etcd endpoint
and the provisioner at the cluster manager.
"""
from __future__ import annotations

import argparse
import time

from repro.configs import SHAPES, get_config, get_smoke
from repro.configs.base import ParallelPlan, ShapeConfig
from repro.core import ClusterImage, VirtualCluster
from repro.core.elastic import ElasticTrainer


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-demo")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--scale-to", default=None,
                    help="N@STEP: scale to N nodes at step STEP")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="crash a node at this step (fault-tolerance demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    plan = ParallelPlan(fsdp=False, remat="nothing", attn_impl="naive",
                        kv_cache="replicated")
    image = ClusterImage.build(f"{cfg.name}-train", cfg, plan, "train")
    cluster = VirtualCluster(n_compute=args.nodes, image=image)
    print(f"image {image.digest}\n{image.dockerfile()}")
    print("rendered hostfile:\n" + cluster.hostfile)

    trainer = ElasticTrainer(cluster.template, cfg, shape, args.ckpt_dir,
                             plan=plan, ckpt_every=args.ckpt_every)

    scale_step, scale_n = None, None
    if args.scale_to:
        n, s = args.scale_to.split("@")
        scale_n, scale_step = int(n), int(s)

    t0 = time.time()

    def on_step(step, metrics):
        if step % args.log_every == 0 or step == 1:
            print(f"step {step:5d} loss={metrics['loss']:.4f} "
                  f"epoch={cluster.rendering.epoch} "
                  f"nodes={len(cluster.compute_nodes())} "
                  f"t={time.time()-t0:.1f}s", flush=True)

    done = 0
    while done < args.steps:
        if scale_step is not None and done == scale_step:
            print(f"--- scaling to {scale_n} nodes (paper §IV auto-join) ---")
            cluster.scale_to(scale_n)
        if args.crash_at is not None and done == args.crash_at:
            victim = cluster.compute_nodes()[-1]
            print(f"--- crashing {victim} (TTL will reap it) ---")
            cluster.crash_node(victim)
            cluster.pump(dt=10.0)  # let the TTL lapse
            trainer.ensure_ready(planned=False)
        cluster.pump(dt=0.1)
        trainer.run_steps(1, on_step=on_step)
        done += 1

    trainer.finalize()
    st = trainer.stats
    print(f"done: {args.steps} steps; epochs={st.epoch_changes} "
          f"reshards={st.reshards} restores={st.restores} "
          f"steps_lost={st.steps_lost}")
    cluster.shutdown()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
