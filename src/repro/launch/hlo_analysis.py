"""Post-SPMD HLO analysis: collective bytes (with while-loop trip scaling)
and roofline terms.

cost_analysis() facts (measured, DESIGN.md §5): values are PER-DEVICE,
post-SPMD, and a while body is counted ONCE. So:
  * total flops/bytes = C(full-step lowering) + (trip-1) * C(one-unit
    lowering), composed by launch/roofline.py;
  * collective bytes are parsed from compiled.as_text(): each collective op
    is weighted by the product of trip counts of its enclosing while loops
    (trip parsed from the loop condition's comparison constant).

Hardware model (v5e-like, per the assignment): 197 bf16 TFLOP/s, 819 GB/s
HBM, ~50 GB/s/link ICI. Collective time model (ring): all-reduce moves 2x
bytes, all-gather/reduce-scatter/all-to-all/permute 1x, over n_links
concurrent links.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
DCN_BW = 6.25e9  # bytes/s per chip cross-pod (assumed 50 Gbit/s NIC share)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:%|\S+ = )?\(?([a-z0-9\[\],{}\- ]+?)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    by_type: Dict[str, int] = field(default_factory=dict)  # weighted bytes
    count: int = 0

    @property
    def total_bytes(self) -> int:
        return sum(self.by_type.values())

    def weighted_time(self, n_links: float = 3.0, bw: float = ICI_BW,
                      dcn_bytes: int = 0) -> float:
        t = 0.0
        for k, b in self.by_type.items():
            factor = 2.0 if k == "all-reduce" else 1.0
            t += factor * b / (n_links * bw)
        t += 2.0 * dcn_bytes / DCN_BW
        return t


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text (brace matching on top-level defs)."""
    comps: Dict[str, str] = {}
    lines = hlo.splitlines()
    i = 0
    while i < len(lines):
        m = _COMP_HDR_RE.match(lines[i])
        if m and lines[i].rstrip().endswith("{"):
            name = m.group(1)
            depth = 1
            body = []
            i += 1
            while i < len(lines) and depth > 0:
                depth += lines[i].count("{") - lines[i].count("}")
                body.append(lines[i])
                i += 1
            comps[name] = "\n".join(body)
        else:
            i += 1
    return comps


def _trip_count(cond_body: str) -> int:
    """Heuristic: the largest integer constant in the loop condition."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return max(consts) if consts else 1


def parse_collectives(hlo: str) -> CollectiveStats:
    """Weighted per-device collective bytes from optimized HLO text."""
    comps = _split_computations(hlo)
    # while structure: body name -> trip count; caller -> callees
    trips: Dict[str, int] = {}
    calls: Dict[str, List[str]] = {}
    for name, body in comps.items():
        for cond, wbody in _WHILE_RE.findall(body):
            trips[wbody] = _trip_count(comps.get(cond, ""))
            calls.setdefault(name, []).append(wbody)

    # weight(comp) = product of trips along the call chain from ENTRY
    weights: Dict[str, int] = {}

    def visit(name: str, w: int):
        weights[name] = max(weights.get(name, 0), w)
        for callee in calls.get(name, []):
            visit(callee, w * trips.get(callee, 1))

    roots = [n for n in comps if n not in trips]
    for r in roots:
        visit(r, 1)

    stats = CollectiveStats()
    for name, body in comps.items():
        w = weights.get(name, 1)
        for typestr, op in _COLL_RE.findall(body):
            b = _shape_bytes(typestr)
            if op.endswith("-start") or op.endswith("-done"):
                op = op.rsplit("-", 1)[0]
            stats.by_type[op] = stats.by_type.get(op, 0) + b * w
            stats.count += 1
    return stats


@dataclass
class RooflineTerms:
    flops_per_device: float
    hbm_bytes_per_device: float
    coll: CollectiveStats
    n_devices: int
    trip_note: str = ""
    dcn_bytes: int = 0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll.weighted_time(dcn_bytes=self.dcn_bytes)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def summary(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.coll.total_bytes,
            "collective_by_type": dict(self.coll.by_type),
        }


def cost_get(cost: dict, key: str) -> float:
    if isinstance(cost, list):
        cost = cost[0]
    return float(cost.get(key, 0.0))


def hbm_bytes_from_cost(cost: dict) -> float:
    """Sum 'bytes accessed' style keys; falls back to operand+output bytes."""
    if isinstance(cost, list):
        cost = cost[0]
    total = 0.0
    for k, v in cost.items():
        if k.startswith("bytes accessed"):
            total = max(total, float(v))  # 'bytes accessed' is the aggregate
    return total
