"""Roofline derivation from compiled dry-run artifacts (DESIGN.md §5).

Because cost_analysis() counts a while body once and reports per-device
values (measured in the feasibility probe), per-cell totals are composed
from three lowerings:

  C_total = C_full(rolled) - sum_i C_unit_i(rolled)
                           + sum_i trip_i * C_unit_i(inner-unrolled)

where unit_i are the scanned segments (the repeating pattern unit; plus the
encoder unit for enc-dec archs). Unit lowerings run with
plan.inner_unroll=True so their attention/rwkv chunk scans contribute exact
flops. Collective bytes come from the full compiled HLO with while-body
trip weighting (launch/hlo_analysis.py), so they need no decomposition.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_shape
from repro.configs.base import ModelConfig, ParallelPlan, ShapeConfig, default_plan
from repro.launch import hlo_analysis as H
from repro.launch import steps as S
from repro.models import model as Mo
from repro.models.env import Env
from repro.optim.adamw import AdamWConfig
from repro.parallel import rules


# ---------------------------------------------------------------------------
# unit lowerings
# ---------------------------------------------------------------------------


def _strip_leading(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), tree)


def _unit_fn(cfg: ModelConfig, env: Env, mode: str, pattern, seq_len: int):
    nv = cfg.num_vision_embeds if cfg.family == "vlm" else 0

    def apply_unit(unit_p, h, caches=None, cur_len=None, enc_out=None):
        if mode == "decode":
            positions = (Mo.build_mrope_positions(1, nv, cur_len=cur_len)
                         if cfg.mrope else None)
        else:
            positions = (Mo.build_mrope_positions(h.shape[1], nv)
                         if cfg.mrope else jnp.arange(h.shape[1]))
        ncs = []
        for i, kind in enumerate(pattern):
            c = (caches[i] if mode == "decode"
                 else ({} if mode == "prefill" else None))
            h, nc, _ = Mo._apply_block(kind, unit_p[i], h, cfg, env,
                                       mode if kind != "enc" else "train",
                                       positions, c, cur_len, enc_out)
            ncs.append(nc)
        return h, (tuple(ncs) if mode in ("prefill", "decode") else None)

    if mode != "train":
        return apply_unit

    wrapped = Mo._remat_wrap(lambda p, h: apply_unit(p, h)[0], env)

    def unit_train(unit_p, h, cot, enc_out=None):
        if enc_out is not None:
            y, vjp = jax.vjp(lambda p, hh, eo: Mo._remat_wrap(
                lambda p2, h2: apply_unit(p2, h2, enc_out=eo)[0], env)(p, hh),
                unit_p, h, enc_out)
            return y, vjp(cot)
        y, vjp = jax.vjp(wrapped, unit_p, h)
        return y, vjp(cot)

    return unit_train


def _unit_lowerings(cfg: ModelConfig, shape: ShapeConfig, env: Env):
    """Yield (name, trip, lower_fn(inner_unroll)->lowered)."""
    B = shape.global_batch
    S_eff = shape.seq_len
    mode = shape.kind
    segs = [("main", cfg.block_pattern, cfg.num_blocks, False)]
    if cfg.is_encdec and mode != "decode":
        segs.append(("enc", ("enc",), cfg.encoder_layers, True))

    p_struct = S.params_struct(cfg, env)

    for name, pattern, trip, is_enc in segs:
        seq = S_eff // cfg.enc_downsample if is_enc else S_eff
        if mode == "decode" and not is_enc:
            seq_h = 1
        else:
            seq_h = seq

        def make(inner_unroll: bool, pattern=pattern, is_enc=is_enc,
                 seq_h=seq_h):
            uenv = Env(env.mesh, dataclasses.replace(
                env.plan, inner_unroll=inner_unroll))
            key = "enc_blocks" if is_enc else "blocks"
            up = _strip_leading(p_struct[key])
            up_sh = rules.to_shardings(rules.param_specs(up, cfg, uenv), uenv)
            h = jax.ShapeDtypeStruct((B, seq_h, cfg.d_model), jnp.bfloat16)
            h_sh = uenv.sharding(uenv.dpx if B % max(uenv.dp, 1) == 0 else
                                 None, None, None)
            umode = "train" if is_enc else mode
            fn = _unit_fn(cfg, uenv, umode, pattern, seq_h)
            args = [up, h]
            shards = [up_sh, h_sh]
            if umode == "train":
                args.append(h)  # cotangent
                shards.append(h_sh)
                if cfg.is_encdec and not is_enc:
                    eo = jax.ShapeDtypeStruct(
                        (B, S_eff // cfg.enc_downsample, cfg.d_model),
                        jnp.bfloat16)
                    args.append(eo)
                    shards.append(h_sh)
            elif umode == "decode":
                c_struct = S.cache_struct(cfg, uenv, shape)
                uc = _strip_leading(c_struct["blocks"])
                uc_sh = rules.to_shardings(
                    rules.cache_specs(uc, cfg, uenv), uenv)
                args += [uc, jax.ShapeDtypeStruct((), jnp.int32)]
                shards += [uc_sh, rules.to_shardings(
                    jax.sharding.PartitionSpec(), uenv)]
            elif umode == "prefill" and cfg.is_encdec and not is_enc:
                eo = jax.ShapeDtypeStruct(
                    (B, S_eff // cfg.enc_downsample, cfg.d_model),
                    jnp.bfloat16)
                fn0 = fn
                fn = lambda up_, h_, eo_: fn0(up_, h_, enc_out=eo_)
                args.append(eo)
                shards.append(h_sh)
            return jax.jit(fn, in_shardings=tuple(shards)).lower(*args)

        yield name, trip, make


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful-flops baseline)
# ---------------------------------------------------------------------------


def model_flops(cfg: ModelConfig, shape: ShapeConfig, env: Env) -> float:
    n_total = Mo.count_params(cfg, env, padded=False)
    if cfg.moe is not None:
        # subtract inactive expert params
        expert = cfg.moe.num_experts * 3 * cfg.d_model * cfg.d_ff
        n_layers_moe = cfg.block_pattern.count("moe") * cfg.num_blocks
        inactive = (1 - cfg.moe.top_k / cfg.moe.num_experts)
        n_active = n_total - n_layers_moe * expert * inactive
    else:
        n_active = n_total
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


# ---------------------------------------------------------------------------
# cell analysis
# ---------------------------------------------------------------------------


def analyze_cell(arch: str, shape_name: str, mesh, *,
                 plan: Optional[ParallelPlan] = None,
                 opt: Optional[AdamWConfig] = None,
                 with_units: bool = True) -> Dict[str, Any]:
    from repro.launch.memory_model import analyze_memory

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    plan = plan or default_plan(cfg, shape)
    env = Env(mesh, plan)
    if opt is None and cfg.param_count() > 1e11:
        # >=100B params: int8-blockwise moments to fit one pod (DESIGN.md §4)
        opt = AdamWConfig(state_dtype="int8")
    args, in_sh, fn = S.input_specs(cfg, shape, env, opt)
    lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = H.parse_collectives(hlo)

    flops = H.cost_get(cost, "flops")
    unit_report = []
    if with_units:
        for name, trip, make in _unit_lowerings(cfg, shape, env):
            rolled = make(False).compile().cost_analysis()
            unrolled = make(True).compile().cost_analysis()
            fr, fu = H.cost_get(rolled, "flops"), H.cost_get(unrolled, "flops")
            flops += trip * fu - fr
            unit_report.append({"segment": name, "trip": trip,
                                "unit_flops": fu})

    opt_bytes = 6.3 if (opt or AdamWConfig()).state_dtype == "int8" else 12.0
    memrep = analyze_memory(cfg, shape, env,
                            opt_state_bytes_per_param=opt_bytes)
    if plan.grad_compression == "int8_ef" and "pod" in env.axis_names:
        # modeled wire saving for the cross-pod gradient sync (optim/compress)
        coll.by_type["all-reduce"] = int(
            coll.by_type.get("all-reduce", 0) * 0.625)  # pod share at int8

    n_dev = mesh.devices.size
    terms = H.RooflineTerms(flops_per_device=flops,
                            hbm_bytes_per_device=memrep.traffic_bytes,
                            coll=coll, n_devices=n_dev)
    mf = model_flops(cfg, shape, env)
    hlo_global = flops * n_dev
    dom = terms.bottleneck
    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(zip(mesh.axis_names, (int(mesh.shape[a])
                                           for a in mesh.axis_names))),
        "n_devices": int(n_dev),
        **terms.summary(),
        "model_flops_global": mf,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": mf / hlo_global if hlo_global else 0.0,
        "memory": {
            "traffic_bytes_per_device": int(memrep.traffic_bytes),
            "resident_bytes_per_device": int(memrep.resident_bytes),
            "components": memrep.components,
            "fits_16GB": memrep.fits_16GB,
            # raw XLA:CPU buffer stats (not TPU-representative; see
            # launch/memory_model.py)
            "xla_cpu_args_bytes": int(mem.argument_size_in_bytes),
            "xla_cpu_temp_bytes": int(mem.temp_size_in_bytes),
            "xla_cpu_bytes_accessed": H.hbm_bytes_from_cost(cost),
        },
        "units": unit_report,
        "dominant": dom,
        # step time bound = max of terms (perfect overlap) / sum (no overlap)
        "step_s_lower": max(terms.compute_s, terms.memory_s,
                            terms.collective_s),
        "step_s_upper": terms.compute_s + terms.memory_s + terms.collective_s,
    }
    out["roofline_fraction"] = (
        (mf / n_dev / H.PEAK_FLOPS) / out["step_s_lower"]
        if out["step_s_lower"] else 0.0)
    return out
