"""Production mesh builders.

make_production_mesh is a FUNCTION (importing this module never touches jax
device state). Axis layout follows the "no-NAT" rule (DESIGN.md §2): "model"
(TP) and "data" (FSDP) ride intra-pod ICI; only "pod" crosses DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over the available devices (subprocess tests)."""
    devs = jax.devices()
    n = n_devices or len(devs)
    model = max(1, min(model, n))
    while n % model:
        model -= 1
    return jax.make_mesh((n // model, model), ("data", "model"),
                         devices=devs[:n])
