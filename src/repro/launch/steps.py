"""Step builders + input specs for every (arch x shape) cell.

`input_specs(...)` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for everything a step consumes — this is
what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelPlan, ShapeConfig
from repro.kernels.sampling import topk_topp_mask
from repro.models import model as Mo
from repro.models.env import Env
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel import rules

Pytree = Any


def make_env(mesh, plan: ParallelPlan) -> Env:
    return Env(mesh=mesh, plan=plan)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, env: Env, opt: AdamWConfig):
    def train_step(state, batch):
        def loss_fn(params):
            return Mo.lm_loss(params, batch, cfg, env)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, new_opt = adamw_update(grads, state["opt"], opt)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, **metrics})

    return train_step


def make_prefill_step(cfg: ModelConfig, env: Env):
    def prefill_step(params, batch):
        logits, caches, _ = Mo.forward(
            params, batch["tokens"], cfg, env, mode="prefill",
            vision_embeds=batch.get("vision_embeds"),
            frames=batch.get("frames"))
        return logits[:, -1, :], caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, env: Env):
    def decode_step(params, caches, tokens, cur_len):
        logits, new_caches, _ = Mo.forward(params, tokens, cfg, env,
                                           mode="decode", caches=caches,
                                           cur_len=cur_len)
        return logits[:, 0, :], new_caches

    return decode_step


# Packed per-row step metadata: one [META_I_ROWS,T] int32 + one
# [META_F_ROWS,T] float32 upload per decode step (ServingEngine.step fills
# them; the fused steps below index through these names). This is what
# keeps the serving loop's per-step host traffic down to two small uploads
# and one [T] token-vector download.
ROW_TOK_SRC = 0  # row in prev_tok holding this row's input token (-1: fresh)
ROW_FRESH = 1    # freshly uploaded input token (prompt chunk / first token)
ROW_CUR_LEN = 2  # KV write position == attention depth for the row
ROW_SEED = 3     # SamplingParams.seed (per-request PRNG root)
ROW_TOP_K = 4    # top-k cutoff (<=0 disables)
ROW_POS0 = 5     # len(prompt)-1 of the row's request (PRNG position base)
META_I_ROWS = 6
ROW_TEMPERATURE = 0  # <=0 lowers the row to greedy argmax
ROW_TOP_P = 1        # nucleus mass (>=1 disables)
META_F_ROWS = 2


def _select_tokens(prev_tok, meta_i):
    """Device-side input-token select from the packed step metadata. Row i
    decodes prev_tok[tok_src[i]] (last step's fused sample/argmax, still on
    device) unless tok_src[i] < 0, in which case it takes the freshly
    uploaded token (prompt-chunk token or a prefill-emitted first token)."""
    tok_src, fresh_tok = meta_i[ROW_TOK_SRC], meta_i[ROW_FRESH]
    safe = jnp.clip(tok_src, 0, prev_tok.shape[0] - 1)
    return jnp.where(tok_src >= 0, prev_tok[safe], fresh_tok)


def make_sample_fn(cfg: ModelConfig, prompt_len: int):
    """Fused on-device sample step: [T,Vpad] logits -> [T] int32 tokens.

    Each row's PRNG key is jax.random.fold_in(PRNGKey(seed), position)
    where position = cur_len - pos0 is the request-logical token index
    (0 for the first generated token; pos0 = len(prompt) - 1 rides in
    ROW_POS0 so prompts shorter than the engine's prompt_len keep their
    own position base). The key depends only on the request's seed and
    its own progress — never on the batch row or composition — so a
    seeded request emits bit-identical tokens whether it decodes alone,
    inside a busy mixed-depth batch, or after a preemption restart (the
    lane-placement-invariance tests hold exactly this).

    Rows with temperature <= 0 take the plain argmax, bit-identical to the
    pre-sampling fused step, which keeps the greedy token-exactness
    baselines meaningful. top-k/top-p masking runs through
    kernels/sampling (Pallas on TPU, same-semantics XLA elsewhere);
    sampling itself is Gumbel-max over the masked, temperature-scaled
    logits — logits never leave the device either way.
    """
    V = cfg.vocab_size

    def sample(logits, meta_i, meta_f):
        lf = logits[:, :V].astype(jnp.float32)
        greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
        temp = meta_f[ROW_TEMPERATURE]
        pos = jnp.maximum(meta_i[ROW_CUR_LEN] - meta_i[ROW_POS0], 0)
        # temperature first, nucleus second (the vLLM/HF ordering): top_p
        # must see the distribution actually being sampled — a 0.8-scaled
        # softmax is sharper, so fewer tokens make the nucleus. top_k is
        # order-invariant (monotone in the logit), the mask handles both.
        scaled = lf / jnp.maximum(temp, 1e-6)[:, None]
        masked = topk_topp_mask(scaled, meta_i[ROW_TOP_K], meta_f[ROW_TOP_P])

        def row_gumbel(seed, p):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), p)
            return jax.random.gumbel(key, (V,), jnp.float32)

        g = jax.vmap(row_gumbel)(meta_i[ROW_SEED], pos)
        sampled = jnp.argmax(masked + g, axis=-1).astype(jnp.int32)
        return jnp.where(temp > 0.0, sampled, greedy)

    return sample


def make_fused_decode_step(cfg: ModelConfig, env: Env, *, prompt_len: int = 0,
                           sample: bool = False):
    """Contiguous-cache (slot pool) decode with the sample step fused on
    device: (next_tokens [T] int32, new_caches); logits never round-trip.

    sample=False is the pure-argmax variant — identical math to the
    pre-v2 step, and what an all-greedy batch runs (no mask/Gumbel work on
    the hot path). sample=True routes through make_sample_fn; greedy rows
    inside a sampling batch still lower to argmax exactly.
    """
    V = cfg.vocab_size
    sampler = make_sample_fn(cfg, prompt_len) if sample else None

    def step(params, caches, prev_tok, meta_i, meta_f):
        tok = _select_tokens(prev_tok, meta_i)
        logits, new_caches, _ = Mo.forward(
            params, tok[:, None], cfg, env, mode="decode", caches=caches,
            cur_len=meta_i[ROW_CUR_LEN])
        lg = logits[:, 0, :]
        if sampler is None:
            nxt = jnp.argmax(lg[:, :V], axis=-1).astype(jnp.int32)
        else:
            nxt = sampler(lg, meta_i, meta_f)
        return nxt, new_caches

    return step


def make_spec_decode_step(cfg: ModelConfig, env: Env, *, prompt_len: int = 0,
                          sample: bool = False):
    """Contiguous-cache decode step with row->slot indirection.

    The slot-pool analogue of the paged step's block tables: row i writes
    its K/V into cache slot row_slots[i] at cur_len[i] and attends over
    that slot at its own depth. Rows with row_slots[i] < 0 are masked —
    their write lands at the cache's never-attended tail position (the
    contiguous analogue of the paged null block), so padding rows cannot
    corrupt live slots. This is what lets speculative verify rows (several
    rows sharing one slot at consecutive depths) ride the same fused step
    the decode slots use.
    """
    V = cfg.vocab_size
    sampler = make_sample_fn(cfg, prompt_len) if sample else None

    def step(params, caches, prev_tok, meta_i, meta_f, row_slots):
        tok = _select_tokens(prev_tok, meta_i)
        logits, new_caches, _ = Mo.forward(
            params, tok[:, None], cfg, env, mode="decode", caches=caches,
            cur_len=meta_i[ROW_CUR_LEN], row_slots=row_slots)
        lg = logits[:, 0, :]
        if sampler is None:
            nxt = jnp.argmax(lg[:, :V], axis=-1).astype(jnp.int32)
        else:
            nxt = sampler(lg, meta_i, meta_f)
        return nxt, new_caches

    return step


def make_paged_decode_step(cfg: ModelConfig, env: Env, *, prompt_len: int = 0,
                           sample: bool = False):
    """Fused decode step over a paged (block-table) KV cache.

    Rows are decode slots plus optional piggybacked prefill lanes: every
    row writes its token's K/V into the physical block its table names at
    cur_len and attends at its own depth, so a prompt chunk (consecutive
    cur_len values sharing one table) prefills *inside* the running decode
    batch — each chunk row sees exactly the keys at positions <= its own.
    The sample/argmax step is fused (see make_fused_decode_step); the [T]
    token vector is the only per-step download.
    """
    V = cfg.vocab_size
    sampler = make_sample_fn(cfg, prompt_len) if sample else None

    def step(params, caches, prev_tok, meta_i, meta_f, tables):
        tok = _select_tokens(prev_tok, meta_i)
        logits, new_caches, _ = Mo.forward(
            params, tok[:, None], cfg, env, mode="decode", caches=caches,
            cur_len=meta_i[ROW_CUR_LEN], block_tables=tables)
        lg = logits[:, 0, :]
        if sampler is None:
            nxt = jnp.argmax(lg[:, :V], axis=-1).astype(jnp.int32)
        else:
            nxt = sampler(lg, meta_i, meta_f)
        return nxt, new_caches

    return step


# ---------------------------------------------------------------------------
# shape-struct builders (no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        S = S - cfg.num_vision_embeds  # vision embeds fill the rest
    out = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "vlm":
        out["vision_embeds"] = _sds((B, cfg.num_vision_embeds, cfg.d_model),
                                    jnp.float32)
    if cfg.is_encdec:
        out["frames"] = _sds((B, S // cfg.enc_downsample, cfg.d_model),
                             jnp.float32)
    return out


def params_struct(cfg: ModelConfig, env: Env) -> Pytree:
    return jax.eval_shape(lambda k: Mo.init_params(k, cfg, env),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def state_struct(cfg: ModelConfig, env: Env, opt: AdamWConfig) -> Pytree:
    p = params_struct(cfg, env)
    o = jax.eval_shape(lambda: adamw_init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p), opt))
    return {"params": p, "opt": o}


def cache_struct(cfg: ModelConfig, env: Env, shape: ShapeConfig) -> Pytree:
    return jax.eval_shape(
        lambda: Mo.init_cache(cfg, env, shape.global_batch, shape.seq_len))


@functools.lru_cache(maxsize=None)
def _nothing():
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig, env: Env,
                opt: Optional[AdamWConfig] = None
                ) -> Tuple[Tuple, Tuple, Any]:
    """Returns (args_structs, in_shardings, step_fn) for the cell.

    args are ready for jax.jit(step).lower(*args)."""
    opt = opt or AdamWConfig()
    if shape.kind == "train":
        st = state_struct(cfg, env, opt)
        bt = batch_struct(cfg, shape)
        in_sh = (rules.to_shardings(rules.state_specs(st, cfg, env), env),
                 rules.to_shardings(rules.batch_specs(bt, cfg, shape, env),
                                    env))
        return (st, bt), in_sh, make_train_step(cfg, env, opt)
    if shape.kind == "prefill":
        pt = params_struct(cfg, env)
        bt = batch_struct(cfg, shape)
        in_sh = (rules.to_shardings(rules.param_specs(pt, cfg, env), env),
                 rules.to_shardings(rules.batch_specs(bt, cfg, shape, env),
                                    env))
        return (pt, bt), in_sh, make_prefill_step(cfg, env)
    # decode
    pt = params_struct(cfg, env)
    ct = cache_struct(cfg, env, shape)
    tok = _sds((shape.global_batch, 1), jnp.int32)
    cur = _sds((), jnp.int32)
    tok_spec = rules.batch_specs({"tokens": tok}, cfg, shape, env)["tokens"]
    in_sh = (rules.to_shardings(rules.param_specs(pt, cfg, env), env),
             rules.to_shardings(rules.cache_specs(ct, cfg, env), env),
             rules.to_shardings(tok_spec, env),
             rules.to_shardings(jax.sharding.PartitionSpec(), env))
    return (pt, ct, tok, cur), in_sh, make_decode_step(cfg, env)
