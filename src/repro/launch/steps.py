"""Step builders + input specs for every (arch x shape) cell.

`input_specs(...)` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for everything a step consumes — this is
what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelPlan, ShapeConfig
from repro.models import model as Mo
from repro.models.env import Env
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel import rules

Pytree = Any


def make_env(mesh, plan: ParallelPlan) -> Env:
    return Env(mesh=mesh, plan=plan)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, env: Env, opt: AdamWConfig):
    def train_step(state, batch):
        def loss_fn(params):
            return Mo.lm_loss(params, batch, cfg, env)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, new_opt = adamw_update(grads, state["opt"], opt)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, **metrics})

    return train_step


def make_prefill_step(cfg: ModelConfig, env: Env):
    def prefill_step(params, batch):
        logits, caches, _ = Mo.forward(
            params, batch["tokens"], cfg, env, mode="prefill",
            vision_embeds=batch.get("vision_embeds"),
            frames=batch.get("frames"))
        return logits[:, -1, :], caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, env: Env):
    def decode_step(params, caches, tokens, cur_len):
        logits, new_caches, _ = Mo.forward(params, tokens, cfg, env,
                                           mode="decode", caches=caches,
                                           cur_len=cur_len)
        return logits[:, 0, :], new_caches

    return decode_step


def make_slot_decode_step(cfg: ModelConfig, env: Env):
    """Decode step for a slot-pooled cache (continuous batching).

    The same step as make_decode_step — Mo.forward accepts cur_len as a
    scalar or a [B] int32 vector, and with a vector each row (slot) attends
    and writes at its own position, so requests at different generation
    depths share one jitted step. Rows holding free slots still compute
    (their writes land in slots that insert fully overwrites) — callers
    mask their outputs.
    """
    return make_decode_step(cfg, env)


# ---------------------------------------------------------------------------
# shape-struct builders (no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        S = S - cfg.num_vision_embeds  # vision embeds fill the rest
    out = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "vlm":
        out["vision_embeds"] = _sds((B, cfg.num_vision_embeds, cfg.d_model),
                                    jnp.float32)
    if cfg.is_encdec:
        out["frames"] = _sds((B, S // cfg.enc_downsample, cfg.d_model),
                             jnp.float32)
    return out


def params_struct(cfg: ModelConfig, env: Env) -> Pytree:
    return jax.eval_shape(lambda k: Mo.init_params(k, cfg, env),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def state_struct(cfg: ModelConfig, env: Env, opt: AdamWConfig) -> Pytree:
    p = params_struct(cfg, env)
    o = jax.eval_shape(lambda: adamw_init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p), opt))
    return {"params": p, "opt": o}


def cache_struct(cfg: ModelConfig, env: Env, shape: ShapeConfig) -> Pytree:
    return jax.eval_shape(
        lambda: Mo.init_cache(cfg, env, shape.global_batch, shape.seq_len))


@functools.lru_cache(maxsize=None)
def _nothing():
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig, env: Env,
                opt: Optional[AdamWConfig] = None
                ) -> Tuple[Tuple, Tuple, Any]:
    """Returns (args_structs, in_shardings, step_fn) for the cell.

    args are ready for jax.jit(step).lower(*args)."""
    opt = opt or AdamWConfig()
    if shape.kind == "train":
        st = state_struct(cfg, env, opt)
        bt = batch_struct(cfg, shape)
        in_sh = (rules.to_shardings(rules.state_specs(st, cfg, env), env),
                 rules.to_shardings(rules.batch_specs(bt, cfg, shape, env),
                                    env))
        return (st, bt), in_sh, make_train_step(cfg, env, opt)
    if shape.kind == "prefill":
        pt = params_struct(cfg, env)
        bt = batch_struct(cfg, shape)
        in_sh = (rules.to_shardings(rules.param_specs(pt, cfg, env), env),
                 rules.to_shardings(rules.batch_specs(bt, cfg, shape, env),
                                    env))
        return (pt, bt), in_sh, make_prefill_step(cfg, env)
    # decode
    pt = params_struct(cfg, env)
    ct = cache_struct(cfg, env, shape)
    tok = _sds((shape.global_batch, 1), jnp.int32)
    cur = _sds((), jnp.int32)
    tok_spec = rules.batch_specs({"tokens": tok}, cfg, shape, env)["tokens"]
    in_sh = (rules.to_shardings(rules.param_specs(pt, cfg, env), env),
             rules.to_shardings(rules.cache_specs(ct, cfg, env), env),
             rules.to_shardings(tok_spec, env),
             rules.to_shardings(jax.sharding.PartitionSpec(), env))
    return (pt, ct, tok, cur), in_sh, make_decode_step(cfg, env)
