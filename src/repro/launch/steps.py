"""Step builders + input specs for every (arch x shape) cell.

`input_specs(...)` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for everything a step consumes — this is
what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelPlan, ShapeConfig
from repro.models import model as Mo
from repro.models.env import Env
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel import rules

Pytree = Any


def make_env(mesh, plan: ParallelPlan) -> Env:
    return Env(mesh=mesh, plan=plan)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, env: Env, opt: AdamWConfig):
    def train_step(state, batch):
        def loss_fn(params):
            return Mo.lm_loss(params, batch, cfg, env)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, new_opt = adamw_update(grads, state["opt"], opt)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, **metrics})

    return train_step


def make_prefill_step(cfg: ModelConfig, env: Env):
    def prefill_step(params, batch):
        logits, caches, _ = Mo.forward(
            params, batch["tokens"], cfg, env, mode="prefill",
            vision_embeds=batch.get("vision_embeds"),
            frames=batch.get("frames"))
        return logits[:, -1, :], caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, env: Env):
    def decode_step(params, caches, tokens, cur_len):
        logits, new_caches, _ = Mo.forward(params, tokens, cfg, env,
                                           mode="decode", caches=caches,
                                           cur_len=cur_len)
        return logits[:, 0, :], new_caches

    return decode_step


def _select_tokens(prev_tok, meta):
    """Device-side input-token select from the packed [3,T] step metadata
    (rows: tok_src, fresh_tok, cur_len — one upload per step). Row i
    decodes prev_tok[tok_src[i]] (last step's argmax, still on device)
    unless tok_src[i] < 0, in which case it takes the freshly uploaded
    fresh token (prompt-chunk token or a prefill-emitted first token).
    This is what keeps the serving loop's per-step host traffic down to
    one small upload and one [T] token-vector download."""
    tok_src, fresh_tok = meta[0], meta[1]
    safe = jnp.clip(tok_src, 0, prev_tok.shape[0] - 1)
    return jnp.where(tok_src >= 0, prev_tok[safe], fresh_tok)


def make_fused_decode_step(cfg: ModelConfig, env: Env):
    """Slot-pool decode with the argmax fused on device.

    meta is the packed [3,T] int32 (tok_src, fresh_tok, cur_len). Returns
    (next_tokens [T] int32, new_caches) — logits never leave the device;
    the engine transfers only the token vector each step."""
    V = cfg.vocab_size

    def step(params, caches, prev_tok, meta):
        tok = _select_tokens(prev_tok, meta)
        logits, new_caches, _ = Mo.forward(
            params, tok[:, None], cfg, env, mode="decode", caches=caches,
            cur_len=meta[2])
        nxt = jnp.argmax(logits[:, 0, :V], axis=-1).astype(jnp.int32)
        return nxt, new_caches

    return step


def make_paged_decode_step(cfg: ModelConfig, env: Env):
    """Fused decode step over a paged (block-table) KV cache.

    Rows are decode slots plus optional piggybacked prefill lanes: every
    row writes its token's K/V into the physical block its table names at
    cur_len and attends at its own depth, so a prompt chunk (consecutive
    cur_len values sharing one table) prefills *inside* the running decode
    batch — each chunk row sees exactly the keys at positions <= its own.
    meta is the packed [3,T] int32 (tok_src, fresh_tok, cur_len). Argmax
    is fused; the [T] token vector is the only per-step download.
    """
    V = cfg.vocab_size

    def step(params, caches, prev_tok, meta, tables):
        tok = _select_tokens(prev_tok, meta)
        logits, new_caches, _ = Mo.forward(
            params, tok[:, None], cfg, env, mode="decode", caches=caches,
            cur_len=meta[2], block_tables=tables)
        nxt = jnp.argmax(logits[:, 0, :V], axis=-1).astype(jnp.int32)
        return nxt, new_caches

    return step


# ---------------------------------------------------------------------------
# shape-struct builders (no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        S = S - cfg.num_vision_embeds  # vision embeds fill the rest
    out = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "vlm":
        out["vision_embeds"] = _sds((B, cfg.num_vision_embeds, cfg.d_model),
                                    jnp.float32)
    if cfg.is_encdec:
        out["frames"] = _sds((B, S // cfg.enc_downsample, cfg.d_model),
                             jnp.float32)
    return out


def params_struct(cfg: ModelConfig, env: Env) -> Pytree:
    return jax.eval_shape(lambda k: Mo.init_params(k, cfg, env),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def state_struct(cfg: ModelConfig, env: Env, opt: AdamWConfig) -> Pytree:
    p = params_struct(cfg, env)
    o = jax.eval_shape(lambda: adamw_init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p), opt))
    return {"params": p, "opt": o}


def cache_struct(cfg: ModelConfig, env: Env, shape: ShapeConfig) -> Pytree:
    return jax.eval_shape(
        lambda: Mo.init_cache(cfg, env, shape.global_batch, shape.seq_len))


@functools.lru_cache(maxsize=None)
def _nothing():
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig, env: Env,
                opt: Optional[AdamWConfig] = None
                ) -> Tuple[Tuple, Tuple, Any]:
    """Returns (args_structs, in_shardings, step_fn) for the cell.

    args are ready for jax.jit(step).lower(*args)."""
    opt = opt or AdamWConfig()
    if shape.kind == "train":
        st = state_struct(cfg, env, opt)
        bt = batch_struct(cfg, shape)
        in_sh = (rules.to_shardings(rules.state_specs(st, cfg, env), env),
                 rules.to_shardings(rules.batch_specs(bt, cfg, shape, env),
                                    env))
        return (st, bt), in_sh, make_train_step(cfg, env, opt)
    if shape.kind == "prefill":
        pt = params_struct(cfg, env)
        bt = batch_struct(cfg, shape)
        in_sh = (rules.to_shardings(rules.param_specs(pt, cfg, env), env),
                 rules.to_shardings(rules.batch_specs(bt, cfg, shape, env),
                                    env))
        return (pt, bt), in_sh, make_prefill_step(cfg, env)
    # decode
    pt = params_struct(cfg, env)
    ct = cache_struct(cfg, env, shape)
    tok = _sds((shape.global_batch, 1), jnp.int32)
    cur = _sds((), jnp.int32)
    tok_spec = rules.batch_specs({"tokens": tok}, cfg, shape, env)["tokens"]
    in_sh = (rules.to_shardings(rules.param_specs(pt, cfg, env), env),
             rules.to_shardings(rules.cache_specs(ct, cfg, env), env),
             rules.to_shardings(tok_spec, env),
             rules.to_shardings(jax.sharding.PartitionSpec(), env))
    return (pt, ct, tok, cur), in_sh, make_decode_step(cfg, env)
