"""Serving CLI — a thin driver over the continuous-batching engine.

Closed-loop demo (trace mode): inject a Poisson arrival trace, serve it via
continuous batching over a paged (block-table) KV cache with chunked
prefill on a VirtualCluster whose autoscaling policy reads the engine's
published metrics, and watch the cluster grow 1->N while the queue is deep
and shrink back as it drains:

  PYTHONPATH=src python -m repro.launch.serve --trace poisson --smoke

One-shot baseline (the pre-continuous-batching path, kept for comparison and
for the token-for-token correctness tests):

  PYTHONPATH=src python -m repro.launch.serve --trace oneshot --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.configs.base import ParallelPlan
from repro.core import ClusterImage, LatencyPolicy, QueueDepthPolicy, \
    VirtualCluster
from repro.core.clock import ManualClock
from repro.launch import steps as St
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve import (SERVE_PLAN, SamplingParams, burst_trace,
                         make_scheduler_policy, make_serving_engine,
                         poisson_trace, repetitive_trace, run_to_completion,
                         sysprompt_trace)


def serve_batch(mesh, cfg, params, prompts, gen_len: int, plan,
                streamed_prefill: bool = False):
    """One-shot batch serving: prefill every prompt together, then decode
    the uniform batch to gen_len. The correctness baseline for the
    continuous-batching engine.

    streamed_prefill=True feeds the prompt token-by-token through the same
    decode step instead of one full-sequence prefill call — the one-shot
    baseline whose floating-point path matches *chunked* prefill (a full
    prefill reduces attention in GEMM order; per-token decode reduces
    per-query — same math, different fp association, so greedy argmax can
    flip on near-ties between the two; see docs/serving.md)."""
    env = Env(mesh=mesh, plan=plan)
    B, S = prompts.shape
    decode = jax.jit(St.make_decode_step(cfg, env), donate_argnums=(1,))

    if streamed_prefill:
        caches = Mo.init_cache(cfg, env, B, S + gen_len)
        logits = None
        for i in range(S):
            logits, caches = decode(params, caches, prompts[:, i:i + 1],
                                    jnp.asarray(i, jnp.int32))
    else:
        prefill = jax.jit(St.make_prefill_step(cfg, env))
        # allocate full-length caches, then write the prompt via prefill
        kw = {"tokens": prompts}
        if cfg.family == "vlm":
            kw["vision_embeds"] = jnp.zeros((B, cfg.num_vision_embeds,
                                             cfg.d_model), jnp.float32)
        if cfg.is_encdec:
            kw["frames"] = jnp.zeros((B, S // cfg.enc_downsample,
                                      cfg.d_model), jnp.float32)
        logits, caches = prefill(params, kw)
        # grow cache seq dim so decode can append (prefill emits length-S
        # caches; window rings stay at min(w, S + gen))
        caches = Mo.grow_caches(caches, gen_len, cfg)
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    out = [tok]
    offset = cfg.num_vision_embeds if cfg.family == "vlm" else 0
    for i in range(gen_len - 1):
        logits, caches = decode(params, caches, tok,
                                jnp.asarray(S + offset + i, jnp.int32))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1
                         ).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def _build_policy(args):
    if args.policy == "latency":
        return LatencyPolicy(target_p95_ms=args.target_p95_ms,
                             min_nodes=args.nodes, max_nodes=args.max_nodes)
    return QueueDepthPolicy(target_per_node=args.queue_per_node,
                            min_nodes=args.nodes, max_nodes=args.max_nodes)


def _sampling_of(args) -> SamplingParams:
    return SamplingParams(temperature=args.temperature, top_k=args.top_k,
                          top_p=args.top_p, seed=args.sample_seed)


def _trace_of(args, cfg):
    """Build the arrival trace (deterministic for the args — the sampled
    verify path regenerates it for a second engine)."""
    sampling = _sampling_of(args)
    if args.trace == "burst":
        return burst_trace(args.requests, prompt_len=args.prompt_len,
                           vocab_size=cfg.vocab_size, gen_len=args.gen,
                           deadline_s=args.deadline, sampling=sampling,
                           seed=args.seed)
    if args.trace == "sysprompt":
        return sysprompt_trace(args.requests, args.rate,
                               prompt_len=args.prompt_len,
                               vocab_size=cfg.vocab_size,
                               prefix_len=args.prefix_len, gen_len=args.gen,
                               gen_len_max=args.gen_max,
                               deadline_s=args.deadline, sampling=sampling,
                               seed=args.seed)
    if args.trace == "repetitive":
        return repetitive_trace(args.requests, args.rate,
                                prompt_len=args.prompt_len,
                                vocab_size=cfg.vocab_size, gen_len=args.gen,
                                gen_len_max=args.gen_max,
                                deadline_s=args.deadline, sampling=sampling,
                                seed=args.seed)
    return poisson_trace(args.requests, args.rate,
                         prompt_len=args.prompt_len,
                         vocab_size=cfg.vocab_size, gen_len=args.gen,
                         gen_len_max=args.gen_max, deadline_s=args.deadline,
                         sampling=sampling, seed=args.seed)


def _make_engine(args, cfg, params, *, num_slots=None, replicas=None,
                 clock=None, spec=None):
    """A ServingEngine (replicas == 1) or a Router + ReplicaSet data
    plane. --kv-blocks is per replica, so a fleet runs at replicas x that
    total budget — pass total/replicas to compare at equal KV bytes.
    `spec` overrides --spec (the --verify re-serve passes "off")."""
    sched = {"preemptive": True} if (args.sched == "edf"
                                     and args.edf_preempt) else {}
    return make_serving_engine(
        cfg, params,
        replicas=args.replicas if replicas is None else replicas,
        routing=args.routing, drain_mode=args.drain,
        num_slots=num_slots or args.slots,
        prompt_len=args.prompt_len, max_gen=args.gen_max,
        kv=args.kv, block_size=args.block_size,
        kv_blocks=args.kv_blocks,
        prefix_cache=args.prefix_cache == "on",
        prefill_chunk=args.prefill_chunk,
        spec=args.spec if spec is None else spec,
        spec_k=args.spec_k,
        swap=args.swap == "on",
        swap_budget_blocks=args.swap_budget_blocks,
        policy=make_scheduler_policy(args.sched, **sched),
        clock=clock)


def run_trace(args, cfg, params) -> int:
    policy = _build_policy(args)
    image = ClusterImage.build(f"{cfg.name}-serve", cfg, SERVE_PLAN, "serve")
    n0 = max(args.nodes, args.replicas)  # fleet replicas track nodes 1:1
    cluster = VirtualCluster(n_compute=n0, image=image, policy=policy,
                             cooldown_s=args.cooldown)
    print("serving replicas register to the catalog:\n" + cluster.hostfile)

    engine = _make_engine(args, cfg, params, clock=cluster.clock)
    multi = args.replicas > 1
    plane = engine.describe() if multi else engine.pool.describe()
    spec_tag = ("off" if args.spec == "off"
                else f"{args.spec} k={args.spec_k}")
    print(f"{plane}, chunked prefill="
          f"{engine.prefill_chunk or 'off'}, scheduler={engine.policy.name}, "
          f"spec={spec_tag}, "
          f"sampling={'greedy' if args.temperature <= 0 else _sampling_of(args)}")
    trace = _trace_of(args, cfg)

    sizes = []  # scaling timeline: (sim_t, n_compute)

    def on_step(i, snap, c):
        n = len(c.current_view().compute)
        if not sizes or sizes[-1][1] != n:
            sizes.append((c.clock.now(), n))
            extra = (f"  replicas={snap['replicas_live']:.0f}"
                     if multi else "")
            print(f"  t={c.clock.now():7.2f}s  nodes={n}  "
                  f"queue={snap['queue_depth']:.0f}  "
                  f"p95={snap.get('latency_p95_ms', 0.0):.0f}ms  "
                  f"occ={snap['slot_occupancy']:.2f}{extra}")

    if multi:
        # the fleet's speedup is real — every live replica decodes its own
        # batch within the tick — so one step costs step_time flat
        dt = args.step_time
    else:
        # one decode step costs step_time on one node; N data-parallel
        # serving replicas drain the shared queue ~N x faster (the PR-1
        # sim speedup model, kept for the single-engine baseline)
        dt = lambda n: args.step_time / max(n, 1)
    t0 = time.time()
    out = cluster.serve(engine, trace, dt=dt, on_step=on_step)
    wall = time.time() - t0

    peak = max((n for _, n in sizes), default=n0)
    final = len(cluster.current_view().compute)
    n_tok = sum(len(t) for t in out.values())
    snap = engine.snapshot()
    print(f"served {len(out)}/{len(trace)} requests, {n_tok} tokens "
          f"in {engine.clock.now():.2f}s sim ({wall:.2f}s wall)")
    print(f"autoscale: start={n0} peak={peak} final={final} "
          f"({len(cluster.scaler.history)} actions)")
    if multi:
        print(f"fleet: replicas live={snap['replicas_live']:.0f} "
              f"cold warmups={snap['replica_warmups']:.0f} "
              f"drained+released={len(engine.released)} "
              f"routing={engine.routing.name}")
    print(f"p50={snap.get('latency_p50_ms', 0.0):.0f}ms "
          f"p95={snap.get('latency_p95_ms', 0.0):.0f}ms "
          f"tokens/s(sim)={snap['tokens_per_s']:.1f}")
    if snap.get("prefix_hit_rate", 0.0) > 0.0:
        print(f"prefix cache: hit rate "
              f"{snap['prefix_hit_rate']:.2f}, prefill tokens computed "
              f"{snap['prefill_tokens']:.0f}, shared occupancy "
              f"{snap['kv_shared_occupancy']:.2f}")
    if "accepted_per_step" in snap:
        print(f"speculative ({spec_tag}): accepted/step "
              f"{snap['accepted_per_step']:.2f}, acceptance rate "
              f"{snap['spec_acceptance_rate']:.2f}")
    if args.swap == "on":
        print(f"swap tier: {snap.get('swapped_blocks', 0.0):.0f} blocks "
              f"out ({snap.get('swap_out_bytes', 0.0):.0f}B), "
              f"{snap.get('swap_in_bytes', 0.0):.0f}B restored, "
              f"recomputed tokens {snap.get('recomputed_tokens', 0.0):.0f}")
    if "kv_quant_divergence" in snap:
        print(f"quant KV: calibrated divergence "
              f"{snap['kv_quant_divergence']:.4f} relative RMS")

    rc = 0
    if args.verify:
        if multi:
            # the multi-replica acceptance bar: the same trace through a
            # single zero-router engine must emit bit-identical tokens —
            # routing, replica count, and any drain events along the way
            # are invisible in the output (greedy and seeded alike)
            eng2 = _make_engine(args, cfg, params, replicas=1,
                                clock=ManualClock())
            out2 = run_to_completion(eng2, _trace_of(args, cfg),
                                     dt=args.step_time)
            ok = out == out2
            print(f"verify {args.replicas} replicas "
                  f"({engine.routing.name} routing) vs 1: "
                  f"{'bit-identical MATCH' if ok else 'MISMATCH'}")
        elif args.spec != "off":
            # the speculative acceptance bar: the same trace served with
            # --spec off on a fresh engine must emit bit-identical tokens
            # — drafters and verify lanes are invisible in the output
            # (greedy and seeded alike; token-match acceptance is the
            # degenerate rejection-sampling residual, serve/spec.py)
            eng2 = _make_engine(args, cfg, params, spec="off",
                                clock=ManualClock())
            out2 = run_to_completion(eng2, _trace_of(args, cfg),
                                     dt=args.step_time)
            ok = out == out2
            print(f"verify --spec {args.spec} (k={args.spec_k}) vs "
                  f"--spec off: "
                  f"{'bit-identical MATCH' if ok else 'MISMATCH'}")
        elif args.kv == "quant" and args.temperature <= 0:
            # the quantized cache is bounded-divergence, not bit-exact, so
            # the fp one-shot is no oracle. Verify the invariance contract
            # it DOES keep: the same trace on a fresh quant engine with a
            # different slot count (different lane placements, batch
            # compositions — and swap/preemption events, if any) must emit
            # bit-identical tokens. tests/test_tiered_kv.py pins the
            # divergence bound against the fp engine separately.
            alt = args.slots // 2 if args.slots > 1 else args.slots + 1
            eng2 = _make_engine(args, cfg, params, num_slots=alt,
                                clock=ManualClock())
            out2 = run_to_completion(eng2, _trace_of(args, cfg),
                                     dt=args.step_time)
            ok = out == out2
            print(f"verify quant KV ({args.slots} vs {alt} slots): "
                  f"{'bit-identical MATCH' if ok else 'MISMATCH'}")
        elif args.temperature > 0:
            # seeded sampling has no one-shot oracle; verify the v2
            # contract instead: the same trace on a fresh engine with a
            # different slot count (different lane placements, different
            # batch compositions) must emit bit-identical tokens
            alt = args.slots // 2 if args.slots > 1 else args.slots + 1
            eng2 = _make_engine(args, cfg, params, num_slots=alt,
                                clock=ManualClock())
            out2 = run_to_completion(eng2, _trace_of(args, cfg),
                                     dt=args.step_time)
            ok = out == out2
            print(f"verify seeded sampling ({args.slots} vs {alt} slots): "
                  f"{'bit-identical MATCH' if ok else 'MISMATCH'}")
        else:
            prompts = jnp.asarray(np.stack([r.prompt for r in trace]))
            # chunked prefill's fp path matches the streamed-prefill
            # one-shot (full-prefill GEMM reassociates; docs/serving.md)
            streamed = bool(engine.prefill_chunk)
            base = np.asarray(serve_batch(None, cfg, params, prompts,
                                          args.gen_max, SERVE_PLAN,
                                          streamed_prefill=streamed))
            # slice by the *admitted* budget (gen_len capped by
            # max_tokens) — submit() no longer rewrites r.gen_len
            ok = all(np.array_equal(base[r.rid][:r.eff_gen_len],
                                    np.array(out[r.rid]))
                     for r in trace)
            tag = "streamed-prefill one-shot" if streamed else "one-shot"
            print(f"verify vs {tag} baseline: "
                  f"{'token-for-token MATCH' if ok else 'MISMATCH'}")
        rc = 0 if ok else 1
    cluster.shutdown()
    return rc


def run_oneshot(args, cfg, params) -> int:
    image = ClusterImage.build(f"{cfg.name}-serve", cfg, SERVE_PLAN, "serve")
    cluster = VirtualCluster(n_compute=args.nodes, image=image)
    print("serving replicas register to the catalog:\n" + cluster.hostfile)
    rng = jax.random.PRNGKey(args.seed)
    prompts = jax.random.randint(rng, (args.requests, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    t0 = time.time()
    toks = cluster.submit(serve_batch, cfg, params, prompts, args.gen,
                          SERVE_PLAN)
    dt = time.time() - t0
    n_tok = args.requests * args.gen
    print(f"generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s on this CPU sim)")
    print("sample:", np.asarray(toks[0])[:16])
    cluster.shutdown()
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="paper-demo")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", default="poisson",
                    choices=("poisson", "burst", "sysprompt", "repetitive",
                             "oneshot"))
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--gen-max", type=int, default=None,
                    help="max gen length (default: --gen)")
    ap.add_argument("--rate", type=float, default=16.0,
                    help="poisson arrival rate, requests/s (sim time)")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV-cache slots per replica (max concurrent "
                    "decodes each)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serving replicas, each with its own KV pool and "
                    "prefix cache; a Router admits requests across them "
                    "and the autoscaler drains/spawns them live (1 = the "
                    "zero-router single-engine data plane)")
    ap.add_argument("--routing", default="occupancy",
                    choices=("occupancy", "prefix"),
                    help="replica routing policy: least committed KV, or "
                    "prefix-affine (route to the replica whose prefix "
                    "cache holds the prompt's longest prefix)")
    ap.add_argument("--drain", default="finish",
                    choices=("finish", "preempt"),
                    help="scale-down drain mode: let a draining replica's "
                    "requests finish, or restart-preempt them back to the "
                    "router queue (bit-identical either way)")
    ap.add_argument("--kv", default="paged",
                    choices=("paged", "quant", "slot"),
                    help="paged block-table cache, int8-quantized paged "
                    "cache (~2x blocks per byte, bounded divergence), or "
                    "PR-1 slot reservation")
    ap.add_argument("--swap", default="off", choices=("on", "off"),
                    help="host swap tier for paged/quant KV: preemptions "
                    "copy victim blocks to host RAM and resume "
                    "bit-identically with zero recompute")
    ap.add_argument("--swap-budget-blocks", type=int, default=None,
                    help="host swap residency cap in blocks (default: "
                    "unbounded); a full budget falls back to restart "
                    "preemption")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV: tokens per block")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="paged KV: physical blocks (default: worst case)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill lane width (0 disables; default: "
                    "prompt_len on attention-only archs)")
    ap.add_argument("--prefix-cache", default="on", choices=("on", "off"),
                    help="paged KV: share full prompt-prefix blocks across "
                    "requests (copy-on-write; exact, greedy and seeded)")
    ap.add_argument("--prefix-len", type=int, default=None,
                    help="sysprompt trace: shared system-prompt length "
                    "(default: 3/4 of --prompt-len)")
    ap.add_argument("--spec", default="off",
                    choices=("off", "ngram", "model"),
                    help="speculative decoding drafter: prompt-lookup "
                    "self-drafting (ngram) or a tiny draft model; the "
                    "target verifies k drafts per slot in one fused step "
                    "and output stays bit-identical to --spec off")
    ap.add_argument("--spec-k", default="4",
                    help="draft tokens proposed per slot per step, or "
                    "'auto': adapt each request's draft depth from its own "
                    "acceptance feedback (AIMD, floor 1)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k logit cutoff (0 disables)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 disables)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="sampling PRNG root (per-request seeds derive "
                    "from it; output is reproducible and lane-invariant)")
    ap.add_argument("--sched", default="fifo", choices=("fifo", "edf"),
                    help="admission-order scheduler policy")
    ap.add_argument("--edf-preempt", action="store_true",
                    help="EDF only: allow restart-preemption of the "
                    "slackest running request for an urgent arrival")
    ap.add_argument("--deadline", type=float, default=float("inf"),
                    help="per-request completion deadline, seconds (EDF "
                    "orders by it; misses feed the autoscaler)")
    ap.add_argument("--nodes", type=int, default=1,
                    help="initial / minimum compute nodes")
    ap.add_argument("--max-nodes", type=int, default=6)
    ap.add_argument("--policy", default="queue", choices=("queue", "latency"))
    ap.add_argument("--queue-per-node", type=int, default=2)
    ap.add_argument("--target-p95-ms", type=float, default=400.0)
    ap.add_argument("--step-time", type=float, default=0.05,
                    help="simulated seconds per decode step on one node")
    ap.add_argument("--cooldown", type=float, default=0.3,
                    help="autoscaler cooldown between actions (sim seconds)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="check tokens against the one-shot baseline")
    args = ap.parse_args()
    if args.spec_k != "auto":
        args.spec_k = int(args.spec_k)
    if args.gen_max is None:
        args.gen_max = args.gen
    if args.prefix_len is None:
        args.prefix_len = (3 * args.prompt_len) // 4
    if (args.trace == "sysprompt" and args.prefix_cache == "on"
            and args.prefix_len < args.block_size):
        print(f"warning: --prefix-len {args.prefix_len} < --block-size "
              f"{args.block_size}: the shared prefix spans no full block, "
              "so the prefix cache cannot hit (try --block-size 4)")

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    rng = jax.random.PRNGKey(0)
    params = Mo.init_params(rng, cfg, Env(mesh=None, plan=SERVE_PLAN))

    if args.trace == "oneshot":
        return run_oneshot(args, cfg, params)
    return run_trace(args, cfg, params)


if __name__ == "__main__":
    raise SystemExit(main())
