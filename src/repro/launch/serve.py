"""Serving driver: batched prefill + decode over the virtual cluster.

  PYTHONPATH=src python -m repro.launch.serve --arch paper-demo --smoke \
      --requests 8 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.configs.base import ParallelPlan, ShapeConfig
from repro.core import ClusterImage, VirtualCluster
from repro.launch import steps as St
from repro.models import model as Mo
from repro.models.env import Env


def serve_batch(mesh, cfg, params, prompts, gen_len: int, plan):
    env = Env(mesh=mesh, plan=plan)
    B, S = prompts.shape
    total = S + gen_len
    prefill = jax.jit(St.make_prefill_step(cfg, env))
    decode = jax.jit(St.make_decode_step(cfg, env), donate_argnums=(1,))

    # allocate full-length caches, then write the prompt via prefill
    kw = {"tokens": prompts}
    if cfg.family == "vlm":
        kw["vision_embeds"] = jnp.zeros((B, cfg.num_vision_embeds,
                                         cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        kw["frames"] = jnp.zeros((B, S // cfg.enc_downsample, cfg.d_model),
                                 jnp.float32)
    logits, caches = prefill(params, kw)
    # grow cache seq dim so decode can append (prefill emits length-S caches)
    caches = Mo.grow_caches(caches, gen_len)
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    out = [tok]
    offset = cfg.num_vision_embeds if cfg.family == "vlm" else 0
    for i in range(gen_len - 1):
        logits, caches = decode(params, caches, tok,
                                jnp.asarray(S + offset + i, jnp.int32))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1
                         ).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-demo")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    plan = ParallelPlan(fsdp=False, remat="full", attn_impl="naive",
                        kv_cache="replicated")
    image = ClusterImage.build(f"{cfg.name}-serve", cfg, plan, "serve")
    cluster = VirtualCluster(n_compute=args.nodes, image=image)
    print("serving replicas register to the catalog:\n" + cluster.hostfile)

    rng = jax.random.PRNGKey(0)
    env0 = Env(mesh=None, plan=plan)
    params = Mo.init_params(rng, cfg, env0)
    prompts = jax.random.randint(rng, (args.requests, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    t0 = time.time()
    toks = cluster.submit(serve_batch, cfg, params, prompts, args.gen, plan)
    dt = time.time() - t0
    n_tok = args.requests * args.gen
    print(f"generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s on this CPU sim)")
    print("sample:", np.asarray(toks[0])[:16])
    cluster.shutdown()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
