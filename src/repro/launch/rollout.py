"""Rollout CLI — the serving fleet as a post-training generation engine.

Closed-loop demo: fan a prompt set out as seeded rollouts over the
continuous-batching fleet (the autoscaler grows into the burst), score
the completions, build chosen/rejected pairs, and step the serving
model's own params with a DPO update — then sample the next round from
the freshly trained policy:

  PYTHONPATH=src python -m repro.launch.rollout --smoke --verify

Multi-turn trace (completions re-enter the queue as follow-ups with grown
shared prefixes — the prefix-cache + affine-routing stress test):

  PYTHONPATH=src python -m repro.launch.rollout --trace multiturn --smoke

--verify checks the reproducibility contract that makes rollouts usable
as training data: the same prompt set through --replicas N and through a
single engine with a different slot count must emit bit-identical
completions per (prompt, sample, turn) coordinate.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core import ClusterImage, LatencyPolicy, QueueDepthPolicy, \
    VirtualCluster
from repro.core.clock import ManualClock
from repro.models import model as Mo
from repro.models.env import Env
from repro.optim.adamw import AdamWConfig
from repro.rollout import (PreferenceTrainer, RolloutEngine, RolloutLoop,
                           make_scorer, rollout_signature)
from repro.serve import (SERVE_PLAN, SamplingParams, make_scheduler_policy,
                         make_serving_engine)


def _build_policy(args):
    if args.policy == "latency":
        return LatencyPolicy(target_p95_ms=args.target_p95_ms,
                             min_nodes=args.nodes, max_nodes=args.max_nodes)
    return QueueDepthPolicy(target_per_node=args.queue_per_node,
                            min_nodes=args.nodes, max_nodes=args.max_nodes)


def _sampling_of(args) -> SamplingParams:
    return SamplingParams(temperature=args.temperature, top_k=args.top_k,
                          top_p=args.top_p, seed=args.sample_seed)


def _prompts_of(args, cfg):
    rng = np.random.default_rng(args.seed)
    return [rng.integers(0, cfg.vocab_size, size=(args.prompt_len,),
                         dtype=np.int32) for _ in range(args.prompts)]


def _make_engine(args, cfg, params, *, replicas=None, num_slots=None,
                 clock=None):
    """Engine budgeted for the multi-turn context growth: turn t prompts
    are base + t*gen tokens, so prompt_len covers the final turn."""
    return make_serving_engine(
        cfg, params,
        replicas=args.replicas if replicas is None else replicas,
        routing=args.routing,
        num_slots=num_slots or args.slots,
        prompt_len=args.prompt_len + (args.turns - 1) * args.gen,
        max_gen=args.gen,
        kv=args.kv, block_size=args.block_size,
        prefix_cache=True,
        prefill_chunk=args.prefill_chunk,
        policy=make_scheduler_policy("fifo"),
        clock=clock)


def _make_scorer(args, cfg, params):
    if args.scorer == "length":
        return make_scorer("length", target=args.gen)
    if args.scorer == "logprob":
        return make_scorer("logprob", cfg=cfg, params=params)
    # keyword: reward the low-id eighth of the vocab — an arbitrary but
    # deterministic target the DPO rounds can visibly steer toward
    return make_scorer("keyword",
                       keywords=tuple(range(max(cfg.vocab_size // 8, 1))))


def run(args, cfg, params) -> int:
    sampling = _sampling_of(args)
    prompts = _prompts_of(args, cfg)
    n_req = args.prompts * args.n_samples

    rc = 0
    if args.verify:
        # the acceptance bar for rollouts-as-training-data: completions
        # are a pure function of (params, prompt, derived seed) — fleet
        # size, slot count, and lane placement must not show in a token
        eng_a = _make_engine(args, cfg, params, clock=ManualClock())
        ro_a = RolloutEngine(eng_a, n_samples=args.n_samples,
                             gen_len=args.gen, sampling=sampling)
        sig_a = rollout_signature(ro_a.generate(prompts, dt=args.step_time,
                                                turns=args.turns))
        alt = args.slots // 2 if args.slots > 1 else args.slots + 1
        eng_b = _make_engine(args, cfg, params, replicas=1, num_slots=alt,
                             clock=ManualClock())
        ro_b = RolloutEngine(eng_b, n_samples=args.n_samples,
                             gen_len=args.gen, sampling=sampling)
        sig_b = rollout_signature(ro_b.generate(prompts, dt=args.step_time,
                                                turns=args.turns))
        ok = sig_a == sig_b
        print(f"verify rollouts: {args.replicas} replicas x {args.slots} "
              f"slots vs 1 replica x {alt} slots: "
              f"{'bit-identical MATCH' if ok else 'MISMATCH'} "
              f"({len(sig_a)} rollouts)")
        rc |= 0 if ok else 1

    image = ClusterImage.build(f"{cfg.name}-rollout", cfg, SERVE_PLAN,
                               "serve")
    n0 = max(args.nodes, args.replicas)
    cluster = VirtualCluster(n_compute=n0, image=image,
                             policy=_build_policy(args),
                             cooldown_s=args.cooldown)
    print("rollout replicas register to the catalog:\n" + cluster.hostfile)

    engine = _make_engine(args, cfg, params, clock=cluster.clock)
    multi = args.replicas > 1
    plane = engine.describe() if multi else engine.pool.describe()
    print(f"{plane}, sampling={sampling}, scorer={args.scorer}, "
          f"n_samples={args.n_samples}, turns={args.turns}")

    sizes = []  # capacity timeline across serve/train phases

    def on_step(i, snap, c):
        n = len(c.current_view().compute)
        if not sizes or sizes[-1][1] != n:
            sizes.append((c.clock.now(), n))

    ro = RolloutEngine(engine, n_samples=args.n_samples, gen_len=args.gen,
                       sampling=sampling)
    trainer = PreferenceTrainer(
        cfg, params, beta=args.beta,
        opt=AdamWConfig(lr=args.lr, warmup_steps=0,
                        total_steps=max(args.rounds * args.train_steps, 1),
                        weight_decay=0.0))
    loop = RolloutLoop(cluster, ro, _make_scorer(args, cfg, params), trainer,
                       prompts=prompts, dt=args.step_time, turns=args.turns,
                       train_steps=args.train_steps, on_step=on_step)

    t0 = time.time()
    for rnd in range(args.rounds):
        m = loop.round()
        nodes = len(cluster.current_view().compute)
        print(f"  round {rnd}: {m['rollout_tokens']:.0f} rollout tokens, "
              f"reward_mean={m['reward_mean']:.4f}, "
              f"pairs={m['pairs_per_round']:.0f}, "
              f"train_loss={m['train_loss']:.4f}  (nodes={nodes})")
    wall = time.time() - t0

    peak = max((n for _, n in sizes), default=n0)
    final = len(cluster.current_view().compute)
    snap = engine.snapshot()
    print(f"{args.rounds} rounds x {n_req} rollouts in "
          f"{cluster.clock.now():.2f}s sim ({wall:.2f}s wall); "
          f"autoscale start={n0} peak={peak} final={final} "
          f"({len(cluster.scaler.history)} actions)")
    if snap.get("prefix_hit_rate", 0.0) > 0.0:
        print(f"prefix cache: hit rate {snap['prefix_hit_rate']:.2f} "
              f"(multi-turn lineages and {args.n_samples}-way sibling "
              f"fan-out share prompt blocks)")

    # the loop's phase metrics arbitrate capacity through the same
    # registry the serve snapshots use — show what the policy last saw
    ms = cluster.scaler.read_metrics(cluster.registry)
    got = {k: ms.get(k) for k in ("rollout_tokens", "reward_mean",
                                  "pairs_per_round", "train_loss")}
    print(f"autoscaler view: {got}")
    rc |= 0 if all(v is not None for v in got.values()) else 1

    if args.verify:
        h0, hN = loop.history[0], loop.history[-1]
        dec = h0["train_loss"] < h0["train_loss_first"] or \
            hN["train_loss"] < h0["train_loss_first"]
        print(f"verify training: loss {h0['train_loss_first']:.4f} -> "
              f"{hN['train_loss']:.4f} over {args.rounds} rounds: "
              f"{'DECREASING' if dec else 'NOT DECREASING'}")
        rc |= 0 if dec else 1
        improved = hN["reward_mean"] >= h0["reward_mean"]
        print(f"reward_mean {h0['reward_mean']:.4f} -> "
              f"{hN['reward_mean']:.4f} "
              f"({'improved/held' if improved else 'regressed'})")

    loop.retire()
    cluster.shutdown()
    return rc


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="paper-demo")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", default="burst",
                    choices=("burst", "multiturn"),
                    help="burst: every prompt's samples arrive at once; "
                    "multiturn: completions re-enter the queue as "
                    "follow-up turns with grown shared prefixes")
    ap.add_argument("--prompts", type=int, default=4,
                    help="distinct prompts per round")
    ap.add_argument("--n-samples", type=int, default=4,
                    help="sampled completions per prompt (the rollout "
                    "fan-out; seeds derive per (prompt, sample))")
    ap.add_argument("--turns", type=int, default=1,
                    help="conversation turns per lineage (multiturn "
                    "trace forces >= 2)")
    ap.add_argument("--rounds", type=int, default=2,
                    help="generate -> score -> train rounds")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=8,
                    help="completion length per turn")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--routing", default="prefix",
                    choices=("occupancy", "prefix"),
                    help="prefix-affine routing keeps a lineage's turns "
                    "on the replica caching its grown prefix")
    ap.add_argument("--kv", default="paged", choices=("paged", "quant"))
    ap.add_argument("--block-size", type=int, default=4,
                    help="small blocks so short shared prefixes span "
                    "full blocks (prefix-cache hits)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill lane width (required for "
                    "variable-length multi-turn prompts; default: auto)")
    ap.add_argument("--scorer", default="keyword",
                    choices=("keyword", "length", "logprob"))
    ap.add_argument("--temperature", type=float, default=0.7,
                    help="> 0 so a prompt's samples differ (greedy "
                    "rollouts all tie and yield no preference pairs)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--beta", type=float, default=0.5,
                    help="DPO inverse-temperature")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--train-steps", type=int, default=4,
                    help="optimizer steps per round")
    ap.add_argument("--nodes", type=int, default=1)
    ap.add_argument("--max-nodes", type=int, default=6)
    ap.add_argument("--policy", default="queue", choices=("queue", "latency"))
    ap.add_argument("--queue-per-node", type=int, default=2)
    ap.add_argument("--target-p95-ms", type=float, default=400.0)
    ap.add_argument("--step-time", type=float, default=0.05)
    ap.add_argument("--cooldown", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="check bit-reproducibility across fleet shapes "
                    "and that the DPO loss decreases")
    args = ap.parse_args()
    if args.trace == "multiturn":
        args.turns = max(args.turns, 2)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    rng = jax.random.PRNGKey(0)
    params = Mo.init_params(rng, cfg, Env(mesh=None, plan=SERVE_PLAN))
    return run(args, cfg, params)


if __name__ == "__main__":
    raise SystemExit(main())
