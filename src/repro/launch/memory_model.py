"""Analytic per-device HBM model for the roofline memory term + fit check.

Why analytic: the dry run compiles for the CPU backend (the only one in this
container), and XLA:CPU's buffer assignment / "bytes accessed" stats are not
fusion-aware the way XLA:TPU's are — the measured 'bytes accessed' is ~100x
a TPU's true HBM traffic. The compute term (flops) and collective term (HLO
collective operand bytes) DO transfer, so those stay measured; HBM traffic
and residency are modeled explicitly from the config + plan below and are
cross-checked against parameter/cache sizes (tests/test_roofline.py).

All numbers are per device, per step.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as Mo
from repro.models.env import Env, vocab_pad


def _tree_bytes(struct) -> int:
    return sum(math.prod(l.shape) * l.dtype.itemsize
               for l in jax.tree.leaves(struct))


@dataclass(frozen=True)
class MemoryReport:
    traffic_bytes: int  # HBM bytes moved per step (roofline memory term)
    resident_bytes: int  # persistent + peak transient residency
    components: Dict[str, int]

    @property
    def fits_16GB(self) -> bool:
        return self.resident_bytes < 16e9


def analyze_memory(cfg: ModelConfig, shape: ShapeConfig, env: Env,
                   opt_state_bytes_per_param: float = 12.0) -> MemoryReport:
    from repro.launch import steps as S

    p_struct = S.params_struct(cfg, env)
    P_global = _tree_bytes(p_struct)
    tp = max(env.tp, 1)
    dp = max(env.dp, 1)
    n_dev = tp * dp
    fsdp = env.plan.fsdp
    # params are TP-sharded always; FSDP adds the dp axis
    P_dev = P_global / (tp * (dp if fsdp else 1))
    n_params_dev = P_dev / 2  # bf16

    B_loc = max(shape.global_batch // dp, 1)
    S_len = shape.seq_len
    d = cfg.d_model
    vp = vocab_pad(cfg, env)
    L = cfg.n_layers

    comp: Dict[str, int] = {}

    if shape.kind == "train":
        # weights: fwd read + bwd read (+ remat recompute read) of the
        # *gathered* (TP-sharded-only) copy; grads written sharded
        gather_factor = 3.0 if env.plan.remat != "full" else 2.0
        # each device reads the TP-sharded weight copy per pass (under FSDP
        # the gather lands in HBM first: local write+read of the gathered
        # buffer; the ICI transfer itself is counted in the collective term)
        comp["weights_rw"] = int(gather_factor * P_global / tp)
        comp["grads_w"] = int(P_dev)
        comp["opt_rw"] = int(2 * n_params_dev * opt_state_bytes_per_param)
        # saved scan carries (remat nothing): one [B,S,d] per layer, w+r;
        # sequence-parallel carries are tp-sharded
        sp_div = tp if (env.plan.seq_shard_acts and S_len % tp == 0) else 1
        comp["act_saved"] = int(2 * L * B_loc * S_len * d * 2 / sp_div)
        # attention kv stream: per layer, per q-chunk pass over K and V
        hkv = max(cfg.n_kv_heads, 1)
        nq = max(S_len // env.plan.attn_q_chunk, 1)
        n_attn = _n_attn_layers(cfg)
        comp["attn_kv_stream"] = int(
            2 * n_attn * nq * B_loc * S_len * hkv * cfg.head_dim * 2)
        comp["logits"] = int(3 * B_loc * S_len * vp / tp * 2)
        resident = int(P_dev + n_params_dev * opt_state_bytes_per_param
                       + P_dev  # grads
                       + L * B_loc * S_len * d * 2 / sp_div  # saved carries
                       + B_loc * S_len * vp / tp * 4  # logits f32 transient
                       + 2e9)  # workspace
    elif shape.kind == "prefill":
        comp["weights_r"] = int(P_global / tp)
        comp["acts"] = int(2 * L * B_loc * S_len * d * 2)
        cache = _cache_bytes_dev(cfg, shape, env, B_loc)
        comp["cache_w"] = int(cache)
        hkv = max(cfg.n_kv_heads, 1)
        nq = max(S_len // env.plan.attn_q_chunk, 1)
        comp["attn_kv_stream"] = int(
            2 * _n_attn_layers(cfg) * nq * B_loc * S_len * hkv
            * cfg.head_dim * 2)
        comp["logits"] = int(B_loc * 1 * vp / tp * 2)
        resident = int(P_dev + cache + B_loc * S_len * d * 2 * 4 + 1e9)
    else:  # decode
        comp["weights_r"] = int(P_global / tp)
        cache = _cache_bytes_dev(cfg, shape, env, B_loc)
        comp["cache_rw"] = int(cache + 2 * B_loc * 1 * d * 2 * L)
        comp["logits"] = int(B_loc * vp / tp * 2)
        resident = int(P_dev + 2 * cache + 1e9)

    return MemoryReport(traffic_bytes=sum(comp.values()),
                        resident_bytes=resident, components=comp)


def _n_attn_layers(cfg: ModelConfig) -> int:
    full = sum(k in ("attn", "moe", "enc", "dec") for k in cfg.block_pattern)
    n = full * cfg.num_blocks + cfg.encoder_layers
    n += sum(k in ("attn", "moe", "dec") for k in cfg.pattern_tail)
    return max(n, 1)


def _cache_bytes_dev(cfg: ModelConfig, shape: ShapeConfig, env: Env,
                     B_loc: int) -> int:
    struct = jax.eval_shape(
        lambda: Mo.init_cache(cfg, env, shape.global_batch, shape.seq_len))
    total = _tree_bytes(struct)
    dp = max(env.dp, 1)
    tp = max(env.tp, 1)
    per_batch = total / dp
    if env.plan.kv_cache == "seq_sharded":
        # k/v leaves shard their seq dim over tp; states shard width/heads
        return int(per_batch / tp)
    return int(per_batch)
