"""Drafters for speculative decoding on the fused step.

A Drafter proposes up to k next tokens for a decoding request; the target
model verifies all of them in ONE fused step by stacking verify rows onto
the decode batch (scheduler.py) — row j holds draft d_j at depth
cur_len + j, exactly the mechanism chunked prefill already rides. The
target's own per-position outputs o_0..o_m come back in the same [T]
token download; the engine keeps the longest prefix where d_j == o_{j-1}
and emits o_0..o_a.

Why token-match acceptance is bit-exact for sampled rows too: the fused
sample step draws each row from fold_in(PRNGKey(seed), position)
(launch/steps.py make_sample_fn) — the target's token at a position is a
deterministic function of (seed, position, logits), and the verify row's
logits are identical to sequential decode's whenever every earlier draft
matched. The textbook rejection-sampling residual therefore degenerates
to exact token match: the "re-draw from the position's own key" IS the
verify row's output. Greedy rows are the temperature<=0 argmax special
case of the same argument.

Two drafters ship behind the one protocol:

`NgramDrafter` — prompt-lookup self-drafting (no extra model, no extra
KV): match the request's trailing n-gram against its own earlier history
(prompt + generated tokens) and propose the continuation that followed
the most recent earlier occurrence. Free to run, strong on repetitive /
templated traffic (system prompts, code, quoting) — the trace family the
CI floor gates on.

`ModelDrafter` — a tiny qwen2-1.5b-smoke-shaped config (own params from
PRNGKey(0), vocab shared with the target) decoding greedily one token
ahead through its own SlotPool. The target's emitted tokens are fed in
as catch-up before each proposal, so rejected draft KV is overwritten
sequentially and never attended (depth masking) — rollback is implicit.
The draft cache is a separate pool: the target's KV blocks hold
[n_kv_heads, head_dim] rows of the *target* — a different-shaped draft
model cannot literally share them, so "sharing the KVBackend" here means
sharing the backend implementation, not the block pool. Each drafter
step is a T=1 fused step with a host sync — simulation-grade; the CI
perf floors gate the ngram drafter only.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import steps as St
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve.kv import shared_jit
from repro.serve.request import Request

Pytree = Any


class Drafter:
    """Base drafter: propose() is the contract; admit/retire are optional
    lifecycle hooks (stateful drafters keep per-request caches)."""

    name = "none"

    def propose(self, req: Request, k: int) -> List[int]:
        """Up to k draft tokens continuing req's history (prompt + tokens).
        May return fewer, or [] to skip speculation this step."""
        raise NotImplementedError

    def admit(self, req: Request) -> None:
        """The engine admitted req (it may re-admit after a preemption)."""

    def retire(self, rid: int) -> None:
        """req finished or was preempted: drop any per-request state."""

    def describe(self) -> str:
        return self.name


def _history(req: Request) -> List[int]:
    return [int(t) for t in req.prompt] + [int(t) for t in req.tokens]


class NgramDrafter(Drafter):
    """Prompt-lookup decoding: the request's own history is the draft
    model. Match the longest trailing n-gram (n = max_n..1) at its most
    recent earlier occurrence and propose the k tokens that followed it."""

    name = "ngram"

    def __init__(self, *, max_n: int = 3):
        self.max_n = max_n

    def propose(self, req: Request, k: int) -> List[int]:
        hist = _history(req)
        L = len(hist)
        for n in range(min(self.max_n, L - 1), 0, -1):
            suffix = hist[L - n:]
            best: List[int] = []
            for i in range(L - n - 1, -1, -1):  # most recent match first
                if hist[i:i + n] == suffix:
                    # i + n <= L - 1, so at least one continuation token
                    cont = hist[i + n:i + n + k]
                    if len(cont) >= k:
                        return cont
                    if len(cont) > len(best):
                        # matches near the end of history truncate the
                        # continuation (a constant run's most recent match
                        # is its own tail) — keep scanning for one that
                        # can supply all k tokens, fall back to the
                        # longest otherwise
                        best = cont
            if best:
                return best
        return []


@dataclasses.dataclass
class _DraftState:
    slot: int
    committed: int  # history positions whose KV the draft cache holds


def draft_config(target: ModelConfig) -> ModelConfig:
    """The tiny draft config: qwen2-1.5b-smoke shapes with the target's
    vocabulary (draft tokens must be target token ids)."""
    return ModelConfig(
        name=f"draft-of-{target.name}",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=3,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=target.vocab_size,
        head_dim=16,
        qkv_bias=True,
        block_pattern=("attn",),
    )


class ModelDrafter(Drafter):
    """A small greedy draft model with its own SlotPool-backed KV.

    Per request: admit() prefills the prompt into a draft slot; propose()
    first catches the draft KV up with the target-emitted tokens (the
    committed cursor), then chains k greedy T=1 steps feeding its own
    predictions. Draft-phase KV writes past the committed cursor are junk
    the moment the target rejects — the next catch-up overwrites them
    sequentially, and depth-masked attention never looked at them."""

    name = "model"

    def __init__(self, target: ModelConfig, env: Env, *, num_slots: int,
                 prompt_len: int, max_gen: int, spec_k: int):
        from repro.serve.slots import SlotPool
        self.cfg = draft_config(target)
        self.env = env
        self.prompt_len = prompt_len
        # + spec_k headroom: draft-phase writes run past the committed
        # history by up to k-1 positions
        self.pool = SlotPool(self.cfg, env, num_slots=num_slots,
                             prompt_len=prompt_len,
                             max_gen=max_gen + spec_k)
        self.params = Mo.init_params(jax.random.PRNGKey(0), self.cfg, env)
        self._prefill = shared_jit(
            ("prefill", self.cfg, env.plan, env.mesh),
            lambda: St.make_prefill_step(self.cfg, env))
        self._state: Dict[int, _DraftState] = {}
        self._tok_prev = jnp.zeros((1,), jnp.int32)

    def admit(self, req: Request) -> None:
        if req.rid in self._state or not self.pool.can_admit(0):
            return
        slot = self.pool.admit(req.rid, req.eff_gen_len)
        _, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompt)[None]})
        self.pool.insert(slot, req.rid, caches, req.eff_gen_len)
        self._state[req.rid] = _DraftState(slot=slot,
                                           committed=self.prompt_len)

    def retire(self, rid: int) -> None:
        st = self._state.pop(rid, None)
        if st is not None:
            self.pool.evict(st.slot)

    def _step(self, tok: int, pos: int, slot: int) -> int:
        """One greedy T=1 fused step: write tok's KV at pos, return the
        draft model's argmax for pos+1."""
        mi = np.zeros((St.META_I_ROWS, 1), np.int32)
        mi[St.ROW_TOK_SRC, 0] = -1
        mi[St.ROW_FRESH, 0] = tok
        mi[St.ROW_CUR_LEN, 0] = pos
        mf = np.zeros((St.META_F_ROWS, 1), np.float32)
        nxt = self.pool.decode(self.params, self._tok_prev, mi, mf,
                               np.asarray([slot], np.int32), sample=False)
        return int(np.asarray(nxt)[0])

    def propose(self, req: Request, k: int) -> List[int]:
        if req.rid not in self._state:
            self.admit(req)  # lazy (re-)admission after preemption
        st = self._state.get(req.rid)
        if st is None:  # draft pool exhausted: skip speculation
            return []
        hist = _history(req)
        if st.committed >= len(hist):
            return []  # nothing new to ingest (engine never gets here)
        # catch-up: commit the target's emitted tokens into the draft KV;
        # the final step's output is the draft for position len(hist)
        nxt = 0
        for pos in range(st.committed, len(hist)):
            nxt = self._step(hist[pos], pos, st.slot)
        st.committed = len(hist)
        out = [nxt]
        pos = len(hist)
        for _ in range(k - 1):  # draft phase: junk KV past committed
            nxt = self._step(nxt, pos, st.slot)
            out.append(nxt)
            pos += 1
        return out[:k]

    def describe(self) -> str:
        return (f"model ({self.cfg.name}: {self.cfg.n_layers}L "
                f"d{self.cfg.d_model})")


class AdaptiveSpecK:
    """Per-request draft-depth controller (`--spec-k auto`), AIMD over the
    acceptance feedback the verify step already produces: full acceptance
    grows k by 1 (the drafter is tracking the target — speculate deeper),
    under-half acceptance halves it (each rejected draft is a wasted
    verify row AND a wasted drafter call; on low-entropy-free traffic k
    collapses to the floor and speculation costs ~one extra row).

    The verify-row *block* stays `cap` wide — step shapes are pinned — so
    adaptation only changes how many of a slot's candidate rows are live
    (the rest stay masked), never the compiled shape set. New requests
    start at `cap`: optimistic, one bad step away from halving, and on
    the repetitive traces the CI floors gate this is the right prior."""

    def __init__(self, cap: int, floor: int = 1):
        assert cap >= floor >= 1
        self.cap = cap
        self.floor = floor
        self._k: Dict[int, int] = {}

    def k(self, rid: int) -> int:
        return self._k.get(rid, self.cap)

    def update(self, rid: int, proposed: int, accepted: int) -> None:
        """One verify outcome for `rid`: `accepted` of `proposed` drafts
        prefix-matched the target this step."""
        k = self._k.get(rid, self.cap)
        if accepted >= proposed:
            k = min(k + 1, self.cap)
        elif accepted * 2 < proposed:
            k = max(k // 2, self.floor)
        self._k[rid] = k

    def retire(self, rid: int) -> None:
        self._k.pop(rid, None)

    def describe(self) -> str:
        return f"adaptive k (floor {self.floor}, cap {self.cap})"


def make_drafter(kind: Optional[str], cfg: ModelConfig, env: Env, *,
                 num_slots: int, prompt_len: int, max_gen: int,
                 spec_k: int) -> Optional[Drafter]:
    """The one drafter-kind dispatch (mirrors make_kv_backend)."""
    if kind is None or kind == "off":
        return None
    if kind == "ngram":
        return NgramDrafter()
    if kind == "model":
        return ModelDrafter(cfg, env, num_slots=num_slots,
                            prompt_len=prompt_len, max_gen=max_gen,
                            spec_k=spec_k)
    raise ValueError(f"unknown drafter {kind!r} "
                     "(expected 'off', 'ngram' or 'model')")
