"""Requests, the arrival queue, and trace generators.

A Request is one generation job: a fixed-length prompt (the engine jits one
prefill shape — variable prompts are padded by the trace generator), a
per-request generation length, an arrival time on the serving clock, and an
optional latency deadline. The RequestQueue gates admission on arrival time
so a whole trace can be loaded up front and replayed deterministically under
a ManualClock.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [prompt_len] int32
    gen_len: int
    arrival_t: float = 0.0
    deadline_s: float = math.inf  # budget from arrival to completion
    # -- filled in by the engine ------------------------------------------
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    tokens: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.arrival_t

    @property
    def missed_deadline(self) -> bool:
        lat = self.latency_s
        return lat is not None and lat > self.deadline_s


class RequestQueue:
    """Arrival-ordered queue with time-gated admission.

    push() keeps the pending deque sorted by arrival time (traces are
    generated sorted; online pushes append). pop_ready(now) releases the
    next request whose arrival time has passed.
    """

    def __init__(self, requests: Optional[Sequence[Request]] = None):
        self._pending: Deque[Request] = deque(
            sorted(requests or [], key=lambda r: r.arrival_t))

    def push(self, req: Request) -> None:
        if self._pending and req.arrival_t < self._pending[-1].arrival_t:
            items = sorted([*self._pending, req], key=lambda r: r.arrival_t)
            self._pending = deque(items)
        else:
            self._pending.append(req)

    def peek_ready(self, now: float) -> Optional[Request]:
        """Next admissible request without popping it — admission control
        must see gen_len (block reservation) before committing."""
        if self._pending and self._pending[0].arrival_t <= now:
            return self._pending[0]
        return None

    def pop_ready(self, now: float) -> Optional[Request]:
        if self._pending and self._pending[0].arrival_t <= now:
            return self._pending.popleft()
        return None

    def depth(self, now: float) -> int:
        """Requests that have arrived but not been admitted."""
        return sum(1 for r in self._pending if r.arrival_t <= now)

    def __len__(self) -> int:  # total pending, arrived or not
        return len(self._pending)


def poisson_trace(n_requests: int, rate_rps: float, *, prompt_len: int,
                  vocab_size: int, gen_len: int = 16,
                  gen_len_max: Optional[int] = None,
                  deadline_s: float = math.inf,
                  seed: int = 0) -> List[Request]:
    """Poisson arrivals (exponential inter-arrival at `rate_rps`) with random
    prompts and uniform gen lengths in [gen_len, gen_len_max]. Deterministic
    for a given seed."""
    rng = np.random.default_rng(seed)
    gmax = gen_len if gen_len_max is None else gen_len_max
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        out.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab_size, size=(prompt_len,),
                                dtype=np.int32),
            gen_len=int(rng.integers(gen_len, gmax + 1)),
            arrival_t=t,
            deadline_s=deadline_s,
        ))
    return out


def burst_trace(n_requests: int, *, prompt_len: int, vocab_size: int,
                gen_len: int = 16, at: float = 0.0,
                deadline_s: float = math.inf, seed: int = 0) -> List[Request]:
    """All requests arrive at once — the worst-case queue spike the
    autoscaler must absorb."""
    rng = np.random.default_rng(seed)
    return [Request(rid=rid,
                    prompt=rng.integers(0, vocab_size, size=(prompt_len,),
                                        dtype=np.int32),
                    gen_len=gen_len, arrival_t=at, deadline_s=deadline_s)
            for rid in range(n_requests)]
