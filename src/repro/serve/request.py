"""Requests, the arrival queue, and trace generators.

A Request is one generation job: a prompt (chunk-prefill backends accept
any length up to the engine's prompt_len budget; classic one-shot prefill
jits one shape and needs exact-length prompts), a per-request generation
length, an arrival time on the serving clock, an optional latency
deadline, and a SamplingParams contract (serve/sampling.py) that shapes
its token distribution. A retired request can seed the next conversation
turn via follow_up() — the seed-derivation lineage and arrival ordering
survive, which is what makes multi-turn rollouts reproducible. The RequestQueue gates admission on
arrival time so a whole trace can be loaded up front and replayed
deterministically under a ManualClock; a SchedulerPolicy (serve/policy.py)
decides *which* arrived request admits next.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from itertools import takewhile
from typing import Deque, List, Optional, Sequence

import numpy as np

from repro.serve.sampling import SamplingParams, effective_gen_len


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [prompt_len] int32
    gen_len: int
    arrival_t: float = 0.0
    deadline_s: float = math.inf  # budget from arrival to completion
    sampling: SamplingParams = field(default_factory=SamplingParams)
    # -- filled in by the engine ------------------------------------------
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    tokens: List[int] = field(default_factory=list)
    # restart preemptions suffered: a re-admission with restarts > 0 is
    # recomputing prompt positions it already paid for once — the engine
    # books those into recomputed_tokens, not prefill_tokens. A swap-out
    # preemption keeps progress on the host tier and does NOT count.
    restarts: int = 0
    # conversation turn this request represents (0 = the opening prompt;
    # follow_up() children increment it). Part of the seed-derivation
    # lineage: turn t samples with sampling.derive_turn(t)'s seed.
    turn: int = 0

    @property
    def done(self) -> bool:
        return self.t_done is not None

    def follow_up(self, new_tokens: Sequence[int] = (), *, rid: int,
                  gen_len: Optional[int] = None,
                  arrival_t: Optional[float] = None, gap_s: float = 0.0,
                  deadline_s: Optional[float] = None) -> "Request":
        """A retired request's output seeding the next conversation turn.

        The child prompt is this request's full context — prompt, its
        generated tokens, and any `new_tokens` the caller appends (a user
        reply, a tool result) — so every turn of a lineage shares a grown
        prefix the cache dedups. Seed lineage is preserved, not copied:
        the child samples with sampling.derive_turn(turn + 1), a pure
        function of the opening request's params, so multi-turn rollouts
        replay bit-identically. Arrival ordering is preserved too —
        the child arrives at this request's completion time (plus an
        optional think-time gap) unless the caller pins `arrival_t`.
        """
        if not self.done:
            raise ValueError(f"request {self.rid} is still in flight; "
                             f"follow_up needs its completed output")
        prompt = np.concatenate([
            np.asarray(self.prompt, np.int32),
            np.asarray(self.tokens, np.int32),
            np.asarray(list(new_tokens), np.int32),
        ]) if (self.tokens or len(new_tokens)) else np.asarray(
            self.prompt, np.int32)
        at = (self.t_done + gap_s) if arrival_t is None else arrival_t
        return Request(
            rid=rid,
            prompt=prompt,
            gen_len=self.gen_len if gen_len is None else gen_len,
            arrival_t=at,
            deadline_s=self.deadline_s if deadline_s is None else deadline_s,
            sampling=self.sampling.derive_turn(self.turn + 1),
            turn=self.turn + 1,
        )

    @property
    def eff_gen_len(self) -> int:
        """gen_len capped by the sampling contract's max_tokens — what the
        engine admits and reserves for. Derived, never written back:
        submit() must not mutate caller state (re-submitting the same
        Request objects, e.g. the CLI --verify re-serve, must see the
        declared gen_len unchanged)."""
        return effective_gen_len(self.gen_len, self.sampling)

    @property
    def abs_deadline(self) -> float:
        """Completion deadline on the serving clock (EDF sorts by this)."""
        return self.arrival_t + self.deadline_s

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.arrival_t

    @property
    def missed_deadline(self) -> bool:
        lat = self.latency_s
        return lat is not None and lat > self.deadline_s


class RequestQueue:
    """Arrival-sorted queue with time-gated admission.

    push() keeps the deque sorted by arrival time: the common case (traces
    and re-pushes arriving in order) is an O(1) append; an out-of-order
    online push — a late injector, a preempted request re-queued with its
    original arrival time — inserts at its sorted position (ties keep push
    order), so it can never hide an already-due request behind a future
    one. pop_ready(now)/ready(now) release only requests whose arrival
    time has passed, from the front in O(1).
    """

    def __init__(self, requests: Optional[Sequence[Request]] = None):
        self._pending: Deque[Request] = deque(
            sorted(requests or [], key=lambda r: r.arrival_t))

    def push(self, req: Request) -> None:
        dq = self._pending
        if not dq or req.arrival_t >= dq[-1].arrival_t:
            dq.append(req)
            return
        # out-of-order: scan from the tail (the insertion point is near it
        # for slightly-late arrivals; preempted re-pushes pay O(depth))
        idx = len(dq) - 1
        while idx > 0 and dq[idx - 1].arrival_t > req.arrival_t:
            idx -= 1
        dq.insert(idx, req)

    def ready(self, now: float) -> List[Request]:
        """All arrived-but-unadmitted requests, in arrival order — the
        candidate set a SchedulerPolicy picks from."""
        return list(takewhile(lambda r: r.arrival_t <= now, self._pending))

    def remove(self, req: Request) -> None:
        """Commit an admission the policy selected out of ready()."""
        self._pending.remove(req)

    def peek_ready(self, now: float) -> Optional[Request]:
        """Next admissible request in arrival order without popping it —
        admission control must see gen_len (block reservation) before
        committing."""
        if self._pending and self._pending[0].arrival_t <= now:
            return self._pending[0]
        return None

    def pop_ready(self, now: float) -> Optional[Request]:
        if self._pending and self._pending[0].arrival_t <= now:
            return self._pending.popleft()
        return None

    def depth(self, now: float) -> int:
        """Requests that have arrived but not been admitted."""
        return sum(1 for _ in takewhile(lambda r: r.arrival_t <= now,
                                        self._pending))

    def __len__(self) -> int:  # total pending, arrived or not
        return len(self._pending)


def _poisson_requests(n_requests: int, rate_rps: float, prompt_fn, rng, *,
                      gen_len: int, gen_len_max: Optional[int],
                      deadline_s: float,
                      sampling: Optional[SamplingParams]) -> List[Request]:
    """Shared Poisson-arrival loop: exponential inter-arrivals, per-rid
    decorrelated sampling seeds, uniform gen lengths. `prompt_fn(rid)`
    builds each prompt (it draws from `rng` between the arrival and the
    gen-length draw, so every trace family keeps a stable stream for a
    given seed)."""
    gmax = gen_len if gen_len_max is None else gen_len_max
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        sp = SamplingParams() if sampling is None else sampling.derive(rid)
        out.append(Request(
            rid=rid,
            prompt=prompt_fn(rid),
            gen_len=int(rng.integers(gen_len, gmax + 1)),
            arrival_t=t,
            deadline_s=deadline_s,
            sampling=sp,
        ))
    return out


def poisson_trace(n_requests: int, rate_rps: float, *, prompt_len: int,
                  vocab_size: int, gen_len: int = 16,
                  gen_len_max: Optional[int] = None,
                  deadline_s: float = math.inf,
                  sampling: Optional[SamplingParams] = None,
                  seed: int = 0) -> List[Request]:
    """Poisson arrivals (exponential inter-arrival at `rate_rps`) with random
    prompts and uniform gen lengths in [gen_len, gen_len_max]. Deterministic
    for a given seed. `sampling` applies to every request (per-request PRNG
    seeds are derived as sampling.seed + rid so requests don't correlate)."""
    rng = np.random.default_rng(seed)
    prompt_fn = lambda rid: rng.integers(0, vocab_size, size=(prompt_len,),
                                         dtype=np.int32)
    return _poisson_requests(n_requests, rate_rps, prompt_fn, rng,
                             gen_len=gen_len, gen_len_max=gen_len_max,
                             deadline_s=deadline_s, sampling=sampling)


def sysprompt_trace(n_requests: int, rate_rps: float, *, prompt_len: int,
                    vocab_size: int, prefix_len: int, gen_len: int = 16,
                    gen_len_max: Optional[int] = None, n_prefixes: int = 1,
                    deadline_s: float = math.inf,
                    sampling: Optional[SamplingParams] = None,
                    seed: int = 0) -> List[Request]:
    """Poisson arrivals whose prompts share system-prompt prefixes: each
    prompt is one of `n_prefixes` fixed templates of `prefix_len` tokens
    followed by a random per-request suffix — the multi-tenant traffic
    shape prefix caching dedups. Deterministic for a given seed (the CLI
    --verify path regenerates it for a second engine)."""
    if not 0 < prefix_len < prompt_len:
        raise ValueError(f"prefix_len must be in (0, prompt_len), got "
                         f"{prefix_len} vs prompt_len {prompt_len}")
    rng = np.random.default_rng(seed)
    prefixes = rng.integers(0, vocab_size, size=(n_prefixes, prefix_len),
                            dtype=np.int32)

    def prompt_fn(rid):
        suffix = rng.integers(0, vocab_size, size=(prompt_len - prefix_len,),
                              dtype=np.int32)
        return np.concatenate([prefixes[rid % n_prefixes], suffix])

    return _poisson_requests(n_requests, rate_rps, prompt_fn, rng,
                             gen_len=gen_len, gen_len_max=gen_len_max,
                             deadline_s=deadline_s, sampling=sampling)


def repetitive_trace(n_requests: int, rate_rps: float, *, prompt_len: int,
                     vocab_size: int, gen_len: int = 16,
                     gen_len_max: Optional[int] = None, motif_len: int = 1,
                     deadline_s: float = math.inf,
                     sampling: Optional[SamplingParams] = None,
                     seed: int = 0) -> List[Request]:
    """Poisson arrivals whose prompts are a short random motif tiled to
    `prompt_len` — templated/boilerplate traffic (form letters, log lines,
    code scaffolding) whose continuations are themselves highly repetitive.
    This is the trace family prompt-lookup speculative decoding is built
    for: the generated stream keeps revisiting n-grams already in the
    request's own history, so NgramDrafter proposals land. Deterministic
    for a given seed (the CLI --verify path regenerates it)."""
    if not 0 < motif_len <= prompt_len:
        raise ValueError(f"motif_len must be in (0, prompt_len], got "
                         f"{motif_len} vs prompt_len {prompt_len}")
    rng = np.random.default_rng(seed)

    def prompt_fn(rid):
        motif = rng.integers(0, vocab_size, size=(motif_len,),
                             dtype=np.int32)
        reps = -(-prompt_len // motif_len)  # ceil
        return np.tile(motif, reps)[:prompt_len]

    return _poisson_requests(n_requests, rate_rps, prompt_fn, rng,
                             gen_len=gen_len, gen_len_max=gen_len_max,
                             deadline_s=deadline_s, sampling=sampling)


def burst_trace(n_requests: int, *, prompt_len: int, vocab_size: int,
                gen_len: int = 16, at: float = 0.0,
                deadline_s: float = math.inf,
                sampling: Optional[SamplingParams] = None,
                seed: int = 0) -> List[Request]:
    """All requests arrive at once — the worst-case queue spike the
    autoscaler must absorb."""
    rng = np.random.default_rng(seed)
    sp = lambda rid: (SamplingParams() if sampling is None
                      else sampling.derive(rid))
    return [Request(rid=rid,
                    prompt=rng.integers(0, vocab_size, size=(prompt_len,),
                                        dtype=np.int32),
                    gen_len=gen_len, arrival_t=at, deadline_s=deadline_s,
                    sampling=sp(rid))
            for rid in range(n_requests)]
