"""KVBackend — the one KV-cache contract the serving plane talks to.

PR-2 left the engine, the CLI, and the metrics path branching on
`kv == "slot" | "paged"` in a dozen places; every planned feature (prefix
sharing, swap-out preemption, multi-replica pools) would have multiplied
those branches. v2 collapses them behind this protocol: a backend owns its
device cache pytree, its bookkeeping, *and its fused decode step* —
`ServingEngine` schedules rows and never learns what a block table is.

Lifecycle of one request through a backend:

    can_admit(gen_len, prompt=…)  reservation check (admission-time
                            backpressure; prompt makes it prefix-aware)
    admit(rid, gen_len, prompt=…) bind a slot + reserve worst-case
                            capacity; a prompt whose prefix the backend
                            already caches admits with shared blocks
    cached_prefix_len(slot) prompt positions admit() served from its
                            prefix cache — the engine starts prefill
                            lanes there (0 on cache-less backends)
    insert(slot, …)         classic path: scatter a batch-1 prefill cache
      — or —
    ensure(slot, pos)       chunked path: grow capacity to cover position
                            (first write into a shared block = copy-on-write)
    finish_prefill(slot)    chunked path: the slot joins the decode batch
                            (paged: registers full prompt blocks for reuse)
    decode(params, …)       one fused step over the whole row set
    advance(slot)           host bookkeeping per emitted token
    finished(slot)          declared gen budget consumed?
    evict(slot)             return capacity (double-free is an error;
                            refcounted backends drop one reference)

`metrics()` returns the backend-specific load signals to merge into the
engine snapshot (e.g. kv_block_occupancy) — the metrics path stops caring
which cache kind produced them, and `describe()` is the one-line banner
the CLI prints. SlotPool (serve/slots.py) and BlockManager
(serve/blocks.py) are the two implementations; make_kv_backend is the
only place a cache-kind string is interpreted.
"""
from __future__ import annotations

from typing import (Any, Callable, Dict, Hashable, List, Optional, Protocol,
                    Tuple, runtime_checkable)

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.env import Env

Pytree = Any

# Backend step/insert/prefill functions are pure closures over (cfg, plan,
# mesh): two replicas built from the same config share compilations. Keyed
# on the frozen config dataclasses themselves, so a distinct config can
# never collide; a non-hashable key (exotic mesh) falls back to a private
# jit. Donation is per-call, so sharing the callable is safe.
_JIT_CACHE: Dict[Tuple, Any] = {}


def shared_jit(key: Tuple[Hashable, ...], builder: Callable[[], Callable],
               **jit_kw):
    """jax.jit(builder()) memoized on `key` — the multi-replica data plane
    builds N backends per fleet, and without this each replica re-traces
    identical step functions."""
    try:
        hash(key)
    except TypeError:  # pragma: no cover - unhashable config/mesh
        return jax.jit(builder(), **jit_kw)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(builder(), **jit_kw)
        _JIT_CACHE[key] = fn
    return fn


@runtime_checkable
class KVBackend(Protocol):
    kind: str                 # registry name ("slot", "paged", ...)
    num_slots: int
    caches: Pytree            # the device cache pytree the backend owns
    chunk_prefill_ok: bool    # can prompts stream through decode lane rows?

    # -- admission / reservation -------------------------------------------
    def can_admit(self, gen_len: int, *, prompt=None) -> bool: ...
    def preempt_frees(self, slot: int, gen_len: int, *,
                      prompt=None) -> bool:
        """Would evicting `slot` make can_admit(gen_len, prompt=...) true?
        The engine asks before acting on a preemption verdict — an
        eviction that cannot make room would cost the victim its progress
        for nothing."""
        ...
    def admit(self, rid: int, gen_len: int, *, prefilling: bool = False,
              prompt=None) -> int: ...
    def cached_prefix_len(self, slot: int) -> int:
        """Prompt positions admit() served from a prefix cache (0 when the
        backend has none) — the engine's lanes start at this position."""
        ...
    def probe_prefix(self, prompt) -> int:
        """Prompt positions an admission *would* serve from this backend's
        prefix cache right now (0 on cache-less backends). Read-only — the
        router's prefix-affine policy probes every replica with it before
        choosing one."""
        ...
    def release(self) -> None:
        """Retire the backend (replica scale-down): verify the free-list
        accounting returns to empty — every block/slot back, no dangling
        reservations; leaks raise — then drop the device cache pytree."""
        ...
    def insert(self, slot: int, rid: int, prefill_caches: Pytree,
               gen_len: int) -> None: ...
    def ensure(self, slot: int, pos: int) -> None: ...
    def finish_prefill(self, slot: int) -> Any: ...
    def truncate(self, slot: int, n: int) -> None:
        """Roll the slot's committed KV back to its first `n` positions —
        the speculative-rejection path. Capacity committed past position
        n-1 returns to the pool (paged: whole blocks freed back to the
        free list, reservation re-credited); reservation-style backends
        (SlotPool) need no device work — junk past the write cursor is
        never attended and is overwritten sequentially. `n` is never below
        the prompt length (verify rows only ever extend generated
        positions), so shared prefix blocks are never in range."""
        ...

    # -- the fused step ----------------------------------------------------
    def decode(self, params: Pytree, prev_tok, meta_i: np.ndarray,
               meta_f: np.ndarray, row_slots: np.ndarray, *,
               sample: bool):
        """Run one fused decode step over T rows. meta_i/meta_f are the
        packed [META_I_ROWS,T] / [META_F_ROWS,T] arrays (launch/steps.py);
        row_slots[t] names the slot whose KV row t addresses (-1: masked).
        Returns the [T] int32 device token vector; the backend swaps its
        own (donated) cache pytree."""
        ...

    # -- per-token bookkeeping / retirement --------------------------------
    def advance(self, slot: int) -> Any: ...
    def finished(self, slot: int) -> bool: ...
    def evict(self, slot: int, *, zero: bool = False) -> None: ...

    # -- host swap tier (optional; no-ops on backends without one) ---------
    def swap_out(self, slot: int) -> bool:
        """Copy the slot's live KV to a host pool and evict it. False means
        the backend cannot swap (no pool / budget full / mid-prefill) and
        the caller should restart-preempt instead."""
        ...
    def has_swapped(self, rid: int) -> bool: ...
    def can_resume(self, rid: int) -> bool: ...
    def plan_resume(self, rid: int) -> bool:
        """Take (or confirm) a standing reservation for `rid`'s swap-in
        footprint so fresh admissions queue behind the victim instead of
        starving it. Idempotent; at most one backend fleet-wide holds the
        plan; swap_in consumes it. False on backends without a swap
        tier."""
        ...
    def cancel_resume_plans(self) -> None:
        """Release every standing resume reservation (drain/release: the
        swapped records stay in the shared pool for a live peer)."""
        ...
    def swap_in(self, rid: int) -> int:
        """Restore a swapped request into a fresh slot (inverse of
        swap_out); decoding resumes from the swap point bit-identically."""
        ...
    def drop_swapped(self, rid: int) -> None: ...

    # -- introspection ------------------------------------------------------
    def info(self, slot: int) -> Any: ...
    def rid_of(self, slot: int) -> int: ...
    def active_slots(self) -> List[int]: ...
    def occupied_slots(self) -> List[int]: ...
    @property
    def free_slot_count(self) -> int: ...
    @property
    def occupancy(self) -> float: ...
    @property
    def free_capacity(self) -> int:
        """Admission capacity still available, in the backend's own units
        (paged: unreserved blocks; slot: free slots). Absolute, not a
        fraction — the router's load key uses it to break occupancy ties
        across heterogeneous pool sizes."""
        ...
    def metrics(self) -> Dict[str, float]: ...
    def describe(self) -> str: ...


def make_kv_backend(kind: str, cfg: ModelConfig, env: Env, *, num_slots: int,
                    prompt_len: int, max_gen: int, block_size: int = 16,
                    kv_blocks: Optional[int] = None,
                    prefix_cache: bool = True,
                    max_shared_fraction: float = 1.0,
                    swap: bool = False,
                    swap_budget_blocks: Optional[int] = None,
                    swap_pool=None) -> KVBackend:
    """The one cache-kind dispatch in the serving plane.

    swap=True attaches a host swap tier (serve/blocks.py HostSwapPool):
    pass a prebuilt `swap_pool` to share one across a fleet's replicas
    (ReplicaSet does), else a private pool is created with
    `swap_budget_blocks` capacity (None = unbounded)."""
    from repro.serve.blocks import (BlockManager, HostSwapPool,
                                    QuantBlockManager)
    from repro.serve.slots import SlotPool

    if swap and swap_pool is None:
        swap_pool = HostSwapPool(swap_budget_blocks)
    elif not swap:
        swap_pool = None
    if kind in ("paged", "quant"):
        cls = QuantBlockManager if kind == "quant" else BlockManager
        return cls(cfg, env, num_slots=num_slots,
                   prompt_len=prompt_len, max_gen=max_gen,
                   block_size=block_size, num_blocks=kv_blocks,
                   prefix_cache=prefix_cache,
                   max_shared_fraction=max_shared_fraction,
                   swap_pool=swap_pool)
    if kind == "slot":
        return SlotPool(cfg, env, num_slots=num_slots, prompt_len=prompt_len,
                        max_gen=max_gen)
    raise ValueError(f"unknown KV backend {kind!r} "
                     "(expected 'paged', 'quant', or 'slot')")
