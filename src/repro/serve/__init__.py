"""Continuous-batching serving subsystem (API v2).

Request (with a SamplingParams contract) -> RequestQueue -> ServingEngine
(SchedulerPolicy picks admission order + preemption verdicts; a KVBackend
— paged BlockManager by default, SlotPool baseline — owns the cache and
the fused decode/sample step) -> ServingMetrics -> registry KV ->
AutoScaler policies -> cluster size.

See docs/serving.md for the full loop, the one-command demo, and the
migration table from the PR-2 surface.
"""
from repro.serve.blocks import (  # noqa: F401
    BlockManager,
    HostSwapPool,
    QuantBlockManager,
)
from repro.serve.kv import KVBackend, make_kv_backend  # noqa: F401
from repro.serve.metrics import ServingMetrics, percentile  # noqa: F401
from repro.serve.policy import (  # noqa: F401
    EDFPolicy,
    FIFOPolicy,
    SchedulerPolicy,
    make_scheduler_policy,
)
from repro.serve.request import (  # noqa: F401
    Request,
    RequestQueue,
    burst_trace,
    poisson_trace,
    repetitive_trace,
    sysprompt_trace,
)
from repro.serve.router import (  # noqa: F401
    LeastOccupancyRouting,
    PrefixAffineRouting,
    ReplicaSet,
    RoutingPolicy,
    make_routing_policy,
    make_serving_engine,
)
from repro.serve.sampling import GREEDY, SamplingParams  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    SERVE_PLAN,
    ReplicaEngine,
    ServingEngine,
    run_to_completion,
)
from repro.serve.slots import SlotPool  # noqa: F401
from repro.serve.spec import (  # noqa: F401
    AdaptiveSpecK,
    Drafter,
    ModelDrafter,
    NgramDrafter,
    make_drafter,
)
