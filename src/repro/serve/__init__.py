"""Continuous-batching serving subsystem.

request -> RequestQueue -> ServingEngine (paged BlockManager KV + fused
decode step with piggybacked prefill lanes; SlotPool kept as baseline)
-> ServingMetrics -> registry KV -> AutoScaler policies -> cluster size.

See docs/serving.md for the full loop and the one-command demo.
"""
from repro.serve.metrics import ServingMetrics, percentile  # noqa: F401
from repro.serve.request import (  # noqa: F401
    Request,
    RequestQueue,
    burst_trace,
    poisson_trace,
)
from repro.serve.blocks import BlockManager  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    SERVE_PLAN,
    ServingEngine,
    run_to_completion,
)
from repro.serve.slots import SlotPool  # noqa: F401
