"""SamplingParams — the per-request generation contract.

Every Request carries one: temperature / top_k / top_p / seed shape the
token distribution, stop_tokens and max_tokens bound the generation, and
the seed makes sampled output *deterministic and lane-placement-invariant*:
the decode step derives each row's PRNG key as

    jax.random.fold_in(jax.random.PRNGKey(seed), position)

where position is the request-logical token index (0 for the first
generated token), never the batch row — so a request emits bit-identical
tokens whether it decodes alone, inside a busy mixed-depth batch, or after
a preemption restart. temperature=0 (the default) lowers to the existing
fused argmax, which is what keeps the greedy token-exactness baselines
meaningful.

The device-side sampler lives in launch/steps.py (make_sample_fn); the
fused top-k/top-p mask is kernels/sampling (Pallas on TPU, the same math
via XLA elsewhere). This module is the host-side surface: the params
dataclass and the packed per-row metadata layout the engine uploads once
per step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet, Optional, Tuple

# The packed per-row step metadata layout ([META_I_ROWS,T] int32 +
# [META_F_ROWS,T] float32, one upload per decode step) is the device-side
# contract and lives in launch/steps.py (ROW_* constants there).


@dataclass(frozen=True)
class SamplingParams:
    """How one request turns logits into tokens.

    temperature <= 0 means greedy argmax (top_k/top_p/seed are ignored on
    that path, so the default params reproduce the pre-v2 engine exactly).
    """
    temperature: float = 0.0
    top_k: int = 0           # keep the k highest logits (<=0: disabled)
    top_p: float = 1.0       # keep the smallest prob mass >= top_p (>=1: off)
    seed: int = 0            # per-request PRNG root (fold_in'd per position)
    stop_tokens: Tuple[int, ...] = ()  # emitting any of these ends the request
    max_tokens: Optional[int] = None   # caps the request's gen_len

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.max_tokens is not None and self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if not (-2**31 <= self.seed < 2**31):
            # the seed rides the int32 step-metadata row; reject here
            # instead of overflowing mid-serve with requests in flight
            raise ValueError(f"seed must fit int32, got {self.seed}")
        # stop_set is consulted once per emitted token in the serving hot
        # loop — build it once (frozen dataclass, so through __setattr__)
        object.__setattr__(self, "_stop_set", frozenset(self.stop_tokens))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def derive(self, rid: int) -> "SamplingParams":
        """Per-request copy with a decorrelated seed (trace generators
        apply one SamplingParams to many requests). Wraps into int32 so a
        base seed near the boundary cannot push a derived request past the
        metadata row's dtype."""
        from dataclasses import replace
        return replace(self, seed=(self.seed + rid) % 2**31)

    def derive_turn(self, turn: int) -> "SamplingParams":
        """Follow-up copy for turn `turn` of a multi-turn lineage. The
        multiplicative mix keeps turn lineages disjoint from the additive
        rid derivation: turn t of rid r never collides with rid r+t of
        turn 0, so a rollout's completions stay decorrelated across both
        axes. Deterministic — the lineage's seeds are a pure function of
        (base seed, rid, turn), which is what makes multi-turn rollouts
        bit-reproducible regardless of placement."""
        from dataclasses import replace
        return replace(self, seed=(self.seed * 1_000_003 + turn) % 2**31)

    @property
    def stop_set(self) -> FrozenSet[int]:
        return self._stop_set


GREEDY = SamplingParams()


def effective_gen_len(gen_len: int, params: SamplingParams) -> int:
    """The token budget admission reserves for: the request's declared
    gen_len capped by its sampling contract's max_tokens."""
    if params.max_tokens is None:
        return gen_len
    return min(gen_len, params.max_tokens)
