"""Router + ReplicaSet — the multi-replica serving data plane.

Before this module, "scale to 4 replicas" changed a simulated step time
while one monolithic engine kept serving every token through one KV pool.
Here the data plane is actually sharded, the way the paper's
service-discovery-driven worker fleet is: a Router front-end owns the
global RequestQueue and admits each arrived request to one of N
`ReplicaEngine`s (serve/scheduler.py), each with its *own* KVBackend —
own block pool, own prefix cache — stepped round-robin on the shared sim
clock (every live replica takes one fused decode step per tick, which is
what data parallelism means here: N replicas decode N batches in the wall
time of one).

WHERE a request lands is a pluggable `RoutingPolicy`, orthogonal to the
`SchedulerPolicy` that decides WHICH arrived request admits next:

  LeastOccupancyRouting  route to the replica with the least committed KV
                         (kv_block_occupancy; slot occupancy elsewhere),
                         in-flight count breaking ties — the classic
                         load-balancer, blind to cache state.
  PrefixAffineRouting    probe every replica's prefix cache with the
                         prompt's blake2b hash chain (serve/blocks.py) and
                         route to the longest cached prefix; fall back to
                         least-occupancy on a universal miss. Per-replica
                         prefix caches only pay off if the same template
                         keeps landing on the same replica — this is the
                         policy that makes them pay.

Scaling is a real lifecycle, not a number: `reconcile(n)` follows the
autoscaler's applied ScalePlans (VirtualCluster.serve calls it with the
live compute-node count each tick). Scale-up first un-drains any replica
still draining (its cache is warm — cheapest capacity there is), then
instantiates fresh replicas (cold cache, counted in `replica_warmups`:
they will miss until their prefix cache refills, the cold-cache warmup
tax the fleet metrics make visible). Scale-down puts replicas in **drain**
mode: no new admissions; running requests either finish (drain_mode
"finish") or are restart-preempted back to the router queue (drain_mode
"preempt" — safe because sampling is position-keyed, the re-served
request regenerates bit-identical tokens); once idle, the replica's pool
is released with leak checking (every block back on the free list or the
release raises) and its metric keys are tombstoned out of the registry.

Fleet metrics: each replica keeps its own ServingMetrics; `snapshot()`
rolls them up (sums for throughput/counters, means for occupancies, true
fleet percentiles over the union of the replicas' latency windows) and
`metric_sources()` exposes the per-replica snapshots plus a "router"
source (queue depth, live count, warmups) for per-source registry
publication — AutoScaler.read_metrics aggregates across sources exactly
as it does across nodes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Protocol, Sequence, runtime_checkable

from repro.core.clock import Clock, ManualClock
from repro.serve.metrics import percentile
from repro.serve.policy import FIFOPolicy, SchedulerPolicy
from repro.serve.request import Request, RequestQueue
from repro.serve.scheduler import (ReplicaEngine, ServingEngine,
                                   validate_requests)


@runtime_checkable
class RoutingPolicy(Protocol):
    name: str

    def route(self, replicas: Sequence[ReplicaEngine], req: Request,
              now: float) -> Optional[ReplicaEngine]:
        """Pick the replica `req` admits to, or None for fleet-wide
        backpressure. Only replicas that can accept the request right now
        may be returned (candidates are pre-checked via can_accept, which
        is admission-accurate because admissions commit immediately)."""
        ...


def _least_loaded(cands: Sequence[ReplicaEngine]) -> ReplicaEngine:
    """Deterministic least-occupancy pick: committed-KV, then in-flight
    count, then fleet position (stable under equal load)."""
    return min(enumerate(cands), key=lambda t: (t[1].load_score(), t[0]))[1]


@dataclass
class LeastOccupancyRouting:
    """Route by committed KV / queue depth — cache-blind load balancing."""
    name: str = "occupancy"

    def route(self, replicas, req, now):
        cands = [r for r in replicas if r.can_accept(req)]
        return _least_loaded(cands) if cands else None


@dataclass
class PrefixAffineRouting:
    """Route to the replica whose prefix cache already holds the longest
    prefix of the prompt (the blake2b chain probe is read-only); fall back
    to least-occupancy when nobody has it. Ties keep the earliest replica
    so a template stays pinned to one cache instead of smearing across
    the fleet."""
    name: str = "prefix"

    def route(self, replicas, req, now):
        cands = [r for r in replicas if r.can_accept(req)]
        if not cands:
            return None
        best, best_len = None, 0
        for r in cands:
            cached = r.pool.probe_prefix(r.prompt_arg(req))
            if cached > best_len:
                best, best_len = r, cached
        return best if best is not None else _least_loaded(cands)


def make_routing_policy(name: str, **kwargs) -> RoutingPolicy:
    """CLI/config-facing registry (launch/serve.py --routing)."""
    if name == "occupancy":
        return LeastOccupancyRouting(**kwargs)
    if name == "prefix":
        return PrefixAffineRouting(**kwargs)
    raise ValueError(f"unknown routing policy {name!r} "
                     "(expected 'occupancy' or 'prefix')")


@dataclass
class _RetiredCounters:
    """Cumulative counters of released replicas — fleet totals must stay
    monotonic across drains (LatencyPolicy's miss-delta logic depends on
    deadline_misses never rewinding)."""
    deadline_misses: float = 0.0
    preemptions: float = 0.0
    prefill_tokens: float = 0.0
    recomputed_tokens: float = 0.0
    swapped_blocks: float = 0.0
    swap_out_bytes: float = 0.0
    swap_in_bytes: float = 0.0
    completed: int = 0
    prefix_hit_tokens: int = 0
    prefix_lookup_tokens: int = 0
    spec_steps: int = 0
    spec_proposed: int = 0
    spec_accepted: int = 0
    spec_emitted: int = 0

    def absorb(self, replica) -> None:
        m = replica.metrics
        self.deadline_misses += m.deadline_misses
        self.preemptions += m.preemptions
        self.prefill_tokens += m.prefill_tokens
        self.recomputed_tokens += m.recomputed_tokens
        pm = replica.pool.metrics()  # absorb runs before release()
        self.swapped_blocks += pm.get("swapped_blocks", 0.0)
        self.swap_out_bytes += pm.get("swap_out_bytes", 0.0)
        self.swap_in_bytes += pm.get("swap_in_bytes", 0.0)
        self.completed += m.completed
        self.prefix_hit_tokens += getattr(replica.pool,
                                          "prefix_hit_tokens", 0)
        self.prefix_lookup_tokens += getattr(replica.pool,
                                             "prefix_lookup_tokens", 0)
        self.spec_steps += m.spec_steps
        self.spec_proposed += m.spec_proposed
        self.spec_accepted += m.spec_accepted
        self.spec_emitted += m.spec_emitted


class ReplicaSet:
    """The Router + N ReplicaEngines, drivable anywhere a ServingEngine is
    (submit / step / drained / results / snapshot share the surface):
    run_to_completion loops it standalone; VirtualCluster.serve drives it
    with autoscaling and calls reconcile() so the fleet follows the
    cluster's compute-node count."""

    def __init__(self, cfg, params, *, replicas: int = 2,
                 routing="occupancy",
                 policy: Optional[SchedulerPolicy] = None,
                 drain_mode: str = "finish",
                 clock: Optional[Clock] = None,
                 metrics_window_s: float = 10.0,
                 **replica_kw):
        """`replica_kw` is forwarded to every ReplicaEngine (num_slots,
        prompt_len, max_gen, kv, block_size, kv_blocks, prefix_cache,
        max_shared_fraction, prefill_chunk, spec, spec_k, swap,
        swap_budget_blocks, plan, mesh) — each replica builds its own
        drafter, but swap=True builds ONE HostSwapPool shared fleet-wide
        — and kv_blocks is PER REPLICA: a fleet at an equal total KV
        budget to a single engine passes total/N here."""
        if replicas < 1:
            raise ValueError(f"need at least one replica, got {replicas}")
        if drain_mode not in ("finish", "preempt"):
            raise ValueError(f"unknown drain_mode {drain_mode!r} "
                             "(expected 'finish' or 'preempt')")
        self.cfg = cfg
        self.params = params
        self.clock = clock or ManualClock()
        self.queue = RequestQueue()
        self.policy: SchedulerPolicy = policy or FIFOPolicy()
        self.routing: RoutingPolicy = (make_routing_policy(routing)
                                       if isinstance(routing, str)
                                       else routing)
        self.drain_mode = drain_mode
        self._replica_kw = dict(replica_kw)
        if self._replica_kw.get("swap") and \
                self._replica_kw.get("swap_pool") is None:
            # ONE host pool for the whole fleet (host RAM is node-local):
            # a request swap-preempted off a draining replica must be
            # restorable by whichever replica the router re-routes it to
            from repro.serve.blocks import HostSwapPool
            self._replica_kw["swap_pool"] = HostSwapPool(
                self._replica_kw.get("swap_budget_blocks"))
        self._window_s = metrics_window_s
        self._next_id = 0
        self.replicas: List[ReplicaEngine] = []
        self.released: List[str] = []  # names, in release order
        self._retired = _RetiredCounters()
        self._retired_sources: List[str] = []  # pending tombstones
        self._results: Dict[int, List[int]] = {}  # archived at release
        self.replica_warmups = 0  # cold spawns after construction
        # host-side gauges merged into snapshot() and the router's metric
        # source (a rollout loop publishes its phase metrics here)
        self.extra_metrics: Dict[str, float] = {}
        for _ in range(replicas):
            self._spawn()
        first = self.replicas[0]
        self.prompt_len = first.prompt_len
        self.max_gen = first.max_gen
        self.prefill_chunk = first.prefill_chunk
        self.kv = first.kv

    # -- lifecycle -----------------------------------------------------------
    def _spawn(self) -> ReplicaEngine:
        r = ReplicaEngine(self.cfg, self.params,
                          name=f"replica-{self._next_id}",
                          clock=self.clock,
                          metrics_window_s=self._window_s,
                          **self._replica_kw)
        self._next_id += 1
        self.replicas.append(r)
        return r

    def live_replicas(self) -> List[ReplicaEngine]:
        return [r for r in self.replicas if not r.draining]

    def reconcile(self, n: int) -> None:
        """Make the fleet track `n` live replicas — the autoscaler's
        applied ScalePlan becomes real lifecycle events. Scale-up:
        un-drain still-draining replicas first (warm cache — the cheapest
        capacity), then spawn cold ones (counted in replica_warmups).
        Scale-down: put the newest live replicas in drain mode (no new
        admissions; drain_mode='preempt' restart-preempts their in-flight
        requests straight back to the router queue). Released pools are
        reaped in step()."""
        n = max(int(n), 1)  # a serving fleet never reaches zero
        live = self.live_replicas()
        if n > len(live):
            for r in self.replicas:
                if len(live) >= n:
                    break
                if r.draining:
                    r.cancel_drain()
                    live.append(r)
            while len(live) < n:
                live.append(self._spawn())
                self.replica_warmups += 1
        elif n < len(live):
            for r in live[n:]:
                for req in r.start_drain(
                        preempt=self.drain_mode == "preempt"):
                    self.queue.push(req)

    def _reap_drained(self) -> None:
        """Release draining replicas that have gone idle: archive their
        results and counters, leak-check + drop their pool, and queue
        their metric keys for tombstoning."""
        for r in [r for r in self.replicas if r.draining and not r.busy]:
            for req in r.completed:
                self._results[req.rid] = list(req.tokens)
            self._retired.absorb(r)
            r.release()
            self.replicas.remove(r)
            self.released.append(r.name)
            self._retired_sources.append(r.name)

    def pop_retired_sources(self) -> List[str]:
        """Names of replicas released since the last call — the cluster
        loop tombstones their registry keys immediately (a departed
        source must not keep skewing fleet aggregates)."""
        out, self._retired_sources = self._retired_sources, []
        return out

    # -- state ---------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return any(r.busy for r in self.replicas)

    def pending(self) -> int:
        return len(self.queue)

    def drained(self) -> bool:
        return not self.busy and not self.pending()

    def submit(self, requests: Sequence[Request]) -> None:
        validate_requests(requests, self.prompt_len, self.max_gen,
                          allow_shorter=self.prefill_chunk > 0)
        for r in requests:
            self.queue.push(r)

    def set_params(self, params) -> None:
        """Swap the serving weights on every replica (a post-training loop
        publishing its updated policy). The fleet must be idle — in-flight
        KV was computed under the old weights."""
        for r in self.replicas:
            r.set_params(params)
        self.params = params

    # -- scheduler iteration ---------------------------------------------------
    def step(self) -> Dict[str, float]:
        """One fleet tick: route admissions out of the global queue, step
        every replica's fused decode batch (all within this tick — the
        data-parallel speedup is real, not a dt rescale), reap drained
        replicas, return the fleet snapshot."""
        now = self.clock.now()
        self._admit_ready(now)
        for r in self.replicas:
            r.step_decode(now)
        self._reap_drained()
        return self.snapshot()

    def _admit_ready(self, now: float) -> None:
        """The router admission loop: SchedulerPolicy picks WHO admits
        next, RoutingPolicy picks WHERE. When nobody can take the pick,
        the policy may issue one fleet-wide preemption verdict per tick
        (the victim's replica must actually free enough — same rules as
        the single-engine loop); otherwise the queue holds backpressure."""
        # swap-aware admission, fleet edition: every arrived swapped-out
        # victim gets a standing re-admission reservation on ONE replica
        # (least-loaded first; the shared HostSwapPool arbitrates
        # ownership) before fresh requests can claim the capacity. A
        # draining owner cancels its plans, so the records re-plan onto a
        # live peer the next tick.
        arrived = self.queue.ready(now)
        if any(r.pool.has_swapped(q.rid)
               for q in arrived for r in self.replicas):
            by_load = sorted(self.live_replicas(),
                             key=lambda r: r.load_score())
            for q in arrived:
                for rep in by_load:
                    if rep.pool.has_swapped(q.rid) \
                            and rep.pool.plan_resume(q.rid):
                        break
        preempted = False
        ready = None
        while True:
            live = [r for r in self.live_replicas() if r.admission_room()]
            if not live:
                return
            if self.queue.peek_ready(now) is None:
                return  # O(1) hot-path exit: nothing has arrived
            if ready is None:
                ready = self.queue.ready(now)
            req = self.policy.select(ready, now)
            if req is None:
                return
            target = self.routing.route(live, req, now)
            if target is None:
                # resume-first fallback: the pick may be blocked by a
                # victim's standing reservation — resuming the victim
                # (pre-reserved; it only needs a slot) makes progress
                # where returning would deadlock the admission loop
                resumed = False
                for q in ready:
                    if q is req or not any(r.pool.has_swapped(q.rid)
                                           for r in live):
                        continue
                    rep = next((r for r in live if r.can_accept(q)), None)
                    if rep is not None:
                        self.queue.remove(q)
                        ready.remove(q)
                        rep.admit(q, now)
                        resumed = True
                        break
                if resumed:
                    continue
                if preempted:
                    return
                target, victim, vslot = self._preemption_target(live, req,
                                                                now)
                if target is None:
                    return  # fleet-wide exhaustion -> queue backpressure
                self.queue.push(target.preempt(victim, vslot, now))
                preempted = True
                ready = None  # the victim re-joined the arrived set
                if not target.can_accept(req):
                    return  # preempt_frees promised room; belt and braces
            self.queue.remove(req)
            if ready is not None:
                ready.remove(req)
            target.admit(req, now)

    def _preemption_target(self, live, req: Request, now: float):
        """Ask the SchedulerPolicy for a victim among every live
        replica's running set; map the verdict back to its replica and
        vet it exactly like the single-engine loop (stale verdicts, open
        lanes, and evictions that cannot make room are all 'no')."""
        running = [r for rep in live for r in rep.running()]
        victim = self.policy.victim(running, req, now)
        if victim is None:
            return None, None, None
        for rep in live:
            vslot = rep.slot_of(victim)
            if vslot is None:
                continue
            if rep.lane_open(vslot):
                return None, None, None
            if not rep.pool.preempt_frees(vslot, req.eff_gen_len,
                                          prompt=rep.prompt_arg(req)):
                return None, None, None
            return rep, victim, vslot
        return None, None, None  # stale verdict: the victim already retired

    # -- reporting -------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """Fleet rollup: throughput and cumulative counters sum (released
        replicas' counters stay absorbed so totals never rewind),
        occupancies average over live pools, and the latency percentiles
        are computed over the UNION of the replicas' sample windows —
        true fleet percentiles, not a max of maxes."""
        now = self.clock.now()
        snaps = [r.snapshot(queue_depth=None) for r in self.replicas]
        out: Dict[str, float] = {
            "queue_depth": float(self.queue.depth(now)),
            "replicas_live": float(len(self.live_replicas())),
            "replica_warmups": float(self.replica_warmups),
            "tokens_per_s": sum(s["tokens_per_s"] for s in snaps),
        }
        for name in ("slot_occupancy", "kv_block_occupancy",
                     "kv_shared_occupancy", "kv_quant_divergence"):
            # fractions OF each pool: a plain mean is exact while pools
            # are homogeneous (one replica_kw builds them all)
            vals = [s[name] for s in snaps if name in s]
            if vals:
                out[name] = sum(vals) / len(vals)
        # the fleet hit rate is computed from summed token COUNTS, not a
        # mean of per-replica ratios — affine routing concentrates a
        # template's traffic on one replica, and idle replicas reporting
        # 0.0 would drag the mean down in proportion to how well the
        # routing is working
        hits = self._retired.prefix_hit_tokens
        lookups = self._retired.prefix_lookup_tokens
        for r in self.replicas:
            hits += getattr(r.pool, "prefix_hit_tokens", 0)
            lookups += getattr(r.pool, "prefix_lookup_tokens", 0)
        if any("prefix_hit_rate" in s for s in snaps) or lookups:
            out["prefix_hit_rate"] = hits / max(lookups, 1)
        for name in ("deadline_misses", "preemptions", "prefill_tokens",
                     "recomputed_tokens"):
            out[name] = (sum(s.get(name, 0.0) for s in snaps)
                         + getattr(self._retired, name))
        # swap traffic (per-backend cumulative counters, summable even
        # over a shared host pool); published only when a swap tier exists
        for name in ("swapped_blocks", "swap_out_bytes", "swap_in_bytes"):
            if any(name in s for s in snaps) or getattr(self._retired,
                                                        name):
                out[name] = (sum(s.get(name, 0.0) for s in snaps)
                             + getattr(self._retired, name))
        # speculative acceptance from summed COUNTS (like the hit rate:
        # a mean of per-replica ratios would weight idle replicas equally)
        rt = self._retired
        steps = rt.spec_steps + sum(r.metrics.spec_steps
                                    for r in self.replicas)
        if steps:
            prop = rt.spec_proposed + sum(r.metrics.spec_proposed
                                          for r in self.replicas)
            acc = rt.spec_accepted + sum(r.metrics.spec_accepted
                                         for r in self.replicas)
            emit = rt.spec_emitted + sum(r.metrics.spec_emitted
                                         for r in self.replicas)
            out["accepted_per_step"] = emit / steps
            out["spec_acceptance_rate"] = acc / max(prop, 1)
        lats: List[float] = []
        ttfts: List[float] = []
        for r in self.replicas:
            ls, ts = r.metrics.window_samples(now)
            lats += ls
            ttfts += ts
        if lats:
            out["latency_p50_ms"] = percentile(lats, 50.0) * 1e3
            out["latency_p95_ms"] = percentile(lats, 95.0) * 1e3
        if ttfts:
            out["ttft_p95_ms"] = percentile(ttfts, 95.0) * 1e3
        out.update(self.extra_metrics)
        return out

    def metric_sources(self) -> Dict[str, Dict[str, float]]:
        """Per-source registry publication: one snapshot per replica
        (namespaced under its name) plus the router's own signals. The
        autoscaler aggregates across sources the same way it aggregates
        across nodes — per-replica occupancy averages, worst-replica
        latency, summed throughput."""
        now = self.clock.now()
        out = {"router": {
            "queue_depth": float(self.queue.depth(now)),
            "replicas_live": float(len(self.live_replicas())),
            "replica_warmups": float(self.replica_warmups),
            **self.extra_metrics,
        }}
        for r in self.replicas:
            out[r.name] = r.snapshot(queue_depth=None)
        return out

    def results(self) -> Dict[int, List[int]]:
        """rid -> generated tokens, across live and released replicas."""
        out = dict(self._results)
        for r in self.replicas:
            for req in r.completed:
                out[req.rid] = list(req.tokens)
        return out

    @property
    def completed_count(self) -> int:
        return (self._retired.completed
                + sum(len(r.completed) for r in self.replicas))

    def describe(self) -> str:
        first = self.replicas[0]
        return (f"{len(self.replicas)} replicas ({first.pool.describe()} "
                f"each), routing={self.routing.name}, "
                f"drain={self.drain_mode}")


def make_serving_engine(cfg, params, *, replicas: int = 1,
                        routing="occupancy", drain_mode: str = "finish",
                        policy=None, clock=None, **replica_kw):
    """One constructor for both data planes: a plain ServingEngine when
    replicas == 1 (the zero-router fast path every existing test and
    baseline measures), a Router + ReplicaSet beyond."""
    if replicas == 1:
        return ServingEngine(cfg, params, policy=policy, clock=clock,
                             **replica_kw)
    return ReplicaSet(cfg, params, replicas=replicas, routing=routing,
                      drain_mode=drain_mode, policy=policy, clock=clock,
                      **replica_kw)
