"""ServingMetrics — the exporter that closes the serving → autoscaler loop.

Counters and sliding windows over the serving clock, snapshotted into the
flat metric names AutoScaler.read_metrics() aggregates:

    queue_depth       arrived-but-unadmitted requests (summed across nodes)
    tokens_per_s      decode throughput over the trailing window
    latency_p50_ms /  request completion latency percentiles
    latency_p95_ms    (arrival -> last token, trailing window)
    ttft_p95_ms       time to first token percentile
    slot_occupancy    fraction of KV slots in use
    deadline_misses   completed requests that blew their deadline (cumulative)
    preemptions       restart-preemptions issued by the scheduler policy
    prefill_tokens    prompt positions actually computed (cumulative;
                      prefix-cache hits are the gap vs tokens submitted)
    recomputed_tokens prompt positions computed a second time after a
                      restart preemption discarded them (swap keeps it 0)
    accepted_per_step tokens emitted per speculating slot-step (> 1.0 is
                      the speculative win; omitted when not speculating)
    spec_acceptance_rate  accepted / proposed draft tokens (ditto)

plus whatever extra load signals the KVBackend reports (the paged
BlockManager adds kv_block_occupancy — committed blocks, the signal that
actually gates admission — and the prefix-cache pair prefix_hit_rate /
kv_shared_occupancy; the metrics path itself never branches on the cache
kind).

NodeAgent.report_serving(snapshot()) writes each as metrics/<node>/<name> —
the same KV path the straggler policy's step-time metrics use, so serving
load is just another signal the reconcile loop reads.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.request import Request

# The declared key set of the whole metrics plane. Everything published
# into the registry (ServingMetrics.snapshot, KVBackend.metrics,
# ReplicaSet.snapshot, the rollout loop's phase counters) and everything
# the autoscaler aggregates or a policy .get()s must be named here —
# the plane is stringly typed end to end, so a key missing from this set
# is a silent no-op on the reading side (the symptom is an autoscaler
# that stops reacting). replint rule R005 enforces membership statically;
# tests/test_metric_schema.py holds the aggregation and tombstone paths
# to the same set.
METRIC_SCHEMA = frozenset({
    # serving core (ServingMetrics.snapshot)
    "queue_depth", "tokens_per_s", "slot_occupancy", "deadline_misses",
    "preemptions", "prefill_tokens", "recomputed_tokens",
    "accepted_per_step", "spec_acceptance_rate",
    "latency_p50_ms", "latency_p95_ms", "ttft_p95_ms",
    # KV backend load signals (BlockManager/QuantBlockManager.metrics)
    "kv_block_occupancy", "prefix_hit_rate", "kv_shared_occupancy",
    "swapped_blocks", "swap_out_bytes", "swap_in_bytes",
    "kv_quant_divergence",
    # fleet rollup extras (ReplicaSet.snapshot)
    "replicas_live", "replica_warmups",
    # training-plane signals (NodeAgent step reports, rollout/loop.py)
    "step_time", "rollout_tokens", "reward_mean", "pairs_per_round",
    "train_loss",
})


def percentile(values, q: float) -> float:
    vs = list(values)
    if not vs:
        return 0.0
    return float(np.percentile(vs, q))


class ServingMetrics:
    def __init__(self, *, window_s: float = 10.0):
        self.window_s = window_s
        self._tokens: Deque[Tuple[float, int]] = deque()  # (t, n_tokens)
        self._latency: Deque[Tuple[float, float]] = deque()  # (t_done, s)
        self._ttft: Deque[Tuple[float, float]] = deque()
        self.total_tokens = 0
        self.completed = 0
        self.deadline_misses = 0
        self.preemptions = 0
        self.prefill_tokens = 0  # prompt positions actually computed
        # prompt positions computed a SECOND time because a restart
        # preemption discarded them (split out of prefill_tokens so
        # swap-out's savings are measurable: with swap this stays 0)
        self.recomputed_tokens = 0
        # speculative decoding (cumulative; only speculating slot-steps
        # count — a replica running --spec off reports none of them)
        self.spec_steps = 0     # slot-steps that carried >= 1 draft
        self.spec_proposed = 0  # draft tokens submitted to verify rows
        self.spec_accepted = 0  # draft tokens accepted (prefix-matched)
        self.spec_emitted = 0   # tokens emitted by speculating slot-steps

    # -- recording ----------------------------------------------------------
    def record_tokens(self, now: float, n: int) -> None:
        if n > 0:
            self._tokens.append((now, n))
            self.total_tokens += n

    def record_first_token(self, req: Request, now: float) -> None:
        self._ttft.append((now, now - req.arrival_t))

    def record_done(self, req: Request, now: float) -> None:
        self.completed += 1
        self._latency.append((now, now - req.arrival_t))
        if req.missed_deadline:
            self.deadline_misses += 1

    def record_preempt(self, now: float) -> None:
        self.preemptions += 1

    def record_spec(self, proposed: int, accepted: int,
                    emitted: int) -> None:
        """One speculating slot-step: `proposed` drafts rode verify rows,
        `accepted` prefix-matched the target, `emitted` tokens came out
        (accepted + 1 unless a stop token cut the run short)."""
        self.spec_steps += 1
        self.spec_proposed += proposed
        self.spec_accepted += accepted
        self.spec_emitted += emitted

    def record_prefill_tokens(self, n: int, *, recompute: bool = False) -> None:
        """Prompt positions run through prefill (lane rows or classic
        batch-1) — prefix-cache hits never get here, so this cumulative
        counter is the denominator bench_serve_prefix compares.
        `recompute=True` routes the count to recomputed_tokens instead:
        the positions were already paid for once, and a restart preemption
        threw them away (host swap-out exists to keep this at 0)."""
        if n > 0:
            if recompute:
                self.recomputed_tokens += n
            else:
                self.prefill_tokens += n

    def _trim(self, now: float) -> None:
        horizon = now - self.window_s
        for dq in (self._tokens, self._latency, self._ttft):
            while dq and dq[0][0] < horizon:
                dq.popleft()

    def window_samples(self, now: float) -> Tuple[List[float], List[float]]:
        """(latency_s, ttft_s) samples still inside the window. Fleet
        rollups merge these across replicas so the published percentiles
        are true fleet percentiles over every completion, not a
        percentile-of-percentiles."""
        self._trim(now)
        return ([s for _, s in self._latency], [s for _, s in self._ttft])

    # -- snapshot -----------------------------------------------------------
    def snapshot(self, now: float, *, queue_depth: Optional[int],
                 slot_occupancy: float,
                 **backend_metrics: float) -> Dict[str, float]:
        """Latency keys are OMITTED until a request completes (resp. emits a
        first token) inside the window — publishing 0ms for "no data" would
        read as excellent latency and make LatencyPolicy scale down
        mid-flight (its no-data branch keys off the absence).

        queue_depth=None omits the key entirely: a replica inside a
        ReplicaSet holds no arrival queue (the router owns it), and
        publishing 0 per replica would multiply the fleet's summed depth.

        **backend_metrics passes the KVBackend's own load signals through
        verbatim (ServingEngine.snapshot feeds pool.metrics() here)."""
        self._trim(now)
        toks = sum(n for _, n in self._tokens)
        span = self.window_s
        if self._tokens:
            # all in-window tokens at one timestamp (first step, or after an
            # idle gap): fall back to the window span rather than ~0
            span = now - self._tokens[0][0]
            if span <= 0.0:
                span = self.window_s
        out = {
            "tokens_per_s": toks / span if toks else 0.0,
            "slot_occupancy": slot_occupancy,
            "deadline_misses": float(self.deadline_misses),
            "preemptions": float(self.preemptions),
            "prefill_tokens": float(self.prefill_tokens),
            "recomputed_tokens": float(self.recomputed_tokens),
        }
        if queue_depth is not None:
            out["queue_depth"] = float(queue_depth)
        if self.spec_steps:  # omitted entirely when not speculating
            out["accepted_per_step"] = self.spec_emitted / self.spec_steps
            out["spec_acceptance_rate"] = (self.spec_accepted
                                           / max(self.spec_proposed, 1))
        for name, val in backend_metrics.items():
            out[name] = float(val)
        lats = [s for _, s in self._latency]
        ttfts = [s for _, s in self._ttft]
        if lats:
            out["latency_p50_ms"] = percentile(lats, 50.0) * 1e3
            out["latency_p95_ms"] = percentile(lats, 95.0) * 1e3
        if ttfts:
            out["ttft_p95_ms"] = percentile(ttfts, 95.0) * 1e3
        return out
