"""SchedulerPolicy — who admits next, and who gets preempted for whom.

The engine used to hard-code FIFO admission inside _admit_ready; v2 makes
the order a swappable policy, mirroring how cluster sizing is a swappable
autoscaler Policy (core/autoscaler.py) — the same policy-driven-cluster
argument from the source paper applied one level down, to requests.

A policy answers two questions each scheduler iteration:

  select(ready, now)            -> which arrived request admits next
  victim(running, candidate, …) -> which running request (if any) to evict
                                   so `candidate` can admit when the KV
                                   backend is full — the preemption verdict

Preemption here is restart-style: the engine returns the victim's blocks,
clears its progress, and re-queues it at its original arrival time. That
is *safe* because sampling is position-keyed (serve/sampling.py): a
restarted request regenerates bit-identical tokens, greedy or seeded.

FIFOPolicy is the extracted legacy behavior. EDFPolicy admits by
slack-to-deadline (earliest absolute deadline first) and, when
preemptive=True, evicts the running request with the most slack to make
room for one that would otherwise blow its deadline; deadline_misses flow
through ServingMetrics into LatencyPolicy (core/autoscaler.py), which
scales the cluster up on new misses — EDF reorders within a node,
the autoscaler buys capacity when reordering is no longer enough.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Protocol, Sequence, runtime_checkable

from repro.serve.request import Request


@runtime_checkable
class SchedulerPolicy(Protocol):
    name: str

    def select(self, ready: Sequence[Request], now: float
               ) -> Optional[Request]:
        """Pick the next request to admit from the arrived set (in arrival
        order), or None to admit nothing this iteration."""
        ...

    def victim(self, running: Sequence[Request], candidate: Request,
               now: float) -> Optional[Request]:
        """Preemption verdict: a running request to evict so `candidate`
        can admit, or None to apply queue backpressure instead. Called only
        when the KV backend cannot admit `candidate` as-is; the engine
        enforces at most one preemption per scheduler iteration."""
        ...


@dataclass
class FIFOPolicy:
    """Arrival order, never preempts — the legacy _admit_ready behavior."""
    name: str = "fifo"

    def select(self, ready, now):
        return ready[0] if ready else None

    def victim(self, running, candidate, now):
        return None


@dataclass
class EDFPolicy:
    """Earliest-deadline-first admission.

    Among arrived requests, admit the one whose absolute deadline
    (arrival_t + deadline_s) is soonest; ties fall back to arrival order
    (ready() is arrival-sorted and min() keeps the first minimum, so a
    deadline-free trace degenerates to FIFO exactly).

    preemptive=True enables the restart-preemption verdict (the textbook
    EDF rule, restart-style): a candidate that is *urgent but still
    salvageable* — nonnegative slack, at most `min_slack_s` of it — may
    evict the slackest runner, provided that runner has at least
    `slack_margin` times the candidate's slack (deadline-free runners
    always qualify). A candidate already past its deadline never preempts:
    destroying a runner's progress cannot save a request that is doomed
    anyway.
    """
    name: str = "edf"
    preemptive: bool = False
    min_slack_s: float = math.inf  # only candidates this urgent may preempt
    slack_margin: float = 2.0   # victim must have this x candidate's slack

    def select(self, ready, now):
        if not ready:
            return None
        return min(ready, key=lambda r: r.abs_deadline)

    def victim(self, running, candidate, now):
        if not self.preemptive or not running:
            return None
        cand_slack = candidate.abs_deadline - now
        if cand_slack < 0.0 or cand_slack > self.min_slack_s:
            return None  # doomed, or not urgent enough to justify a restart
        slackest = max(running, key=lambda r: r.abs_deadline)
        vic_slack = slackest.abs_deadline - now
        if vic_slack <= cand_slack * self.slack_margin:
            return None  # nobody is meaningfully better off than the candidate
        return slackest


def make_scheduler_policy(name: str, **kwargs) -> SchedulerPolicy:
    """CLI/config-facing registry (launch/serve.py --sched)."""
    if name == "fifo":
        return FIFOPolicy(**kwargs)
    if name == "edf":
        return EDFPolicy(**kwargs)
    raise ValueError(f"unknown scheduler policy {name!r} "
                     "(expected 'fifo' or 'edf')")
