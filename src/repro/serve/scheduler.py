"""ReplicaEngine + ServingEngine — continuous batching over a KVBackend.

The serving data plane is split into two layers:

`ReplicaEngine` is ONE serving replica: a KVBackend (its own block pool,
its own prefix cache), the in-flight lane/slot bookkeeping, the fused
decode step, and its own ServingMetrics. It has no arrival queue and no
SchedulerPolicy — it only answers "can you take this request?"
(`can_accept`), commits admissions (`admit`), and runs decode ticks
(`step_decode`). It also owns the drain lifecycle real scale-down needs:
a draining replica accepts no new work, finishes (or restart-preempts)
what it holds, and `release()` returns its pool with leak checking.

`ServingEngine` is the single-replica composition kept as the stable
public surface: a RequestQueue + SchedulerPolicy admission loop over one
ReplicaEngine. The multi-replica composition is `serve/router.py`'s
`ReplicaSet`: a Router front-end owning the global queue, admitting each
request to one of N ReplicaEngines via a RoutingPolicy.

One scheduler iteration (ServingEngine.step()):

  1. admit: the SchedulerPolicy (serve/policy.py) picks which arrived
     request admits next (FIFO, EDF, ...) while the KVBackend can reserve
     its worst case (exhaustion = queue backpressure, not an OOM
     mid-decode). If the backend is full, the policy may issue a
     preemption verdict: the engine evicts the victim, clears its
     progress, and re-queues it at its original arrival time —
     restart-preemption is safe because sampling is position-keyed
     (serve/sampling.py), so the victim regenerates identical tokens.
     On chunk-capable backends the prompt is *not* prefilled in a separate
     batch-1 call: it streams through `prefill_chunk` piggybacked lane
     rows of the regular decode step (chunked prefill). Other admissions
     take classic batch-1 prefill + insert.
  2. decode: one fused jitted step over decode rows (+ lane rows), run by
     the backend (it owns the cache layout and the step function): every
     row writes K/V where its backend says and attends at its own depth;
     the sample step (per-request temperature / top-k / top-p, seeded
     per-position PRNG; temperature=0 = argmax) happens on device and the
     [T] int32 token vector is the only per-step host download.
  3. retire: finished slots (gen budget spent, or a stop token emitted)
     return their capacity to the backend.

The engine never re-jits per admission; step shapes are pinned to
(num_slots,) and (num_slots + prefill_chunk,) rows. Greedy decoding keeps
output token-for-token equal to the one-shot serve_batch baseline on every
backend; seeded sampling is reproducible and lane-placement-invariant —
tests/test_serving.py holds all of it. Every row is computed independently
(each attends over its own KV at its own depth), which is what makes
per-request output invariant to *which replica* serves it — the property
the multi-replica exactness tests pin down.

The clock is injected: tests and the simulated cluster drive a ManualClock
(deterministic arrival replay); nothing here sleeps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelPlan
from repro.core.clock import Clock, ManualClock
from repro.launch import steps as St
from repro.models.env import Env
from repro.serve.kv import KVBackend, make_kv_backend, shared_jit
from repro.serve.metrics import ServingMetrics
from repro.serve.policy import FIFOPolicy, SchedulerPolicy
from repro.serve.request import Request, RequestQueue

Pytree = Any


def _default_attn_impl() -> str:
    """Pallas paged flash-decode on TPU; vectorized XLA gather elsewhere
    (same math — the greedy equivalence tests hold on every backend)."""
    try:
        return "pallas" if jax.default_backend() == "tpu" else "naive"
    except Exception:  # pragma: no cover - backend probe failure
        return "naive"


SERVE_PLAN = ParallelPlan(fsdp=False, remat="full",
                          attn_impl=_default_attn_impl(),
                          kv_cache="replicated")


@dataclass
class _Lane:
    """An in-flight chunked prefill riding the decode batch's lane rows.

    prefill_chunk is a *token budget* shared by every admitting request
    (Sarathi-style): each step the budget rows are packed FIFO across the
    open lanes, so several short prompts can prefill in one step while a
    long prompt streams through in chunks."""
    slot: int
    req: Request
    pos: int = 0  # prompt tokens consumed so far
    take: int = 0  # rows granted this step
    last_row: int = 0  # row of the chunk's final token (first-token source)


class ReplicaEngine:
    """One serving replica: KVBackend + lanes + fused step + metrics.

    Admission *order* lives above this class (ServingEngine's policy loop
    for one replica; ReplicaSet's router for a fleet); the replica only
    commits admissions it has capacity for and steps its own batch."""

    def __init__(self, cfg: ModelConfig, params: Pytree, *,
                 name: str = "replica-0",
                 num_slots: int = 4, prompt_len: int = 32, max_gen: int = 32,
                 kv="paged", block_size: int = 16,
                 kv_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 max_shared_fraction: float = 1.0,
                 prefill_chunk: Optional[int] = None,
                 spec=None, spec_k=4,
                 swap: bool = False,
                 swap_budget_blocks: Optional[int] = None,
                 swap_pool=None,
                 plan: Optional[ParallelPlan] = None, mesh=None,
                 clock: Optional[Clock] = None,
                 metrics_window_s: float = 10.0):
        self.cfg = cfg
        self.params = params
        self.name = name
        self.prompt_len = prompt_len
        self.max_gen = max_gen
        self.clock = clock or ManualClock()
        env = Env(mesh=mesh, plan=plan or SERVE_PLAN)
        self.env = env
        if isinstance(kv, str):
            self.pool: KVBackend = make_kv_backend(
                kv, cfg, env, num_slots=num_slots, prompt_len=prompt_len,
                max_gen=max_gen, block_size=block_size, kv_blocks=kv_blocks,
                prefix_cache=prefix_cache,
                max_shared_fraction=max_shared_fraction,
                swap=swap, swap_budget_blocks=swap_budget_blocks,
                swap_pool=swap_pool)
        else:  # a pre-built backend (custom implementations plug in here)
            self.pool = kv
            num_slots = self.pool.num_slots
        self.kv = self.pool.kind
        if prefill_chunk is None:
            prefill_chunk = prompt_len if self.pool.chunk_prefill_ok else 0
        if prefill_chunk and not self.pool.chunk_prefill_ok:
            raise ValueError(
                f"{cfg.name}: chunked prefill is not supported by the "
                f"'{self.pool.kind}' backend for this arch (recurrent "
                "state is sequential over the prompt; ring writes wrap "
                "within a chunk; the slot pool has no per-row tables)")
        self.prefill_chunk = int(prefill_chunk)
        # -- speculative decoding (serve/spec.py) --------------------------
        # verify rows ride the step like lane rows: several rows share one
        # slot at consecutive depths. That needs per-row independent math
        # over a scatter-then-write cache — attention blocks only. Window
        # ('local') rings wrap within a draft run and recurrent state is
        # sequential, so both are gated off (exactly the chunked-prefill
        # gate, for the same reason).
        # spec_k="auto": the verify-row block stays `cap` rows wide (step
        # shapes are pinned) but the live draft depth per request is tuned
        # from its own acceptance feedback (serve/spec.py AdaptiveSpecK)
        if spec_k == "auto":
            from repro.serve.spec import AdaptiveSpecK
            self.spec_k = 4
            self._spec_ctl: Optional[Any] = AdaptiveSpecK(cap=self.spec_k)
        else:
            self.spec_k = int(spec_k)
            self._spec_ctl = None
        if isinstance(spec, str) or spec is None:
            from repro.serve.spec import make_drafter
            self.drafter = make_drafter(spec, cfg, env,
                                        num_slots=num_slots,
                                        prompt_len=prompt_len,
                                        max_gen=max_gen, spec_k=self.spec_k)
        else:  # a pre-built Drafter (tests plug deterministic ones in)
            self.drafter = spec
        if self.drafter is not None:
            kinds = set(cfg.block_pattern) | set(cfg.pattern_tail)
            if not kinds <= {"attn", "moe"}:
                raise ValueError(
                    f"{cfg.name}: speculative decoding needs per-row "
                    "attention blocks (sliding-window rings wrap within a "
                    "draft; recurrent state is sequential)")
            if self.spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.metrics = ServingMetrics(window_s=metrics_window_s)
        self._prefill = shared_jit(
            ("prefill", cfg, env.plan, env.mesh),
            lambda: St.make_prefill_step(cfg, env))
        # classic admissions sample their first token from the prefill
        # logits with the same fused sample math (position 0)
        self._sample_first = shared_jit(
            ("sample_first", cfg, prompt_len),
            lambda: St.make_sample_fn(cfg, prompt_len))
        self._lanes: List[_Lane] = []
        # device [T] int32: last step's fused sample/argmax. Seeded at
        # num_slots so the step's (rows, prev-rows) shape pair cycles
        # through its <= 4 combinations deterministically — a two-request
        # warm trace compiles them all (benchmarks warm exactly that way).
        self._tok_prev = jnp.zeros((num_slots,), jnp.int32)
        self._row_src: Dict[int, int] = {}  # slot -> row in _tok_prev
        self._fresh: Dict[int, int] = {}  # slot -> host-known next token
        self._inflight: Dict[int, Request] = {}  # rid -> request
        self.completed: List[Request] = []
        self.decode_steps = 0
        self.draining = False
        # host-side gauges merged into every snapshot (a rollout loop
        # publishes its phase metrics here so they ride the same rollup
        # the autoscaler already reads)
        self.extra_metrics: Dict[str, float] = {}

    # -- state -----------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return bool(self._inflight)

    def prompt_arg(self, req: Request):
        """The prompt to hand the backend's admission probes: chunked
        admissions pass it so a prefix-caching backend can attach shared
        blocks (classic batch-1 prefill scatters the whole prompt and
        cannot share)."""
        return req.prompt if self.prefill_chunk else None

    def admission_room(self) -> bool:
        """Lane-budget gate: open lanes only while the step's token budget
        can still reach a new prompt (bounds admitted-but-starved lanes
        ~1). Classic (non-chunked) replicas always have room — the
        backend's can_admit is the only gate."""
        if not self.prefill_chunk:
            return True
        return (sum(len(l.req.prompt) - l.pos for l in self._lanes)
                < self.prefill_chunk)

    def can_take(self, req: Request) -> bool:
        """Capacity predicate only: can the backend hold `req` right now?
        A swapped-out request resumes instead of re-admitting — its gate
        is can_resume (free slot + its allocated blocks + its unspent
        reservation), not the fresh-admission math."""
        if self.pool.has_swapped(req.rid):
            return self.pool.can_resume(req.rid)
        return self.pool.can_admit(req.eff_gen_len,
                                   prompt=self.prompt_arg(req))

    def can_accept(self, req: Request) -> bool:
        """Could this replica commit `req` right now? (Routing predicate —
        admission-accurate because admit() takes its reservations
        immediately, so successive calls within one tick stay honest.)"""
        return (not self.draining and self.admission_room()
                and self.can_take(req))

    # -- admission commit ---------------------------------------------------
    def admit(self, req: Request, now: float) -> None:
        """Commit one admission (caller already took it off its queue)."""
        req.t_admit = now
        self._inflight[req.rid] = req
        if self.pool.has_swapped(req.rid):
            # swap-in resume: the host tier holds the request's whole KV
            # at its preemption cursor. Restore it, seed the fused step
            # with the last emitted token (the one swap-out never fed
            # back), and decoding continues bit-identically — no prefill,
            # no recompute, first token long since recorded.
            slot = self.pool.swap_in(req.rid)
            self._fresh[slot] = req.tokens[-1]
            if self.drafter is not None:
                self.drafter.admit(req)
            return
        if self.drafter is not None:
            self.drafter.admit(req)
        if self.prefill_chunk:
            slot = self.pool.admit(req.rid, req.eff_gen_len,
                                   prefilling=True, prompt=req.prompt)
            # cached prefix positions never ride a lane: start at the
            # first uncached token (at most prompt_len - 1 — the last
            # prompt token always runs to emit the first token)
            self._lanes.append(_Lane(
                slot=slot, req=req,
                pos=self.pool.cached_prefix_len(slot)))
        else:
            self._admit_classic(
                self.pool.admit(req.rid, req.eff_gen_len), req, now)

    def _admit_classic(self, slot: int, req: Request, now: float) -> None:
        """Batch-1 prefill + cache insert (the non-chunked path). The first
        token is sampled from the prefill logits at position 0 — greedy
        requests take the plain argmax, bit-identical to the pre-v2 engine
        — and fed to the same step's decode via the fresh-token path."""
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompt)[None]})
        self.metrics.record_prefill_tokens(len(req.prompt),
                                           recompute=req.restarts > 0)
        self.pool.insert(slot, req.rid, caches, req.eff_gen_len)
        if req.sampling.greedy:
            first = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
        else:
            mi = np.zeros((St.META_I_ROWS, 1), np.int32)
            mf = np.zeros((St.META_F_ROWS, 1), np.float32)
            mi[St.ROW_CUR_LEN, 0] = len(req.prompt) - 1  # -> position 0
            self._fill_sampling(mi, mf, 0, req)
            first = int(self._sample_first(logits, mi, mf)[0])
        req.t_first_token = now
        req.tokens.append(first)
        self._fresh[slot] = first
        self.metrics.record_first_token(req, now)
        self.metrics.record_tokens(now, 1)
        if self.pool.finished(slot) or first in req.sampling.stop_set:
            self._retire(slot, now)  # gen_len == 1 / instant stop token

    # -- preemption (restart-style) ------------------------------------------
    def running(self) -> List[Request]:
        """Decoding (preemptible) requests, for the policy's verdict."""
        return [self._inflight[self.pool.info(s).rid]
                for s in self.pool.active_slots()]

    def slot_of(self, req: Request) -> Optional[int]:
        """The slot `req` occupies, or None if it holds none (a stale
        policy verdict — e.g. the victim retired this iteration). Callers
        treat None as "no victim"; a bare next() here would leak
        StopIteration out of the scheduler loop."""
        return next((s for s in self.pool.occupied_slots()
                     if self.pool.rid_of(s) == req.rid), None)

    def lane_open(self, slot: int) -> bool:
        return any(ln.slot == slot for ln in self._lanes)

    def preempt(self, victim: Request, slot: int, now: float) -> Request:
        """Preemption: return the victim's KV capacity; the caller
        re-queues it at its original arrival time.

        Swap-out first: a backend with a host tier copies the victim's
        blocks out (serve/blocks.py HostSwapPool), so its tokens and
        first-token timestamp survive — re-admission restores the KV and
        decoding resumes bit-identically with zero recompute.

        Restart fallback (no host tier / budget full / mid-prefill):
        clear the victim's progress entirely. Safe because sampling is
        position-keyed — on re-admission the victim regenerates
        bit-identical tokens (greedy or seeded) — but the re-prefill is
        paid compute, booked into recomputed_tokens via `restarts`.

        Metrics semantics: the victim's pre-preemption tokens stay in
        tokens_per_s (the device really decoded them — that is the decode
        throughput the autoscaler budgets), and a restart records a
        second, longer TTFT sample alongside the first. Both read as load,
        i.e. they bias the policies toward scaling up while preemptions
        are happening — the conservative direction."""
        # only decode slots are preemptible (running() excludes
        # prefilling): an open lane would keep writing prompt chunks into
        # a freed/reassigned slot — make the invariant explicit here too
        assert not self.lane_open(slot), \
            f"preempting slot {slot} with an open prefill lane"
        swapped = self.pool.swap_out(slot)
        if not swapped:
            self.pool.evict(slot)
        self._row_src.pop(slot, None)
        self._fresh.pop(slot, None)
        if self.drafter is not None:
            self.drafter.retire(victim.rid)
        if self._spec_ctl is not None:
            self._spec_ctl.retire(victim.rid)
        del self._inflight[victim.rid]
        victim.t_admit = None
        if not swapped:
            victim.tokens.clear()
            victim.t_first_token = None
            victim.restarts += 1
        self.metrics.record_preempt(now)
        return victim

    # -- drain lifecycle ------------------------------------------------------
    def start_drain(self, *, preempt: bool = False) -> List[Request]:
        """Enter drain mode: no new admissions (can_accept goes False).
        With preempt=False the replica finishes what it holds; with
        preempt=True every in-flight request — decoding or mid-prefill —
        is restart-preempted and returned for the caller to re-queue
        (bit-identical regeneration is the position-keyed sampling
        guarantee, so a drain can be immediate without changing output)."""
        self.draining = True
        # a draining replica will never run its planned swap-ins — free
        # the standing reservations so a live peer can take them over
        self.pool.cancel_resume_plans()
        if not preempt:
            return []
        now = self.clock.now()
        # closing the lanes first makes mid-prefill slots preemptible too
        # (preempt()'s open-lane guard is about a lane writing into a
        # freed slot; with no lanes left there is nothing to write)
        self._lanes.clear()
        return [self.preempt(self._inflight[self.pool.rid_of(slot)], slot,
                             now)
                for slot in list(self.pool.occupied_slots())]

    def cancel_drain(self) -> None:
        """Scale-up may reuse a still-draining replica: its cache is warm,
        which beats a cold spawn."""
        self.draining = False

    def release(self) -> None:
        """Return the replica's pool to the void. Must be idle; the
        backend's release() verifies its free-list accounting returns to
        empty (no leaked blocks/reservations) before dropping the device
        cache."""
        if self.busy or self._lanes:
            raise RuntimeError(
                f"{self.name}: release() while {len(self._inflight)} "
                "requests are in flight — drain first")
        self.pool.release()

    # -- one decode tick -----------------------------------------------------
    def step_decode(self, now: float) -> int:
        """Run one fused decode step over the replica's mixed batch
        (+ prefill lanes) and retire finished requests. Returns tokens
        emitted this tick."""
        active = self.pool.active_slots()
        lanes = self._lanes
        if not active and not lanes:
            return 0

        # pack the prefill token budget FIFO across open lanes
        N = self.pool.num_slots
        budget = self.prefill_chunk
        for lane in lanes:
            lane.take = min(budget, len(lane.req.prompt) - lane.pos)
            budget -= lane.take
        # prefill compute actually spent this step (prefix-cache hits
        # shrink it: cached positions never occupy a lane row); chunks of
        # restart-preempted requests are re-work, booked separately
        self.metrics.record_prefill_tokens(
            sum(ln.take for ln in lanes if ln.req.restarts == 0))
        self.metrics.record_prefill_tokens(
            sum(ln.take for ln in lanes if ln.req.restarts > 0),
            recompute=True)
        lane_rows = self.prefill_chunk if lanes else 0
        # speculative verify rows: a fixed block of num_slots * spec_k rows
        # stacked after the lane rows (slot s's candidates at spec_base +
        # s*spec_k + j), present whenever a drafter is configured so the
        # step shape set stays as bounded as without speculation. Unused
        # candidate rows stay masked (row_slots -1).
        spec_rows = N * self.spec_k if self.drafter is not None else 0
        spec_base = N + lane_rows
        T = N + lane_rows + spec_rows
        meta_i = np.zeros((St.META_I_ROWS, T), np.int32)
        meta_f = np.zeros((St.META_F_ROWS, T), np.float32)
        meta_i[St.ROW_TOK_SRC, :] = -1
        row_slots = np.full((T,), -1, np.int32)
        sample = False
        # draft proposals per decoding slot. k is capped so the last
        # verify row's write position stays inside the request's declared
        # budget: max accepted emission is gen_len - tokens_done tokens,
        # i.e. the final-step row never speculates (its write position
        # prompt_len + gen_len - 2 is the last the reservation covers).
        drafts: Dict[int, List[int]] = {}
        if self.drafter is not None:
            for slot in active:
                info = self.pool.info(slot)
                req = self._inflight[info.rid]
                k_live = (self.spec_k if self._spec_ctl is None
                          else self._spec_ctl.k(req.rid))
                k_eff = min(k_live,
                            info.gen_len - info.tokens_done - 1)
                if k_eff <= 0:
                    continue
                d = self.drafter.propose(req, k_eff)[:k_eff]
                if d:
                    drafts[slot] = d
        for slot in active:
            info = self.pool.info(slot)
            req = self._inflight[info.rid]
            self.pool.ensure(slot, info.cur_len)
            row_slots[slot] = slot
            meta_i[St.ROW_CUR_LEN, slot] = info.cur_len
            sample |= self._fill_sampling(meta_i, meta_f, slot, req)
            if slot in self._fresh:
                meta_i[St.ROW_TOK_SRC, slot] = -1
                meta_i[St.ROW_FRESH, slot] = self._fresh.pop(slot)
            else:
                meta_i[St.ROW_TOK_SRC, slot] = self._row_src.pop(slot, slot)
        for slot, d in drafts.items():
            info = self.pool.info(slot)
            req = self._inflight[info.rid]
            # blocks for every candidate write position (rolled back on
            # rejection via truncate)
            self.pool.ensure(slot, info.cur_len + len(d))
            base = spec_base + slot * self.spec_k
            for j, tok in enumerate(d):
                r = base + j
                row_slots[r] = slot
                meta_i[St.ROW_FRESH, r] = tok
                meta_i[St.ROW_CUR_LEN, r] = info.cur_len + 1 + j
                sample |= self._fill_sampling(meta_i, meta_f, r, req)
        row = N
        for lane in lanes:
            if lane.take <= 0:
                continue
            self.pool.ensure(lane.slot, lane.pos + lane.take - 1)
            sl = slice(row, row + lane.take)
            meta_i[St.ROW_FRESH, sl] = \
                lane.req.prompt[lane.pos:lane.pos + lane.take]
            meta_i[St.ROW_CUR_LEN, sl] = \
                np.arange(lane.pos, lane.pos + lane.take)
            row_slots[sl] = lane.slot
            sample |= self._fill_sampling(meta_i, meta_f, sl, lane.req)
            row += lane.take
            lane.last_row = row - 1

        nxt_dev = self.pool.decode(self.params, self._tok_prev, meta_i,
                                   meta_f, row_slots, sample=sample)
        self._tok_prev = nxt_dev
        nxt = np.asarray(nxt_dev)  # the one host transfer per step
        self.decode_steps += 1

        emitted = 0
        for slot in active:
            info = self.pool.info(slot)
            req = self._inflight[info.rid]
            cur = info.cur_len
            d = drafts.get(slot, [])
            outs = [int(nxt[slot])]
            base = spec_base + slot * self.spec_k
            outs += [int(nxt[base + j]) for j in range(len(d))]
            # accept the longest prefix where draft j matches the target's
            # own output for that position (o_{j-1}): verify row j's
            # logits — and, seeded, its fold_in(seed, position) draw — are
            # bit-identical to sequential decode's exactly while every
            # earlier draft matched, so emitting o_0..o_a is bit-exact
            a = 0
            while a < len(d) and d[a] == outs[a]:
                a += 1
            emit = outs[:a + 1]
            stop = req.sampling.stop_set
            cut = next((i for i, t in enumerate(emit) if t in stop), None)
            if cut is not None:
                emit = emit[:cut + 1]
            if d:
                # roll the rejected suffix's KV capacity back (a no-op
                # when every draft was accepted) and record acceptance
                self.pool.truncate(slot, cur + len(emit))
                self.metrics.record_spec(len(d), len(emit) - 1, len(emit))
                if self._spec_ctl is not None:
                    self._spec_ctl.update(req.rid, len(d), len(emit) - 1)
            # next step's input token (the last emitted) sits at the row
            # that produced it — main row for a=0, else verify row a-1
            self._row_src[slot] = (slot if len(emit) == 1
                                   else base + len(emit) - 2)
            for tok in emit:
                self.pool.advance(slot)
                req.tokens.append(tok)
                emitted += 1
            if self.pool.finished(slot) or emit[-1] in stop:
                self._retire(slot, now)
        still_open: List[_Lane] = []
        for lane in lanes:
            lane.pos += lane.take
            if lane.pos < len(lane.req.prompt):
                still_open.append(lane)
                continue
            slot = lane.slot
            self.pool.finish_prefill(slot)
            req = lane.req
            req.t_first_token = now
            tok = int(nxt[lane.last_row])
            req.tokens.append(tok)
            self.metrics.record_first_token(req, now)
            # next step, this slot's input token comes from the lane row
            self._row_src[slot] = lane.last_row
            emitted += 1
            if self.pool.finished(slot) or tok in req.sampling.stop_set:
                self._retire(slot, now)
        self._lanes = still_open
        if emitted:
            self.metrics.record_tokens(now, emitted)
        return emitted

    @staticmethod
    def _fill_sampling(meta_i, meta_f, rows, req: Request) -> bool:
        """Write one request's SamplingParams into its row(s); returns
        whether the row actually samples (so an all-greedy batch can take
        the pure-argmax step variant)."""
        sp = req.sampling
        meta_i[St.ROW_SEED, rows] = sp.seed
        meta_i[St.ROW_TOP_K, rows] = sp.top_k
        meta_i[St.ROW_POS0, rows] = len(req.prompt) - 1
        meta_f[St.ROW_TEMPERATURE, rows] = sp.temperature
        meta_f[St.ROW_TOP_P, rows] = sp.top_p
        return not sp.greedy

    def _retire(self, slot: int, now: float) -> None:
        rid = self.pool.rid_of(slot)
        req = self._inflight.pop(rid)
        req.t_done = now
        self.completed.append(req)
        self.metrics.record_done(req, now)
        self.pool.evict(slot)
        self._row_src.pop(slot, None)
        self._fresh.pop(slot, None)
        if self.drafter is not None:
            self.drafter.retire(rid)
        if self._spec_ctl is not None:
            self._spec_ctl.retire(rid)

    # -- reporting ----------------------------------------------------------------
    def load_score(self):
        """Routing key: committed KV first (the signal that actually gates
        admission on paged backends; slot occupancy elsewhere), the
        in-flight count as the queue-depth tiebreak, then *absolute* free
        capacity. The fractions alone mis-rank heterogeneous fleets: two
        empty replicas with unequal --kv-blocks both score occupancy 0.0,
        but the big pool can absorb strictly more load — prefer it (more
        free capacity = smaller key). Homogeneous fleets are unaffected:
        equal fractions imply equal free capacity, so the ordering
        degenerates to the old one."""
        m = self.pool.metrics()
        return (m.get("kv_block_occupancy", self.pool.occupancy),
                len(self._inflight), -self.pool.free_capacity)

    def set_params(self, params: Pytree) -> None:
        """Swap the serving weights (a post-training loop publishing its
        updated policy). Only between requests: in-flight KV was computed
        under the old weights, so a mid-request swap would silently mix
        models inside one generation. Same tree structure as the old
        params keeps every shared jit warm — no recompile."""
        if self.busy or self._lanes:
            raise RuntimeError(
                f"{self.name}: set_params with {len(self._inflight)} "
                "requests in flight — drain first")
        self.params = params

    def snapshot(self, *, queue_depth: Optional[int] = None
                 ) -> Dict[str, float]:
        return self.metrics.snapshot(self.clock.now(),
                                     queue_depth=queue_depth,
                                     slot_occupancy=self.pool.occupancy,
                                     **self.pool.metrics(),
                                     **self.extra_metrics)


class ServingEngine:
    """The single-replica serving composition: RequestQueue +
    SchedulerPolicy admission loop over one ReplicaEngine. Kept as the
    stable public surface (tests/CLI/benchmarks); `serve/router.py`'s
    ReplicaSet is the N-replica composition of the same pieces."""

    def __init__(self, cfg: ModelConfig, params: Pytree, *,
                 num_slots: int = 4, prompt_len: int = 32, max_gen: int = 32,
                 kv="paged", block_size: int = 16,
                 kv_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 max_shared_fraction: float = 1.0,
                 prefill_chunk: Optional[int] = None,
                 spec=None, spec_k=4,
                 swap: bool = False,
                 swap_budget_blocks: Optional[int] = None,
                 swap_pool=None,
                 policy: Optional[SchedulerPolicy] = None,
                 plan: Optional[ParallelPlan] = None, mesh=None,
                 clock: Optional[Clock] = None,
                 metrics_window_s: float = 10.0):
        self.replica = ReplicaEngine(
            cfg, params, num_slots=num_slots, prompt_len=prompt_len,
            max_gen=max_gen, kv=kv, block_size=block_size,
            kv_blocks=kv_blocks, prefix_cache=prefix_cache,
            max_shared_fraction=max_shared_fraction,
            prefill_chunk=prefill_chunk, spec=spec, spec_k=spec_k,
            swap=swap, swap_budget_blocks=swap_budget_blocks,
            swap_pool=swap_pool,
            plan=plan, mesh=mesh, clock=clock,
            metrics_window_s=metrics_window_s)
        self.policy: SchedulerPolicy = policy or FIFOPolicy()
        self.queue = RequestQueue()

    # -- delegated surface (the replica owns the data plane) -----------------
    @property
    def cfg(self):
        return self.replica.cfg

    @property
    def params(self):
        return self.replica.params

    @property
    def env(self):
        return self.replica.env

    @property
    def clock(self):
        return self.replica.clock

    @property
    def pool(self) -> KVBackend:
        return self.replica.pool

    @property
    def kv(self) -> str:
        return self.replica.kv

    @property
    def prompt_len(self) -> int:
        return self.replica.prompt_len

    @property
    def max_gen(self) -> int:
        return self.replica.max_gen

    @property
    def prefill_chunk(self) -> int:
        return self.replica.prefill_chunk

    @property
    def drafter(self):
        return self.replica.drafter

    @property
    def spec_k(self) -> int:
        return self.replica.spec_k

    @property
    def metrics(self) -> ServingMetrics:
        return self.replica.metrics

    @metrics.setter
    def metrics(self, m: ServingMetrics) -> None:
        self.replica.metrics = m

    @property
    def completed(self) -> List[Request]:
        return self.replica.completed

    @property
    def decode_steps(self) -> int:
        return self.replica.decode_steps

    @decode_steps.setter
    def decode_steps(self, n: int) -> None:
        self.replica.decode_steps = n

    @property
    def extra_metrics(self) -> Dict[str, float]:
        return self.replica.extra_metrics

    def set_params(self, params: Pytree) -> None:
        self.replica.set_params(params)

    @property
    def _prefill(self):
        return self.replica._prefill

    @property
    def _lanes(self) -> List[_Lane]:
        return self.replica._lanes

    @property
    def _inflight(self) -> Dict[int, Request]:
        return self.replica._inflight

    # -- state -----------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return self.replica.busy

    def pending(self) -> int:
        return len(self.queue)

    def drained(self) -> bool:
        return not self.busy and not self.pending()

    def submit(self, requests: Sequence[Request]) -> None:
        """Validate and enqueue. Never mutates the caller's Requests: the
        admitted generation budget (gen_len capped by max_tokens) is
        derived at admission via Request.eff_gen_len, so re-submitting the
        same objects (the CLI --verify re-serve path) sees the declared
        gen_len unchanged."""
        validate_requests(requests, self.prompt_len, self.max_gen,
                          allow_shorter=self.prefill_chunk > 0)
        for r in requests:
            self.queue.push(r)

    # -- scheduler iteration ------------------------------------------------------
    def step(self) -> Dict[str, float]:
        """Admit arrivals (policy order), run one fused decode step over
        the mixed batch (+ prefill lanes), retire finished requests.
        Returns the metrics snapshot (what a node would publish)."""
        now = self.clock.now()
        self._admit_ready(now)
        self.replica.step_decode(now)
        return self.snapshot()

    # -- admission ----------------------------------------------------------------
    def _admit_ready(self, now: float) -> None:
        rep = self.replica
        # swap-aware admission: before any fresh request can claim blocks,
        # every arrived swapped-out victim gets a re-admission *plan* — a
        # standing reservation for its resume footprint (blocks it held +
        # its unspent reservation). Fresh admissions see the shrunk
        # free_unreserved and queue behind the victim instead of starving
        # it; the plan is consumed by swap_in and survives across ticks,
        # so resume capacity accretes instead of being re-raced each step.
        for r in self.queue.ready(now):
            if rep.pool.has_swapped(r.rid):
                rep.pool.plan_resume(r.rid)
        preempted = False  # at most one restart per iteration (no thrash)
        ready = None  # built lazily, reused across the loop (O(arrived)
        # once per step, not per admission; invalidated when the queue
        # changes underneath it — i.e. a preemption re-push)
        while True:
            if not rep.admission_room():
                return
            if self.queue.peek_ready(now) is None:
                return  # O(1) hot-path exit: nothing has arrived
            if ready is None:
                ready = self.queue.ready(now)
            req = self.policy.select(ready, now)
            if req is None:
                return
            prompt = rep.prompt_arg(req)
            if not rep.can_take(req):
                # resume-first fallback: the policy's pick is blocked —
                # possibly *by* a victim's standing reservation. Resuming
                # an admissible swapped request never takes what the pick
                # waits for (its blocks are pre-reserved; it only needs a
                # free slot) and retiring it is the fastest way to free
                # real capacity — and it keeps EDF's tight-deadline picks
                # from starving victims behind an admission deadlock.
                swapped = next(
                    (r for r in ready if r is not req
                     and rep.pool.has_swapped(r.rid) and rep.can_take(r)),
                    None)
                if swapped is not None:
                    self.queue.remove(swapped)
                    ready.remove(swapped)
                    rep.admit(swapped, now)
                    continue
                victim = None if preempted else \
                    self.policy.victim(rep.running(), req, now)
                if victim is None:
                    return  # backend exhaustion -> queue backpressure
                vslot = rep.slot_of(victim)
                if vslot is None or rep.lane_open(vslot):
                    # a policy may hand back a stale verdict (the victim
                    # retired this iteration) or — buggy — a mid-prefill
                    # request whose open lane would keep writing into a
                    # freed slot; both are "no victim": backpressure
                    return
                if not rep.pool.preempt_frees(vslot, req.eff_gen_len,
                                              prompt=prompt):
                    # eviction could not make room — don't cost the victim
                    # its progress for nothing (and don't re-try a doomed
                    # candidate against every runner, one per step)
                    return
                self.queue.push(rep.preempt(victim, vslot, now))
                preempted = True
                ready = None  # the victim re-joined the arrived set
                if not rep.can_take(req):
                    # preempt_frees promised room for a fresh admission;
                    # a swap-resume's can_resume gate may still disagree
                    return
            self.queue.remove(req)
            if ready is not None:
                ready.remove(req)
            rep.admit(req, now)

    # -- reporting ----------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        now = self.clock.now()
        return self.replica.snapshot(queue_depth=self.queue.depth(now))

    def results(self) -> Dict[int, List[int]]:
        """rid -> generated tokens, for every completed request."""
        return {r.rid: list(r.tokens) for r in self.completed}


def validate_requests(requests: Sequence[Request], prompt_len: int,
                      max_gen: int, *, allow_shorter: bool = False) -> None:
    """Shared submit-time validation (ServingEngine and the router both
    gate here, before anything reaches a replica). Chunk-prefill backends
    stream prompts through lane rows at the request's own length, so they
    accept any prompt up to the engine's prompt_len budget
    (allow_shorter=True); classic batch-1 prefill jits one shape and
    keeps the exact-length contract."""
    for r in requests:
        n = len(r.prompt)
        if (n != prompt_len if not allow_shorter
                else not 0 < n <= prompt_len):
            raise ValueError(
                f"request {r.rid}: prompt length {n} "
                + (f"not in (0, {prompt_len}]" if allow_shorter
                   else f"!= engine prompt_len {prompt_len} (pad the trace)"))
        if r.eff_gen_len > max_gen:
            raise ValueError(
                f"request {r.rid}: gen_len {r.eff_gen_len} > "
                f"engine max_gen {max_gen}")


def run_to_completion(engine, requests: Sequence[Request] = (), *,
                      dt: float = 0.05, max_steps: int = 100_000,
                      on_step: Optional[Callable[[int, Dict[str, float]],
                                                 None]] = None
                      ) -> Dict[int, List[int]]:
    """Standalone drain loop (no cluster): step the engine (a ServingEngine
    or a router.ReplicaSet), advance the clock by `dt` between iterations.
    VirtualCluster.serve() is the cluster-integrated version of this
    loop."""
    engine.submit(requests)
    steps = 0
    while not engine.drained() and steps < max_steps:
        snap = engine.step()
        engine.clock.sleep(dt)
        if on_step is not None:
            on_step(steps, snap)
        steps += 1
    if not engine.drained():
        raise RuntimeError(f"serve did not drain in {max_steps} steps")
    return engine.results()
