"""ServingEngine — continuous batching over a paged (block-table) KV cache.

One scheduler iteration (step()):

  1. admit: pop arrivals while a slot is free AND the block pool can
     reserve the request's worst-case blocks (block exhaustion = queue
     backpressure, not an OOM mid-decode). On attention-only archs the
     prompt is *not* prefilled in a separate batch-1 call: it streams
     through `prefill_chunk` piggybacked lane rows of the regular decode
     step (chunked prefill), so admission never stalls the pool and there
     is no grow_caches/full-cache copy. Recurrent-state archs (rglru/rwkv
     blocks) keep the classic batch-1 prefill + paged insert.
  2. decode: one fused jitted step over decode rows (+ lane rows): every
     row writes K/V into the physical block its table names and attends at
     its own depth; argmax happens on device and the [T] int32 token
     vector is the only per-step host transfer (logits and last-token
     state never round-trip).
  3. retire: finished slots return their blocks to the O(1) free list.

The engine never re-jits per admission; step shapes are pinned to
(num_slots,) and (num_slots + prefill_chunk,) rows. Greedy decoding keeps
output token-for-token equal to the one-shot serve_batch baseline and to
the PR-1 slot pool — tests/test_serving.py holds it to both.

kv="slot" keeps the PR-1 slot-reserved pool (worst-case prompt_len+max_gen
KV per slot) as the measured baseline for benchmarks and as a fallback.

The clock is injected: tests and the simulated cluster drive a ManualClock
(deterministic arrival replay); nothing here sleeps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelPlan
from repro.core.clock import Clock, ManualClock
from repro.launch import steps as St
from repro.models.env import Env
from repro.serve.blocks import RECURRENT_KINDS, BlockManager
from repro.serve.metrics import ServingMetrics
from repro.serve.request import Request, RequestQueue
from repro.serve.slots import SlotPool

Pytree = Any


def _default_attn_impl() -> str:
    """Pallas paged flash-decode on TPU; vectorized XLA gather elsewhere
    (same math — the greedy equivalence tests hold on every backend)."""
    try:
        return "pallas" if jax.default_backend() == "tpu" else "naive"
    except Exception:  # pragma: no cover - backend probe failure
        return "naive"


SERVE_PLAN = ParallelPlan(fsdp=False, remat="full",
                          attn_impl=_default_attn_impl(),
                          kv_cache="replicated")


@dataclass
class _Lane:
    """An in-flight chunked prefill riding the decode batch's lane rows.

    prefill_chunk is a *token budget* shared by every admitting request
    (Sarathi-style): each step the budget rows are packed FIFO across the
    open lanes, so several short prompts can prefill in one step while a
    long prompt streams through in chunks."""
    slot: int
    req: Request
    pos: int = 0  # prompt tokens consumed so far
    take: int = 0  # rows granted this step
    last_row: int = 0  # row of the chunk's final token (first-token source)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Pytree, *,
                 num_slots: int = 4, prompt_len: int = 32, max_gen: int = 32,
                 kv: str = "paged", block_size: int = 16,
                 kv_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 plan: Optional[ParallelPlan] = None, mesh=None,
                 clock: Optional[Clock] = None,
                 metrics_window_s: float = 10.0):
        assert kv in ("paged", "slot"), kv
        self.cfg = cfg
        self.params = params
        self.kv = kv
        self.prompt_len = prompt_len
        self.max_gen = max_gen
        self.clock = clock or ManualClock()
        env = Env(mesh=mesh, plan=plan or SERVE_PLAN)
        self.env = env
        if kv == "paged":
            self.pool = BlockManager(cfg, env, num_slots=num_slots,
                                     prompt_len=prompt_len, max_gen=max_gen,
                                     block_size=block_size,
                                     num_blocks=kv_blocks)
            kinds = set(cfg.block_pattern) | set(cfg.pattern_tail)
            # recurrent state rows can't parallelize a prompt chunk inside
            # one step, and window-ring writes would wrap onto each other
            # within a chunk (rows p and p+w share ring slot p%w); both
            # admit via batch-1 prefill + paged insert instead
            chunk_ok = not (kinds & set(RECURRENT_KINDS)) \
                and "local" not in kinds
            if prefill_chunk is None:
                prefill_chunk = prompt_len if chunk_ok else 0
            if prefill_chunk and not chunk_ok:
                raise ValueError(
                    f"{cfg.name}: chunked prefill needs attention-only "
                    "blocks without sliding windows (recurrent state is "
                    "sequential over the prompt; ring writes wrap within "
                    "a chunk)")
            self._decode = jax.jit(St.make_paged_decode_step(cfg, env),
                                   donate_argnums=(1,))
        else:
            self.pool = SlotPool(cfg, env, num_slots=num_slots,
                                 prompt_len=prompt_len, max_gen=max_gen)
            prefill_chunk = 0
            self._decode = jax.jit(St.make_fused_decode_step(cfg, env),
                                   donate_argnums=(1,))
        self.prefill_chunk = int(prefill_chunk)
        self.queue = RequestQueue()
        self.metrics = ServingMetrics(window_s=metrics_window_s)
        self._prefill = jax.jit(St.make_prefill_step(cfg, env))
        self._lanes: List[_Lane] = []
        # device [T] int32: last step's fused argmax. Seeded at num_slots so
        # the step's (rows, prev-rows) shape pair cycles through its <= 4
        # combinations deterministically — a two-request warm trace compiles
        # them all (benchmarks warm exactly that way).
        self._tok_prev = jnp.zeros((num_slots,), jnp.int32)
        self._row_src: Dict[int, int] = {}  # slot -> row in _tok_prev
        self._fresh: Dict[int, int] = {}  # slot -> host-known next token
        self._inflight: Dict[int, Request] = {}  # rid -> request
        self.completed: List[Request] = []
        self.decode_steps = 0

    # -- state -----------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return bool(self._inflight)

    def pending(self) -> int:
        return len(self.queue)

    def drained(self) -> bool:
        return not self.busy and not self.pending()

    def submit(self, requests: Sequence[Request]) -> None:
        for r in requests:
            if len(r.prompt) != self.prompt_len:
                raise ValueError(
                    f"request {r.rid}: prompt length {len(r.prompt)} != "
                    f"engine prompt_len {self.prompt_len} (pad the trace)")
            if r.gen_len > self.max_gen:
                raise ValueError(f"request {r.rid}: gen_len {r.gen_len} > "
                                 f"engine max_gen {self.max_gen}")
            self.queue.push(r)

    # -- scheduler iteration ------------------------------------------------------
    def step(self) -> Dict[str, float]:
        """Admit arrivals, run one fused decode step over the mixed batch
        (+ prefill lanes), retire finished requests. Returns the metrics
        snapshot (what a node would publish)."""
        now = self.clock.now()
        self._admit_ready(now)

        active = self.pool.active_slots()
        lanes = self._lanes
        if not active and not lanes:
            return self.snapshot()

        # pack the prefill token budget FIFO across open lanes
        N = self.pool.num_slots
        budget = self.prefill_chunk
        for lane in lanes:
            lane.take = min(budget, self.prompt_len - lane.pos)
            budget -= lane.take
        lane_rows = self.prefill_chunk if lanes else 0
        T = N + lane_rows
        meta = np.zeros((3, T), np.int32)  # tok_src / fresh / cur_len
        meta[0, :] = -1
        paged = self.kv == "paged"
        if paged:
            tbl_g = np.zeros((T, self.pool.table.shape[1]), np.int32)
            tbl_l = np.zeros((T, self.pool.table_local.shape[1]), np.int32)
        for slot in active:
            info = self.pool.info(slot)
            meta[2, slot] = info.cur_len
            if paged:
                self.pool.ensure(slot, info.cur_len)
                tbl_g[slot] = self.pool.table[slot]
                tbl_l[slot] = self.pool.table_local[slot]
            if slot in self._fresh:
                meta[0, slot] = -1
                meta[1, slot] = self._fresh.pop(slot)
            else:
                meta[0, slot] = self._row_src.pop(slot, slot)
        row = N
        for lane in lanes:
            if lane.take <= 0:
                continue
            self.pool.ensure(lane.slot, lane.pos + lane.take - 1)
            sl = slice(row, row + lane.take)
            meta[1, sl] = lane.req.prompt[lane.pos:lane.pos + lane.take]
            meta[2, sl] = np.arange(lane.pos, lane.pos + lane.take)
            tbl_g[sl] = self.pool.table[lane.slot]
            tbl_l[sl] = self.pool.table_local[lane.slot]
            row += lane.take
            lane.last_row = row - 1

        tables = {"global": jnp.asarray(tbl_g)} if paged else None
        if paged and self.pool.has_local:
            tables["local"] = jnp.asarray(tbl_l)
        prev = self._tok_prev
        if paged:
            nxt_dev, self.pool.caches = self._decode(
                self.params, self.pool.caches, prev, jnp.asarray(meta),
                tables)
        else:
            nxt_dev, self.pool.caches = self._decode(
                self.params, self.pool.caches, prev, jnp.asarray(meta))
        self._tok_prev = nxt_dev
        nxt = np.asarray(nxt_dev)  # the one host transfer per step
        self.decode_steps += 1

        emitted = 0
        for slot in active:
            info = self.pool.advance(slot)
            req = self._inflight[info.rid]
            req.tokens.append(int(nxt[slot]))
            emitted += 1
            if self.pool.finished(slot):
                self._retire(slot, now)
        still_open: List[_Lane] = []
        for lane in lanes:
            lane.pos += lane.take
            if lane.pos < self.prompt_len:
                still_open.append(lane)
                continue
            slot = lane.slot
            self.pool.finish_prefill(slot)
            req = lane.req
            req.t_first_token = now
            req.tokens.append(int(nxt[lane.last_row]))
            self.metrics.record_first_token(req, now)
            # next step, this slot's input token comes from the lane row
            self._row_src[slot] = lane.last_row
            emitted += 1
            if self.pool.finished(slot):
                self._retire(slot, now)
        self._lanes = still_open
        if emitted:
            self.metrics.record_tokens(now, emitted)
        return self.snapshot()

    # -- admission ----------------------------------------------------------------
    def _admit_ready(self, now: float) -> None:
        if self.kv == "slot":
            while self.pool.free_slot_count:
                req = self.queue.pop_ready(now)
                if req is None:
                    break
                self._admit_classic(self.pool.acquire_slot(), req, now)
            return
        if self.prefill_chunk:
            # open lanes while the step's token budget can still reach a
            # new prompt (bounds admitted-but-starved lanes to ~1)
            while (sum(self.prompt_len - l.pos for l in self._lanes)
                   < self.prefill_chunk):
                req = self.queue.peek_ready(now)
                if req is None or not self.pool.can_admit(req.gen_len):
                    return  # block/slot exhaustion -> queue backpressure
                self.queue.pop_ready(now)
                slot = self.pool.admit(req.rid, req.gen_len, prefilling=True)
                req.t_admit = now
                self._inflight[req.rid] = req
                self._lanes.append(_Lane(slot=slot, req=req))
            return
        while True:
            req = self.queue.peek_ready(now)
            if req is None or not self.pool.can_admit(req.gen_len):
                break
            self.queue.pop_ready(now)
            self._admit_classic(self.pool.admit(req.rid, req.gen_len), req,
                                now)

    def _admit_classic(self, slot: int, req: Request, now: float) -> None:
        """Batch-1 prefill + cache insert (slot pool, and paged archs with
        recurrent state). The first token is argmax'd from the prefill
        logits and fed to the same step's decode via the fresh-token path."""
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompt)[None]})
        self.pool.insert(slot, req.rid, caches, req.gen_len)
        first = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
        req.t_admit = now
        req.t_first_token = now
        req.tokens.append(first)
        self._fresh[slot] = first
        self._inflight[req.rid] = req
        self.metrics.record_first_token(req, now)
        self.metrics.record_tokens(now, 1)
        if self.pool.finished(slot):  # gen_len == 1: prefill was the job
            self._retire(slot, now)

    def _retire(self, slot: int, now: float) -> None:
        rid = self.pool.rid_of(slot)
        req = self._inflight.pop(rid)
        req.t_done = now
        self.completed.append(req)
        self.metrics.record_done(req, now)
        self.pool.evict(slot)
        self._row_src.pop(slot, None)
        self._fresh.pop(slot, None)

    # -- reporting ----------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        now = self.clock.now()
        kwargs = {}
        if self.kv == "paged":
            kwargs["kv_block_occupancy"] = self.pool.block_occupancy
        return self.metrics.snapshot(now, queue_depth=self.queue.depth(now),
                                     slot_occupancy=self.pool.occupancy,
                                     **kwargs)

    def results(self) -> Dict[int, List[int]]:
        """rid -> generated tokens, for every completed request."""
        return {r.rid: list(r.tokens) for r in self.completed}


def run_to_completion(engine: ServingEngine,
                      requests: Sequence[Request] = (), *,
                      dt: float = 0.05, max_steps: int = 100_000,
                      on_step: Optional[Callable[[int, Dict[str, float]],
                                                 None]] = None
                      ) -> Dict[int, List[int]]:
    """Standalone drain loop (no cluster): step the engine, advance the
    clock by `dt` between iterations. VirtualCluster.serve() is the
    cluster-integrated version of this loop."""
    engine.submit(requests)
    steps = 0
    while not engine.drained() and steps < max_steps:
        snap = engine.step()
        engine.clock.sleep(dt)
        if on_step is not None:
            on_step(steps, snap)
        steps += 1
    if not engine.drained():
        raise RuntimeError(f"serve did not drain in {max_steps} steps")
    return engine.results()
