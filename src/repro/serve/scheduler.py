"""ServingEngine — continuous batching over a slot-pooled KV cache.

One scheduler iteration (step()):

  1. admit: while a KV slot is free and a request has arrived, run the
     batch-1 prefill, write its cache into the slot (jitted, traced slot
     index — no re-compile), and emit the request's first token;
  2. decode: one jitted step over the *whole* pool — a [num_slots] cur_len
     vector lets every slot attend and write at its own depth, so requests
     join and leave the running batch freely;
  3. retire: slots whose request hit gen_len free up and their latency is
     recorded.

The engine never re-jits after construction: prefill is pinned to
(1, prompt_len), decode to (num_slots, 1). Greedy (argmax) decoding keeps
continuous-batched output token-for-token equal to the one-shot
serve_batch baseline — the correctness bar tests/test_serving.py holds it to.

The clock is injected: tests and the simulated cluster drive a ManualClock
(deterministic arrival replay); nothing here sleeps.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelPlan
from repro.core.clock import Clock, ManualClock
from repro.launch import steps as St
from repro.models.env import Env
from repro.serve.metrics import ServingMetrics
from repro.serve.request import Request, RequestQueue
from repro.serve.slots import SlotPool

Pytree = Any

SERVE_PLAN = ParallelPlan(fsdp=False, remat="full", attn_impl="naive",
                          kv_cache="replicated")


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Pytree, *,
                 num_slots: int = 4, prompt_len: int = 32, max_gen: int = 32,
                 plan: Optional[ParallelPlan] = None, mesh=None,
                 clock: Optional[Clock] = None,
                 metrics_window_s: float = 10.0):
        self.cfg = cfg
        self.params = params
        self.prompt_len = prompt_len
        self.max_gen = max_gen
        self.clock = clock or ManualClock()
        env = Env(mesh=mesh, plan=plan or SERVE_PLAN)
        self.env = env
        self.pool = SlotPool(cfg, env, num_slots=num_slots,
                             prompt_len=prompt_len, max_gen=max_gen)
        self.queue = RequestQueue()
        self.metrics = ServingMetrics(window_s=metrics_window_s)
        self._prefill = jax.jit(St.make_prefill_step(cfg, env))
        self._decode = jax.jit(St.make_slot_decode_step(cfg, env),
                               donate_argnums=(1,))
        self._last_tok = np.zeros((num_slots, 1), np.int32)
        self._inflight: Dict[int, Request] = {}  # rid -> request
        self.completed: List[Request] = []
        self.decode_steps = 0

    # -- state -----------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return bool(self._inflight)

    def pending(self) -> int:
        return len(self.queue)

    def drained(self) -> bool:
        return not self.busy and not self.pending()

    def submit(self, requests: Sequence[Request]) -> None:
        for r in requests:
            if len(r.prompt) != self.prompt_len:
                raise ValueError(
                    f"request {r.rid}: prompt length {len(r.prompt)} != "
                    f"engine prompt_len {self.prompt_len} (pad the trace)")
            if r.gen_len > self.max_gen:
                raise ValueError(f"request {r.rid}: gen_len {r.gen_len} > "
                                 f"engine max_gen {self.max_gen}")
            self.queue.push(r)

    # -- scheduler iteration ------------------------------------------------------
    def step(self) -> Dict[str, float]:
        """Admit arrivals, step the mixed decode batch once, retire finished
        requests. Returns the metrics snapshot (what a node would publish)."""
        now = self.clock.now()
        while True:
            free = self.pool.free_slots()
            if not free:
                break
            req = self.queue.pop_ready(now)
            if req is None:
                break
            self._admit(free[0], req, now)

        active = self.pool.active_slots()
        if active:
            logits, self.pool.caches = self._decode(
                self.params, self.pool.caches, jnp.asarray(self._last_tok),
                jnp.asarray(self.pool.cur_lens()))
            nxt = np.asarray(jnp.argmax(logits[:, :self.cfg.vocab_size], -1)
                             ).astype(np.int32)
            self.decode_steps += 1
            emitted = 0
            for slot in active:
                info = self.pool.advance(slot)
                req = self._inflight[info.rid]
                req.tokens.append(int(nxt[slot]))
                self._last_tok[slot, 0] = nxt[slot]
                emitted += 1
                if self.pool.finished(slot):
                    self._retire(slot, now)
            self.metrics.record_tokens(now, emitted)
        return self.snapshot()

    def _admit(self, slot: int, req: Request, now: float) -> None:
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompt)[None]})
        self.pool.insert(slot, req.rid, caches, req.gen_len)
        first = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
        req.t_admit = now
        req.t_first_token = now
        req.tokens.append(first)
        self._last_tok[slot, 0] = first
        self._inflight[req.rid] = req
        self.metrics.record_first_token(req, now)
        self.metrics.record_tokens(now, 1)
        if self.pool.finished(slot):  # gen_len == 1: prefill was the job
            self._retire(slot, now)

    def _retire(self, slot: int, now: float) -> None:
        rid = self.pool.rid_of(slot)
        req = self._inflight.pop(rid)
        req.t_done = now
        self.completed.append(req)
        self.metrics.record_done(req, now)
        self.pool.evict(slot)

    # -- reporting ----------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        now = self.clock.now()
        return self.metrics.snapshot(now, queue_depth=self.queue.depth(now),
                                     slot_occupancy=self.pool.occupancy)

    def results(self) -> Dict[int, List[int]]:
        """rid -> generated tokens, for every completed request."""
        return {r.rid: list(r.tokens) for r in self.completed}


def run_to_completion(engine: ServingEngine,
                      requests: Sequence[Request] = (), *,
                      dt: float = 0.05, max_steps: int = 100_000,
                      on_step: Optional[Callable[[int, Dict[str, float]],
                                                 None]] = None
                      ) -> Dict[int, List[int]]:
    """Standalone drain loop (no cluster): step the engine, advance the
    clock by `dt` between iterations. VirtualCluster.serve() is the
    cluster-integrated version of this loop."""
    engine.submit(requests)
    steps = 0
    while not engine.drained() and steps < max_steps:
        snap = engine.step()
        engine.clock.sleep(dt)
        if on_step is not None:
            on_step(steps, snap)
        steps += 1
    if not engine.drained():
        raise RuntimeError(f"serve did not drain in {max_steps} steps")
    return engine.results()
