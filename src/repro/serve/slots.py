"""SlotPool — a fixed-capacity, slot-addressed KV-cache backend.

The pool owns one init_cache() pytree whose batch dim is the slot dim, plus
host-side bookkeeping (which request occupies which slot, each slot's write
position). Inserting a prefilled request and stepping the mixed decode batch
are both jitted once at pool shape — admission never re-compiles, which is
what lets new requests join a running decode batch (continuous batching).

This is the `kind="slot"` KVBackend (serve/kv.py): worst-case
prompt_len+max_gen reservation per slot, kept as the measured baseline for
the paged BlockManager and as a fallback. It cannot stream prompts through
decode lane rows (chunk_prefill_ok=False — a contiguous cache has no
per-row tables to alias a chunk onto), so admission is always classic
batch-1 prefill + insert.

All device work is functional: insert/evict/decode return nothing but swap
the pool's cache pytree; the engine owns the only reference (buffers are
donated through the jitted ops, so a pool slot update does not copy the
pool).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import steps as St
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve.kv import shared_jit

Pytree = Any

FREE = -1  # slot_rid value for an unoccupied slot


@dataclass
class SlotInfo:
    rid: int
    cur_len: int  # next decode write position for this slot
    tokens_done: int  # generated so far (prefill emits the first)
    gen_len: int


class SlotPool:
    kind = "slot"
    chunk_prefill_ok = False

    def __init__(self, cfg: ModelConfig, env: Env, *, num_slots: int,
                 prompt_len: int, max_gen: int):
        if cfg.family == "vlm" or cfg.is_encdec:
            raise ValueError(
                f"{cfg.name}: continuous batching supports decoder-only "
                "archs (vlm/enc-dec prefill carries extra modalities)")
        if "local" in cfg.block_pattern + cfg.pattern_tail:
            # sliding-window blocks keep a ring-aligned cache of size
            # min(window, seq); growing a prompt-sized ring to the pool's
            # ring size would scramble the slot=pos%w alignment
            raise ValueError(
                f"{cfg.name}: sliding-window ('local') blocks are not yet "
                "supported by the slot pool (ring-buffer caches cannot be "
                "grown after prefill)")
        self.cfg = cfg
        self.env = env
        self.num_slots = num_slots
        self.prompt_len = prompt_len
        self.max_gen = max_gen
        self.caches: Pytree = Mo.init_cache(cfg, env, num_slots,
                                            prompt_len + max_gen)
        self._slots: List[Optional[SlotInfo]] = [None] * num_slots
        self._free: Deque[int] = deque(range(num_slots))  # O(1) admission
        # grow the batch-1 prefill cache to pool seq length, then write it
        # into the slot — one jitted op, slot index traced (no re-jit per slot)
        self._insert = shared_jit(
            ("slot_insert", cfg, max_gen),
            lambda: (lambda pool, c, slot: Mo.cache_insert_slot(
                pool, Mo.grow_caches(c, max_gen), slot)),
            donate_argnums=(0,))
        self._evict = shared_jit(("slot_evict", cfg),
                                 lambda: Mo.cache_evict_slot,
                                 donate_argnums=(0,))
        # two fused-step variants: an all-greedy batch runs the pure-argmax
        # step (no mask/Gumbel work); any sampling row selects the sampler
        self._decode = {
            s: shared_jit(
                ("slot_decode", cfg, env.plan, env.mesh, prompt_len, s),
                lambda s=s: St.make_fused_decode_step(cfg, env,
                                                      prompt_len=prompt_len,
                                                      sample=s),
                donate_argnums=(1,))
            for s in (False, True)}
        # row-indirected variant (T != num_slots rows, row_slots maps rows
        # onto cache slots): what speculative verify rows ride
        self._decode_spec = {
            s: shared_jit(
                ("slot_decode_spec", cfg, env.plan, env.mesh, prompt_len, s),
                lambda s=s: St.make_spec_decode_step(cfg, env,
                                                     prompt_len=prompt_len,
                                                     sample=s),
                donate_argnums=(1,))
            for s in (False, True)}

    # -- occupancy ---------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    @property
    def free_slot_count(self) -> int:
        return len(self._free)

    def acquire_slot(self) -> int:
        """Pop a free slot in O(1) (the admission loop used to rescan
        free_slots() per admitted request — O(n^2) under bursts)."""
        return self._free.popleft()

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    def occupied_slots(self) -> List[int]:
        return self.active_slots()

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self.free_slots()) / max(self.num_slots, 1)

    @property
    def free_capacity(self) -> int:
        """Absolute admission headroom: free slots (each slot is a full
        worst-case reservation here)."""
        return len(self._free)

    def info(self, slot: int) -> Optional[SlotInfo]:
        return self._slots[slot]

    def rid_of(self, slot: int) -> int:
        s = self._slots[slot]
        return FREE if s is None else s.rid

    # -- admission / retirement --------------------------------------------
    def can_admit(self, gen_len: int, *, prompt=None) -> bool:
        return bool(self._free)  # no prefix cache: prompt can't help

    def preempt_frees(self, slot: int, gen_len: int, *,
                      prompt=None) -> bool:
        """A slot is worst-case reserved, so evicting any slot admits any
        request the engine already validated against max_gen."""
        return True

    def admit(self, rid: int, gen_len: int, *, prefilling: bool = False,
              prompt=None) -> int:
        """Bind a free slot for `rid`. The slot stays empty (info=None)
        until insert() writes the prefilled cache — the slot pool has no
        chunked-prefill path, so `prefilling` must be False. A contiguous
        per-slot cache has nothing to share, so `prompt` is ignored."""
        assert not prefilling, "slot pool has no chunked-prefill lanes"
        return self.acquire_slot()

    def cached_prefix_len(self, slot: int) -> int:
        """No prefix cache: every prompt position prefills."""
        return 0

    def probe_prefix(self, prompt) -> int:
        """No prefix cache: a router probe can never hit here."""
        return 0

    def release(self) -> None:
        """Retire the pool (replica scale-down): every slot must be back
        on the free list — a leak raises — then the cache is dropped."""
        live = [i for i, s in enumerate(self._slots) if s is not None]
        if live:
            raise RuntimeError(f"release with occupied slots {live}")
        if len(self._free) != self.num_slots:
            raise RuntimeError(
                f"release leaked {self.num_slots - len(self._free)} slots "
                "(acquired but never evicted)")
        self.caches = None

    def insert(self, slot: int, rid: int, prefill_caches: Pytree,
               gen_len: int) -> None:
        """Bind `rid` to `slot` and write its prefilled (batch-1, length
        prompt_len) cache into the pool."""
        assert self._slots[slot] is None, f"slot {slot} occupied"
        if slot in self._free:  # direct pool use (tests): claim this slot
            self._free.remove(slot)
        self.caches = self._insert(self.caches, prefill_caches,
                                   jnp.asarray(slot, jnp.int32))
        self._slots[slot] = SlotInfo(rid=rid, cur_len=self.prompt_len,
                                     tokens_done=1, gen_len=gen_len)

    def ensure(self, slot: int, pos: int) -> None:
        """Capacity is reserved wholesale at admission — nothing to grow."""

    def truncate(self, slot: int, n: int) -> None:
        """Speculative rollback is free on a reserved contiguous cache:
        positions past the write cursor are never attended (attention
        depth is cur_len) and the sequential decode overwrites them before
        the cursor ever reaches them."""

    def finish_prefill(self, slot: int) -> SlotInfo:
        raise NotImplementedError("slot pool has no chunked-prefill lanes")

    def evict(self, slot: int, *, zero: bool = False) -> None:
        """Free `slot`. Insert fully overwrites a slot, so zeroing is only
        for hygiene (tests assert evicted slots hold no stale KV)."""
        if self._slots[slot] is not None:
            self._free.append(slot)
        self._slots[slot] = None
        if zero:
            self.caches = self._evict(self.caches,
                                      jnp.asarray(slot, jnp.int32))

    # -- host swap tier: not supported (worst-case reservation has no
    # partial progress worth preserving at block granularity); the engine's
    # swap path falls back to restart preemption on False ---------------------
    def swap_out(self, slot: int) -> bool:
        return False

    def has_swapped(self, rid: int) -> bool:
        return False

    def can_resume(self, rid: int) -> bool:
        return False

    def plan_resume(self, rid: int) -> bool:
        return False

    def cancel_resume_plans(self) -> None:
        pass

    def swap_in(self, rid: int) -> int:
        raise NotImplementedError("slot pool has no host swap tier")

    def drop_swapped(self, rid: int) -> None:
        pass

    # -- the fused step -------------------------------------------------------
    def decode(self, params, prev_tok, meta_i, meta_f, row_slots, *,
               sample: bool):
        """One fused step over the contiguous pool. The classic shape
        (T == num_slots rows) addresses slots directly (row == slot) and
        ignores row_slots; a wider batch — speculative verify rows stacked
        past the slots — runs the row-indirected step, where row_slots
        maps each row onto its slot's cache row (-1 masks)."""
        if meta_i.shape[1] != self.num_slots:
            nxt, self.caches = self._decode_spec[sample](
                params, self.caches, prev_tok, jnp.asarray(meta_i),
                jnp.asarray(meta_f), jnp.asarray(row_slots))
            return nxt
        del row_slots
        nxt, self.caches = self._decode[sample](
            params, self.caches, prev_tok, jnp.asarray(meta_i),
            jnp.asarray(meta_f))
        return nxt

    # -- decode-batch views ---------------------------------------------------
    def advance(self, slot: int) -> SlotInfo:
        """Record one decoded token for `slot`; returns the updated info."""
        s = self._slots[slot]
        assert s is not None
        s.cur_len += 1
        s.tokens_done += 1
        return s

    def finished(self, slot: int) -> bool:
        s = self._slots[slot]
        return s is not None and s.tokens_done >= s.gen_len

    # -- reporting ----------------------------------------------------------
    def metrics(self) -> Dict[str, float]:
        return {}

    def describe(self) -> str:
        return (f"slot KV: {self.num_slots} slots x "
                f"{self.prompt_len + self.max_gen} reserved tokens")

    # -- introspection (tests) ----------------------------------------------
    def read_slot(self, slot: int) -> Pytree:
        return Mo.cache_read_slot(self.caches, jnp.asarray(slot, jnp.int32))
