"""BlockManager — a paged KV cache: global block pool + per-request tables.

The slot pool (serve/slots.py) reserves prompt_len + max_gen KV per slot for
a request's whole lifetime, so one long-tail gen length pins worst-case
memory for everyone. The BlockManager instead owns a global pool of
fixed-size KV blocks (Mo.init_paged_cache) and a host-side [num_slots, MB]
block table per request; blocks are allocated on demand as a request's
cur_len crosses block boundaries and returned to an O(1) free list at
retirement, so resident KV tracks what requests actually wrote — at a fixed
HBM budget the pool admits 2-4x the concurrent requests of slot reservation.

Admission is gated by *reservation*: a request reserves (but does not yet
allocate) the blocks its declared gen_len can ever need, so on-demand
allocation can never deadlock mid-decode and block exhaustion surfaces as
clean queue backpressure at admit time.

Physical block 0 is the null block: never allocated, it absorbs the writes
of masked rows (free slots / idle prefill lanes) in the fused decode step.

Sliding-window ('local') layers get their own window-sized tables: a ring
of ceil(w/bs) blocks written at pos % w — softmax over keys is permutation-
invariant and RoPE is applied at write time, so the ring never needs
unscrambling (this is what lets recurrentgemma-style archs serve here while
the slot pool still rejects them).

Reclaim ordering is hit-count-weighted: when the free list runs dry, the
retained (refcount-0, still-registered) block with the fewest lifetime
prefix-cache hits is unregistered first, LRU insertion order breaking
ties — a hot system prompt outlives a parade of one-off templates. A
`max_shared_fraction` residency cap bounds how much of the pool the
prefix index may retain at all, so one tenant's template churn cannot
monopolize a replica's pool (blocks past the cap simply never register;
they free normally at retirement).

Prefix caching (copy-on-write sharing): real multi-user traffic is
dominated by shared prompt prefixes (system prompts, few-shot templates).
Full prompt blocks are content-addressed by a per-block hash *chain*
(h_j = H(h_{j-1} || tokens_j), vLLM-style, so a block hash commits to its
whole prefix); a request whose prompt chain hits the index admits with its
table pointing at the shared physical blocks — those prefill positions are
never recomputed (the engine starts its lanes at `cached_len`). Sharing is
refcounted: a block returns to the pool only when its last reference drops,
and registered blocks whose refcount hits zero are *retained* in an LRU
reclaim list (still KV-valid, still admission capacity) until the free list
runs dry. The first write into a still-shared block — only ever the
boundary block of a fully-cached prompt — triggers copy-on-write into a
fresh block (Mo.make_paged_copy), so two requests sharing a prefix can
never observe each other's writes. The double-free guard extends to
refcounts: freeing through a table whose entry is already at refcount zero
raises before the free list is poisoned.
"""
from __future__ import annotations

import hashlib
from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import steps as St
from repro.models import model as Mo
from repro.models.env import Env
from repro.serve.kv import shared_jit

Pytree = Any

FREE = -1

RECURRENT_KINDS = ("rglru", "rwkv")


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclass
class PagedSlot:
    rid: int
    cur_len: int  # next decode write position
    tokens_done: int
    gen_len: int
    plen: int = 0  # the request's own prompt length (0: engine prompt_len)
    prefilling: bool = False  # still consuming prompt chunks (lane rows)
    alloc_g: int = 0  # global-table entries bound so far (shared + private)
    alloc_l: int = 0  # local-table blocks allocated so far
    reserved: int = 0  # blocks reserved but not yet allocated
    cached_len: int = 0  # prompt tokens served from the prefix cache
    shared_g: int = 0  # leading table entries referencing shared blocks
    hashes: Tuple[bytes, ...] = ()  # prompt block hash chain (full blocks)


@dataclass
class SwapRecord:
    """One swapped-out request: its block KV pulled to host numpy plus the
    PagedSlot bookkeeping needed to rebuild the slot on swap-in."""
    rid: int
    payload: Any  # numpy pytree congruent to the device pool, block dim = n
    n_blocks: int
    nbytes: int
    cur_len: int
    tokens_done: int
    gen_len: int
    reserved: int
    cached_len: int
    alloc_g: int
    alloc_l: int
    plen: int = 0


class HostSwapPool:
    """Host-side (numpy) KV block pool — the swap tier under the device
    pool. Preemption copies a victim's blocks out instead of discarding
    them; re-admission scatters them back and decoding resumes from the
    swap point bit-identically (no recompute). One pool is shared across a
    ReplicaSet's replicas (host RAM is node-local), so a request drained
    off one replica can restore on another.

    Accounting mirrors the device pool's: `budget_blocks` caps host
    residency (a full budget makes swap_out fall back to restart
    preemption), and the last backend to detach leak-checks that every
    swapped request was either restored or dropped."""

    def __init__(self, budget_blocks: Optional[int] = None):
        if budget_blocks is not None and budget_blocks < 0:
            raise ValueError(f"budget_blocks must be >= 0 or None, got "
                             f"{budget_blocks}")
        self.budget_blocks = budget_blocks  # None = unbounded
        self._records: Dict[int, SwapRecord] = {}
        self._attached = 0
        # resume-plan ownership: at most one backend fleet-wide holds a
        # standing re-admission reservation for a swapped rid (two
        # replicas both reserving the same victim's footprint would
        # double-commit fleet capacity for one resume)
        self._planned: Dict[int, Any] = {}

    @property
    def blocks_resident(self) -> int:
        return sum(r.n_blocks for r in self._records.values())

    @property
    def bytes_resident(self) -> int:
        return sum(r.nbytes for r in self._records.values())

    def can_store(self, n_blocks: int) -> bool:
        return (self.budget_blocks is None
                or self.blocks_resident + n_blocks <= self.budget_blocks)

    def store(self, rec: SwapRecord) -> None:
        assert rec.rid not in self._records, \
            f"rid {rec.rid} is already swapped out"
        assert self.can_store(rec.n_blocks), "host swap budget exhausted"
        self._records[rec.rid] = rec

    def has(self, rid: int) -> bool:
        return rid in self._records

    def peek(self, rid: int) -> SwapRecord:
        return self._records[rid]

    def take(self, rid: int) -> SwapRecord:
        """Remove and return `rid`'s record (swap-in frees host residency)."""
        self._planned.pop(rid, None)
        return self._records.pop(rid)

    def drop(self, rid: int) -> None:
        """Discard a swapped request (cancelled / restarted): its host
        blocks free without a restore."""
        self._planned.pop(rid, None)
        self._records.pop(rid, None)

    # -- resume-plan ownership ------------------------------------------------
    def plan(self, rid: int, owner: Any) -> None:
        cur = self._planned.get(rid)
        assert cur is None or cur is owner, \
            f"rid {rid} already has a resume plan on another backend"
        self._planned[rid] = owner

    def planner(self, rid: int) -> Optional[Any]:
        return self._planned.get(rid)

    def unplan(self, rid: int) -> None:
        self._planned.pop(rid, None)

    def attach(self) -> None:
        self._attached += 1

    def detach(self) -> None:
        """A backend released its device pool. When the last one detaches,
        the host pool must be empty — a swapped request nobody can ever
        restore is a leak, same class of bug as a lost device block."""
        self._attached -= 1
        if self._attached <= 0 and self._records:
            held = sorted(self._records)
            raise RuntimeError(
                f"host swap pool leaked {len(held)} swapped request(s) "
                f"{held} ({self.blocks_resident} blocks) at last detach")


class BlockManager:
    kind = "paged"
    _quant = False  # QuantBlockManager flips this: int8 pool + scales

    def __init__(self, cfg: ModelConfig, env: Env, *, num_slots: int,
                 prompt_len: int, max_gen: int, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 max_shared_fraction: float = 1.0,
                 swap_pool: Optional[HostSwapPool] = None):
        if cfg.family == "vlm" or cfg.is_encdec:
            raise ValueError(
                f"{cfg.name}: continuous batching supports decoder-only "
                "archs (vlm/enc-dec prefill carries extra modalities)")
        kinds = set(cfg.block_pattern) | set(cfg.pattern_tail)
        if not kinds <= set(Mo.PAGEABLE_KINDS) | set(RECURRENT_KINDS):
            raise ValueError(f"{cfg.name}: kinds {sorted(kinds)} have no "
                             "paged-cache layout")
        self.cfg = cfg
        self.env = env
        self.num_slots = num_slots
        self.prompt_len = prompt_len
        self.max_gen = max_gen
        self.block_size = block_size
        self.window = cfg.local_window
        self.has_global = bool(kinds & {"attn", "moe"})
        self.has_local = "local" in kinds
        # recurrent state rows pin the decode batch to slot == row
        self.has_state = bool(kinds & set(RECURRENT_KINDS))
        # recurrent state can't parallelize a prompt chunk inside one step,
        # and window-ring writes would wrap onto each other within a chunk
        # (rows p and p+w share ring slot p%w); both admit via batch-1
        # prefill + paged insert instead
        self.chunk_prefill_ok = not self.has_state and not self.has_local
        max_kv = prompt_len + max_gen  # last written pos < prompt+gen-1
        bs = block_size
        self.mb_global = _ceil_div(max_kv, bs) if self.has_global else 0
        self.mb_local = (_ceil_div(min(self.window, max_kv), bs)
                         if self.has_local else 0)
        worst = num_slots * (self.mb_global + self.mb_local)
        self.num_blocks = (num_blocks if num_blocks is not None
                           else worst + 1)  # +1: the null block
        if self.num_blocks < 1 + self.mb_global + self.mb_local:
            raise ValueError(
                f"num_blocks={self.num_blocks} cannot hold even one request "
                f"({self.mb_global}+{self.mb_local} blocks + null)")
        self.caches: Pytree = Mo.init_paged_cache(
            cfg, env, num_slots, self.num_blocks, bs, quant=self._quant)
        # -- host swap tier (tentpole b): preemption copies victim blocks
        # out instead of discarding; the pool may be shared fleet-wide
        self.swap_pool = swap_pool
        if swap_pool is not None:
            swap_pool.attach()
        self._swap_out_bytes = 0
        self._swap_in_bytes = 0
        self._swapped_blocks = 0  # cumulative blocks this backend swapped
        # swap-aware admission: rid -> blocks this backend holds reserved
        # for the rid's planned swap-in (counted in _reserved_total)
        self._resume_plans: Dict[int, int] = {}
        # host-side tables: row per slot, 0 = unallocated (null block)
        self.table = np.zeros((num_slots, max(self.mb_global, 1)), np.int32)
        self.table_local = np.zeros((num_slots, max(self.mb_local, 1)),
                                    np.int32)
        self._slots: List[Optional[PagedSlot]] = [None] * num_slots
        self._free_slots: Deque[int] = deque(range(num_slots))
        self._free_blocks: Deque[int] = deque(range(1, self.num_blocks))
        # mirror of _free_blocks for the O(1) double-free guard: a block id
        # returned twice would sit in the free list twice and get handed to
        # two requests, silently cross-writing their KV
        self._free_block_set: Set[int] = set(self._free_blocks)
        self._reserved_total = 0  # blocks promised to admitted requests
        # -- prefix cache state (sharing only applies to global tables; a
        # window ring rewrites positions in place and recurrent state is
        # not content-addressable, so those archs keep prefix_cache off)
        self.prefix_cache = (bool(prefix_cache) and self.has_global
                             and self.chunk_prefill_ok)
        if not 0.0 <= max_shared_fraction <= 1.0:
            raise ValueError(f"max_shared_fraction must be in [0, 1], got "
                             f"{max_shared_fraction}")
        self.max_shared_fraction = float(max_shared_fraction)
        self._ref = np.zeros(self.num_blocks, np.int64)  # table references
        self._cached: Dict[bytes, int] = {}   # prefix-chain hash -> block id
        self._hash_of: Dict[int, bytes] = {}  # registered block -> its hash
        self._hits: Dict[int, int] = {}  # registered block -> cache hits
        # registered blocks whose last reference dropped: still KV-valid,
        # still admission capacity. Values are insertion sequence numbers:
        # reclaim pops the fewest-hits entry, LRU breaking ties.
        self._reclaim: Dict[int, int] = {}
        self._reclaim_seq = 0
        self._hit_tokens = 0     # prompt tokens served from the cache
        self._lookup_tokens = 0  # prompt tokens probed at admission
        self._cow_copies = 0
        # one-entry probe memo: a backpressured queue head re-probes every
        # scheduler step, and one admission probes up to three times
        # (can_admit, admit's assert, admit) — don't re-hash the prompt
        # each time. Invalidated whenever the index changes.
        self._probe_memo: Optional[Tuple[bytes, tuple]] = None
        # shared_jit: N replicas built from the same config share these
        # compilations instead of re-tracing identical closures per pool
        self._insert = shared_jit(("paged_insert", cfg, bs),
                                  lambda: Mo.make_paged_insert(cfg, bs),
                                  donate_argnums=(0,))
        self._copy = shared_jit(("paged_copy", cfg),
                                lambda: Mo.make_paged_copy(cfg),
                                donate_argnums=(0,))
        self._evict = shared_jit(("paged_evict", cfg),
                                 lambda: Mo.make_paged_evict(cfg),
                                 donate_argnums=(0,))
        self._read = shared_jit(("paged_read", cfg),
                                lambda: Mo.make_paged_read(cfg))
        # two fused-step variants: an all-greedy batch runs the pure-argmax
        # step (no mask/Gumbel work); any sampling row selects the sampler
        self._decode = {
            s: shared_jit(
                ("paged_decode", cfg, env.plan, env.mesh, prompt_len, s),
                lambda s=s: St.make_paged_decode_step(cfg, env,
                                                      prompt_len=prompt_len,
                                                      sample=s),
                donate_argnums=(1,))
            for s in (False, True)}

    # -- sizing / admission math -------------------------------------------
    def blocks_for(self, gen_len: int, plen: Optional[int] = None) -> int:
        """Physical blocks a request with this gen_len can ever touch (its
        KV spans positions [0, plen + gen_len - 1)). `plen` defaults to
        the engine's prompt_len budget; chunked admissions pass the
        request's own prompt length so a short multi-turn opener doesn't
        reserve a full-length prompt's worst case."""
        kv = max((plen or self.prompt_len) + gen_len - 1, 1)
        n = _ceil_div(kv, self.block_size) if self.has_global else 0
        if self.has_local:
            n += _ceil_div(min(self.window, kv), self.block_size)
        return n

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1

    @property
    def free_unreserved(self) -> int:
        """Free + reclaimable (cache-retained, refcount 0) minus promised
        reservations — the capacity admission may still hand out."""
        return (len(self._free_blocks) + len(self._reclaim)
                - self._reserved_total)

    def _prompt_hashes(self, prompt) -> Tuple[bytes, ...]:
        """Content-hash chain over the prompt's *full* blocks. Each link
        commits to the whole prefix up to and including its block, so a
        single dict lookup per block matches vLLM's prefix trie. blake2b,
        not Python hash(): a collision here would silently serve one
        request another's KV."""
        bs = self.block_size
        toks = np.ascontiguousarray(np.asarray(prompt, np.int32))
        h = b"paged-prefix-root"
        out = []
        for j in range(len(toks) // bs):
            m = hashlib.blake2b(h, digest_size=16)
            m.update(toks[j * bs:(j + 1) * bs].tobytes())
            h = m.digest()
            out.append(h)
        return tuple(out)

    def _probe(self, prompt) -> Tuple[Tuple[bytes, ...], int, int, int]:
        """(hashes, shared, cached_len, cow): how much of `prompt` the
        cache already holds. cached_len is capped at prompt_len - 1 — the
        last prompt token always runs (its logits emit the first generated
        token), so a fully-cached prompt keeps exactly one lane row and its
        write into the shared boundary block is the copy-on-write case
        (cow=1 reserves the fresh block that copy will need)."""
        if prompt is None or not self.prefix_cache:
            return (), 0, 0, 0
        key = np.ascontiguousarray(np.asarray(prompt, np.int32)).tobytes()
        if self._probe_memo is not None and self._probe_memo[0] == key:
            return self._probe_memo[1]
        hashes = self._prompt_hashes(prompt)
        shared = 0
        for h in hashes:
            if h not in self._cached:
                break
            shared += 1
        cached_len = min(shared * self.block_size, len(prompt) - 1)
        cow = 1 if shared * self.block_size > cached_len else 0
        self._probe_memo = (key, (hashes, shared, cached_len, cow))
        return hashes, shared, cached_len, cow

    def can_admit(self, gen_len: int, *, prompt=None) -> bool:
        """With `prompt`, admission is prefix-aware: shared blocks cost no
        fresh capacity, except that resurrecting a reclaimable block (and
        the one copy-on-write block of a fully-cached prompt) re-commits
        physical capacity the reservation math must still cover."""
        if not self._free_slots:
            return False
        hashes, shared, _, cow = self._probe(prompt)
        resurrect = sum(1 for h in hashes[:shared]
                        if self._ref[self._cached[h]] == 0)
        plen = len(prompt) if prompt is not None else None
        need = self.blocks_for(gen_len, plen) - shared + cow + resurrect
        return need <= self.free_unreserved

    def preempt_frees(self, slot: int, gen_len: int, *,
                      prompt=None) -> bool:
        """Evicting `slot` frees its unspent reservation plus every block
        it holds the *last* reference to (shared blocks merely decref —
        registered ones land in the reclaim list, which still counts as
        capacity) — admit iff that covers the candidate's need. With
        `prompt`, the need is prefix-discounted exactly like can_admit's,
        so a hot-prefix candidate is not stalled behind worst-case math;
        hit blocks whose only holder is the victim count as resurrections
        (the eviction parks them in reclaim, the candidate pulls them
        right back out)."""
        s = self._slots[slot]
        assert s is not None
        vblocks = {int(self.table[slot, j]) for j in range(s.alloc_g)}
        vblocks |= {int(self.table_local[slot, j])
                    for j in range(s.alloc_l)}
        freed = s.reserved + sum(  # replint: ignore[R001] -- order-insensitive reduction: sum over the set is the same for any iteration order
            1 for b in vblocks if self._ref[b] == 1)
        hashes, shared, _, cow = self._probe(prompt)
        resurrect = 0
        for h in hashes[:shared]:
            bid = self._cached[h]
            if self._ref[bid] == 0 or (self._ref[bid] == 1
                                       and bid in vblocks):
                resurrect += 1
        plen = len(prompt) if prompt is not None else None
        need = self.blocks_for(gen_len, plen) - shared + cow + resurrect
        return need <= self.free_unreserved + freed

    # -- occupancy ----------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def active_slots(self) -> List[int]:
        """Slots in the decode batch (prefilling slots ride lane rows)."""
        return [i for i, s in enumerate(self._slots)
                if s is not None and not s.prefilling]

    def occupied_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free_slots) / max(self.num_slots, 1)

    @property
    def free_capacity(self) -> int:
        """Absolute admission headroom: unreserved blocks (slots are
        rarely the binding constraint on a paged pool)."""
        return max(self.free_unreserved, 0)

    @property
    def blocks_in_use(self) -> int:
        """Blocks referenced by live requests (cache-retained blocks with
        no referents are reclaimable capacity, not use)."""
        return (self.usable_blocks - len(self._free_blocks)
                - len(self._reclaim))

    @property
    def block_occupancy(self) -> float:
        """Fraction of the pool committed (allocated + reserved) — the
        admission-honest load signal published to the autoscaler."""
        committed = self.blocks_in_use + self._reserved_total
        return committed / max(self.usable_blocks, 1)

    def info(self, slot: int) -> Optional[PagedSlot]:
        return self._slots[slot]

    def rid_of(self, slot: int) -> int:
        s = self._slots[slot]
        return FREE if s is None else s.rid

    # -- admission / allocation --------------------------------------------
    def admit(self, rid: int, gen_len: int, *, prefilling: bool = False,
              prompt=None) -> int:
        """Reserve a slot + the request's worst-case blocks; allocation
        itself happens on demand via ensure(). Returns the slot.

        With `prompt` (chunked admissions only), the prefix cache is
        probed first: hit blocks are attached (refcounted) to the slot's
        table and `cached_len` records how many prompt positions never
        need a prefill lane — the engine starts its lanes there."""
        use_prefix = prefilling and prompt is not None
        assert self.can_admit(gen_len, prompt=prompt if use_prefix else None)
        slot = self._free_slots.popleft()
        plen = len(prompt) if prompt is not None else self.prompt_len
        need = self.blocks_for(gen_len, plen)
        hashes, shared, cached_len, cow = (
            self._probe(prompt) if use_prefix else ((), 0, 0, 0))
        s = PagedSlot(rid=rid, cur_len=0, tokens_done=0, gen_len=gen_len,
                      plen=plen, prefilling=prefilling,
                      reserved=need - shared + cow,
                      cached_len=cached_len, shared_g=shared, hashes=hashes)
        self._slots[slot] = s
        for j in range(shared):
            self._attach(slot, j, self._cached[hashes[j]])
        s.alloc_g = shared
        self._reserved_total += s.reserved
        if use_prefix and self.prefix_cache:
            self._lookup_tokens += len(prompt)
            self._hit_tokens += cached_len
        return slot

    def _attach(self, slot: int, j: int, bid: int) -> None:
        """Point table entry j at shared block `bid` (incref; resurrect it
        from the reclaim list if its last holder already retired). Every
        attach is a cache hit — the count is what reclaim ordering
        weighs."""
        if self._ref[bid] == 0:
            del self._reclaim[bid]
        self._ref[bid] += 1
        self._hits[bid] = self._hits.get(bid, 0) + 1
        self.table[slot, j] = bid

    def _unregister_coldest(self) -> int:
        """Pop the reclaimable block with the fewest lifetime cache hits
        (LRU insertion order breaks ties — pure LRU is the zero-hit
        degenerate case) and drop its prefix-index entry."""
        bid = min(self._reclaim,
                  key=lambda b: (self._hits.get(b, 0), self._reclaim[b]))
        del self._reclaim[bid]
        del self._cached[self._hash_of.pop(bid)]
        self._hits.pop(bid, None)
        self._probe_memo = None  # the index shrank; memoized hits may lie
        return bid

    def _take_block(self) -> int:
        """A fresh physical block: the free list first, else reclaim the
        coldest cache-retained block (hit-count-weighted, LRU ties)."""
        if self._free_blocks:
            bid = self._free_blocks.popleft()
            self._free_block_set.discard(bid)
            return bid
        return self._unregister_coldest()

    def _release(self, bid: int) -> bool:
        """Drop one reference to `bid`; returns True iff the block went
        back to the free list (registered blocks are retained, reclaimable
        coldest-first, so a later identical prompt still hits)."""
        self._ref[bid] -= 1
        if self._ref[bid] > 0:
            return False
        if bid in self._hash_of:
            self._reclaim[bid] = self._reclaim_seq
            self._reclaim_seq += 1
            return False
        self._free_blocks.append(bid)
        self._free_block_set.add(bid)
        return True

    def _alloc(self, slot: int, local: bool) -> None:
        s = self._slots[slot]
        bid = self._take_block()
        self._ref[bid] = 1
        tbl = self.table_local if local else self.table
        if local:
            tbl[slot, s.alloc_l] = bid
            s.alloc_l += 1
        else:
            tbl[slot, s.alloc_g] = bid
            s.alloc_g += 1
        s.reserved -= 1
        self._reserved_total -= 1
        assert s.reserved >= 0, "request outgrew its reservation"

    def _cow(self, slot: int, j: int) -> None:
        """Copy-on-write: `slot` is about to write into shared table entry
        j (the boundary block of a fully-cached prompt). Copy the block's
        KV into a fresh block (reserved at admission), repoint the table,
        drop the shared reference — the sharer(s) and the cache keep the
        original; this request's writes land in its private copy."""
        s = self._slots[slot]
        old = int(self.table[slot, j])
        new = self._take_block()
        self._ref[new] = 1
        self.caches = self._copy(self.caches, jnp.asarray(old, jnp.int32),
                                 jnp.asarray(new, jnp.int32))
        self.table[slot, j] = new
        s.shared_g = j
        s.reserved -= 1
        self._reserved_total -= 1
        assert s.reserved >= 0, "copy-on-write outgrew its reservation"
        self._cow_copies += 1
        self._release(old)

    def ensure(self, slot: int, pos: int) -> None:
        """Allocate blocks so `slot` can write KV at logical position `pos`
        (and the matching window-ring position). On-demand growth: called
        right before every decode/prefill-chunk step. Writes are strictly
        sequential from cached_len, so the only write that can land in a
        shared block is the first one past a partially-cached boundary —
        that is the copy-on-write trigger."""
        s = self._slots[slot]
        assert s is not None
        bs = self.block_size
        if self.has_global:
            if s.shared_g * bs > s.cached_len and pos >= s.cached_len:
                self._cow(slot, s.shared_g - 1)
            while s.alloc_g < pos // bs + 1:
                self._alloc(slot, local=False)
        if self.has_local:
            ring_hi = min(pos, self.window - 1)
            while s.alloc_l < ring_hi // bs + 1:
                self._alloc(slot, local=True)

    def truncate(self, slot: int, n: int) -> None:
        """Roll `slot`'s committed KV back to its first `n` positions —
        the speculative-rejection path. Blocks wholly past position n-1
        go back through _release (refcounted: a registered block is
        retained for reclaim, a private one returns to the free list) and
        their reservation is re-credited, so a rejected draft costs the
        pool nothing. Junk KV inside the kept boundary block needs no
        device work: attention depth is cur_len, and the sequential write
        cursor overwrites it before it could ever be attended.

        Never reaches shared prefix blocks: verify rows only extend
        generated positions, so n >= prompt_len >= shared_g * block_size
        (COW has already privatized the boundary block by the time a slot
        decodes)."""
        s = self._slots[slot]
        assert s is not None and not s.prefilling
        keep = _ceil_div(n, self.block_size)
        assert keep >= s.shared_g, \
            f"truncate({n}) would free shared prefix blocks of slot {slot}"
        for j in range(keep, s.alloc_g):
            self._release(int(self.table[slot, j]))
            self.table[slot, j] = 0
            s.reserved += 1
            self._reserved_total += 1
        s.alloc_g = min(s.alloc_g, keep)
        # local ring tables are untouched: speculative decode is gated off
        # sliding-window archs (has_local pools never see truncate)

    def _tables_of(self, slot: int):
        return (jnp.asarray(self.table[slot]),
                jnp.asarray(self.table_local[slot]))

    def insert(self, slot: int, rid: int, prefill_caches: Pytree,
               gen_len: int) -> None:
        """Classic admission (SlotPool-compatible): bind `rid` to `slot`
        (pre-acquired via admit) or acquire one, then scatter the batch-1
        prefill cache into the slot's blocks. Used for recurrent-state
        archs and as the non-chunked fallback."""
        if self._slots[slot] is None:
            # direct pool use (tests): take this specific slot
            assert self.can_admit(gen_len), "block pool exhausted"
            self._free_slots.remove(slot)
            need = self.blocks_for(gen_len)
            self._slots[slot] = PagedSlot(rid=rid, cur_len=0, tokens_done=0,
                                          gen_len=gen_len, reserved=need)
            self._reserved_total += need
        s = self._slots[slot]
        assert s.shared_g == 0, \
            "classic insert scatters the whole prompt; it cannot target a " \
            "slot admitted with shared prefix blocks"
        s.rid = rid
        s.plen = self.prompt_len  # classic prefill scatters the full shape
        self.ensure(slot, self.prompt_len - 1)
        tg, tl = self._tables_of(slot)
        self.caches = self._insert(self.caches, prefill_caches,
                                   jnp.asarray(slot, jnp.int32), tg, tl)
        s.cur_len = self.prompt_len
        s.tokens_done = 1
        s.prefilling = False

    def finish_prefill(self, slot: int) -> PagedSlot:
        """Chunked prefill consumed the whole prompt: the slot joins the
        decode batch (its first token was emitted by the last lane row).
        The slot's full prompt blocks now hold valid KV — register any not
        yet in the prefix index so later identical prefixes hit. (Full
        prompt blocks are never written again: generation writes start at
        prompt_len, past the last registered block.)"""
        s = self._slots[slot]
        assert s is not None and s.prefilling
        s.prefilling = False
        s.cur_len = s.plen or self.prompt_len
        s.tokens_done = 1
        if self.prefix_cache:
            cap = int(self.max_shared_fraction * self.usable_blocks)
            for j, h in enumerate(s.hashes):
                if h in self._cached:
                    continue
                if len(self._hash_of) >= cap:
                    # residency cap: the prefix index may not retain more
                    # than max_shared_fraction of the pool. Make room by
                    # unregistering the coldest *reclaimable* entry; if
                    # every registered block is still referenced, this
                    # block simply stays private (freed normally at
                    # retirement) — one tenant's template churn cannot
                    # monopolize the pool.
                    if not self._reclaim:
                        continue
                    freed = self._unregister_coldest()
                    self._free_blocks.append(freed)
                    self._free_block_set.add(freed)
                bid = int(self.table[slot, j])
                self._cached[h] = bid
                self._hash_of[bid] = h
                self._hits.setdefault(bid, 0)
                self._probe_memo = None  # the index grew; re-probe
        return s

    # -- the fused step -------------------------------------------------------
    def decode(self, params, prev_tok, meta_i, meta_f, row_slots, *,
               sample: bool):
        """One fused step over the block pool. row_slots[t] names the slot
        whose tables row t addresses (decode rows: the slot itself; prefill
        lane rows: the admitting slot; -1: masked row -> null tables)."""
        rs = np.asarray(row_slots)
        safe = np.clip(rs, 0, self.num_slots - 1)
        live = (rs >= 0)[:, None]
        tables = {"global": jnp.asarray(np.where(live, self.table[safe], 0))}
        if self.has_local:
            tables["local"] = jnp.asarray(
                np.where(live, self.table_local[safe], 0))
        nxt, self.caches = self._decode[sample](
            params, self.caches, prev_tok, jnp.asarray(meta_i),
            jnp.asarray(meta_f), tables)
        return nxt

    # -- decode-batch views -------------------------------------------------
    def advance(self, slot: int) -> PagedSlot:
        s = self._slots[slot]
        assert s is not None and not s.prefilling
        s.cur_len += 1
        s.tokens_done += 1
        return s

    def finished(self, slot: int) -> bool:
        s = self._slots[slot]
        return (s is not None and not s.prefilling
                and s.tokens_done >= s.gen_len)

    # -- retirement ---------------------------------------------------------
    def evict(self, slot: int, *, zero: bool = False) -> None:
        """Free `slot`: drop one reference per table entry — a block
        returns to the free list only when its last reference drops AND it
        is not cache-registered (registered blocks are retained in the
        reclaim list so later identical prefixes still hit). Zeroing is
        hygiene only (tests) and skips blocks that stay shared or cached.

        Double frees are hard errors, not silent corruption: evicting an
        already-free slot raises, and a table entry whose block is already
        at refcount zero or sitting in the free list (an aliased table —
        exactly the corruption refcounting must never introduce) raises
        before the free list is poisoned."""
        s = self._slots[slot]
        if s is None:
            raise RuntimeError(
                f"double free: slot {slot} is already free (its block "
                "table was returned to the pool once)")
        freeing_g = [int(self.table[slot, j]) for j in range(s.alloc_g)]
        freeing_l = [int(self.table_local[slot, j])
                     for j in range(s.alloc_l)]
        freeing = freeing_g + freeing_l
        dup = [b for b in freeing
               if b in self._free_block_set or self._ref[b] <= 0]
        if len(set(freeing)) != len(freeing):  # within-table alias
            dup += [b for b in sorted(set(freeing)) if freeing.count(b) > 1]
        if dup:
            raise RuntimeError(
                f"double free: slot {slot} block table names free block(s) "
                f"{sorted(set(dup))} — the free list would hand them to "
                "two requests")
        if zero:
            # only blocks this eviction actually returns to the free list
            # may be zeroed; shared or cache-retained blocks keep their KV
            # (padding 0s land in the null block, which absorbs anything)
            dropping = {b for b in freeing
                        if self._ref[b] == 1 and b not in self._hash_of}
            zg = np.zeros_like(self.table[slot])
            gl = [b for b in freeing_g if b in dropping]
            zg[:len(gl)] = gl
            zl = np.zeros_like(self.table_local[slot])
            ll = [b for b in freeing_l if b in dropping]
            zl[:len(ll)] = ll
            self.caches = self._evict(self.caches,
                                      jnp.asarray(slot, jnp.int32),
                                      jnp.asarray(zg), jnp.asarray(zl))
        for b in freeing:
            self._release(b)
        self.table[slot, :] = 0
        self.table_local[slot, :] = 0
        self._reserved_total -= s.reserved
        self._slots[slot] = None
        self._free_slots.append(slot)

    # -- host swap tier ------------------------------------------------------
    def _swap_gather(self, slot: int, gids, lids) -> Pytree:
        """Pull `slot`'s named blocks (and state row) off the device as one
        pytree congruent to the pool with the block dim shrunk to n —
        quant scale leaves ride along automatically."""
        gi = jnp.asarray(np.asarray(gids, np.int32))
        li = jnp.asarray(np.asarray(lids, np.int32))

        def kv(dst, is_local, is_scale, axis):
            ids = li if is_local else gi
            return dst[:, ids] if axis == 1 else dst[ids]

        def state(dst, axis):
            return jax.lax.dynamic_slice_in_dim(dst, slot, 1, axis=axis)

        f = Mo._paged_kv_op(self.caches, self.cfg, kv, state)
        return jax.tree_util.tree_map_with_path(f, self.caches)

    def _swap_scatter(self, slot: int, gids, lids, payload: Pytree) -> None:
        """Scatter a host payload back into freshly allocated blocks (the
        inverse of _swap_gather, new physical ids)."""
        gi = jnp.asarray(np.asarray(gids, np.int32))
        li = jnp.asarray(np.asarray(lids, np.int32))

        def kv(dst, is_local, is_scale, axis, src):
            ids = li if is_local else gi
            src = jnp.asarray(src).astype(dst.dtype)
            if axis == 1:
                return dst.at[:, ids].set(src)
            return dst.at[ids].set(src)

        def state(dst, axis, src):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, jnp.asarray(src).astype(dst.dtype), slot, axis=axis)

        f = Mo._paged_kv_op(self.caches, self.cfg, kv, state)
        self.caches = jax.tree_util.tree_map_with_path(
            f, self.caches, payload)

    def swap_out(self, slot: int) -> bool:
        """Copy `slot`'s live KV (every allocated block + state row) to the
        host pool, then evict the slot. Returns False — caller falls back
        to restart preemption — when no host pool is attached, the budget
        is exhausted, or the slot is still prefilling (partial-prompt lane
        state doesn't restore; restart is the correct path there). Shared
        prefix blocks are copied too: the restore allocates private blocks,
        trading dedup for zero recompute (the index keeps the originals)."""
        if self.swap_pool is None:
            return False
        s = self._slots[slot]
        assert s is not None
        if s.prefilling:
            return False
        n_blocks = s.alloc_g + s.alloc_l
        if not self.swap_pool.can_store(n_blocks):
            return False
        gids = self.table[slot, :s.alloc_g].copy()
        lids = self.table_local[slot, :s.alloc_l].copy()
        payload = jax.device_get(self._swap_gather(slot, gids, lids))
        nbytes = int(sum(x.nbytes for x in jax.tree.leaves(payload)))
        self.swap_pool.store(SwapRecord(
            rid=s.rid, payload=payload, n_blocks=n_blocks, nbytes=nbytes,
            cur_len=s.cur_len, tokens_done=s.tokens_done, gen_len=s.gen_len,
            reserved=s.reserved, cached_len=s.cached_len,
            alloc_g=s.alloc_g, alloc_l=s.alloc_l, plen=s.plen))
        self.evict(slot)
        self._swap_out_bytes += nbytes
        self._swapped_blocks += n_blocks
        return True

    def has_swapped(self, rid: int) -> bool:
        return self.swap_pool is not None and self.swap_pool.has(rid)

    def plan_resume(self, rid: int) -> bool:
        """Reserve `rid`'s swap-in footprint ahead of fresh admissions.

        Opportunistic can_resume probes race every tick against fresh
        arrivals with tighter deadlines: the victim only ever resumes in a
        tick where its whole footprint happens to be free at probe time —
        under a steady EDF stream of fresh work, possibly never. A plan
        is a standing reservation (counted in _reserved_total, shrinking
        free_unreserved) taken the moment capacity exists, so fresh
        admissions queue behind the victim instead of starving it. One
        backend fleet-wide may hold the plan (HostSwapPool arbitrates);
        swap_in consumes it. Returns True iff this backend now holds the
        plan. Idempotent — a standing plan re-probes for free."""
        if not self.has_swapped(rid):
            return False
        if rid in self._resume_plans:
            return True
        if self.swap_pool.planner(rid) is not None:
            return False  # another replica already reserved the resume
        rec = self.swap_pool.peek(rid)
        need = rec.n_blocks + rec.reserved
        if need > self.free_unreserved:
            return False
        self._resume_plans[rid] = need
        self._reserved_total += need
        self.swap_pool.plan(rid, self)
        return True

    def cancel_resume_plans(self) -> None:
        """Release every standing resume reservation (drain/release path:
        a retiring backend must not pin capacity for resumes it will never
        run — the swapped records stay in the shared pool, and a live
        peer can take over the plan next tick)."""
        for rid, need in list(self._resume_plans.items()):
            self._reserved_total -= need
            self.swap_pool.unplan(rid)
        self._resume_plans.clear()

    def can_resume(self, rid: int) -> bool:
        """Swap-in admission math: a free slot plus the request's allocated
        blocks AND its unspent reservation (it must still be able to finish
        its declared gen_len without deadlocking mid-decode). With a
        standing plan here the blocks are already reserved — only the slot
        is still in question; a plan held by another backend makes the rid
        theirs to resume."""
        if not self.has_swapped(rid) or not self._free_slots:
            return False
        planner = self.swap_pool.planner(rid)
        if planner is not None:
            return planner is self
        rec = self.swap_pool.peek(rid)
        return rec.n_blocks + rec.reserved <= self.free_unreserved

    def swap_in(self, rid: int) -> int:
        """Restore a swapped request: allocate fresh blocks, scatter the
        host payload back, rebuild the PagedSlot at its swap-point cursor.
        The restored KV is byte-identical to what swap_out pulled (numpy
        round-trips bf16/int8 losslessly), so decoding resumes bit-
        identically; restored blocks are private (shared_g=0, no hashes —
        re-registration would alias the index's live originals)."""
        assert self.can_resume(rid), f"cannot resume swapped rid {rid}"
        planned = self._resume_plans.pop(rid, None)
        if planned is not None:  # consume the standing reservation
            self._reserved_total -= planned
        rec = self.swap_pool.take(rid)
        slot = self._free_slots.popleft()
        need = rec.reserved + rec.alloc_g + rec.alloc_l
        s = PagedSlot(rid=rid, cur_len=rec.cur_len,
                      tokens_done=rec.tokens_done, gen_len=rec.gen_len,
                      plen=rec.plen, reserved=need, cached_len=rec.cached_len)
        self._slots[slot] = s
        self._reserved_total += need
        for _ in range(rec.alloc_g):  # _alloc draws the reservation down
            self._alloc(slot, local=False)
        for _ in range(rec.alloc_l):
            self._alloc(slot, local=True)
        self._swap_scatter(slot, self.table[slot, :rec.alloc_g],
                           self.table_local[slot, :rec.alloc_l], rec.payload)
        self._swap_in_bytes += rec.nbytes
        return slot

    def drop_swapped(self, rid: int) -> None:
        """Discard `rid`'s host copy (restart fallback / cancellation) and
        release any standing resume reservation held for it here."""
        if self.swap_pool is not None:
            planned = self._resume_plans.pop(rid, None)
            if planned is not None:
                self._reserved_total -= planned
            self.swap_pool.drop(rid)

    def cached_prefix_len(self, slot: int) -> int:
        """Prompt positions this slot serves from the prefix cache — the
        engine starts the request's prefill lanes here."""
        s = self._slots[slot]
        return 0 if s is None else s.cached_len

    def probe_prefix(self, prompt) -> int:
        """Prompt positions an admission would serve from the cache right
        now (read-only). The router's prefix-affine policy probes every
        replica's pool with this before choosing one."""
        if prompt is None:
            return 0
        return self._probe(prompt)[2]

    def release(self) -> None:
        """Retire the pool (replica scale-down). Verifies the free-list
        accounting returns to empty — every usable block either free or
        cache-retained with zero references, no dangling reservations —
        then drops the device cache pytree. Leaks raise: a drained
        replica that cannot account for all its blocks is exactly the bug
        refcounting must never hide."""
        live = [i for i, s in enumerate(self._slots) if s is not None]
        if live:
            raise RuntimeError(f"release with occupied slots {live}")
        if self.swap_pool is not None:
            # standing resume reservations are not leaks: the records stay
            # in the shared pool for a live peer to plan next tick
            self.cancel_resume_plans()
        if self._reserved_total:
            raise RuntimeError(f"release leaked {self._reserved_total} "
                               "reserved blocks")
        accounted = len(self._free_blocks) + len(self._reclaim)
        if accounted != self.usable_blocks:
            raise RuntimeError(
                f"release leaked {self.usable_blocks - accounted} blocks "
                f"({len(self._free_blocks)} free + {len(self._reclaim)} "
                f"reclaimable of {self.usable_blocks})")
        if int(np.count_nonzero(self._ref)):
            held = np.flatnonzero(self._ref).tolist()
            raise RuntimeError(f"release with referenced blocks {held}")
        self.caches = None
        if self.swap_pool is not None:
            # last backend off a shared pool leak-checks host residency
            pool, self.swap_pool = self.swap_pool, None
            pool.detach()

    # -- reporting ----------------------------------------------------------
    @property
    def prefix_hit_tokens(self) -> int:
        """Cumulative prompt tokens served from the cache — the fleet
        rollup sums these raw counts across replicas (a mean of
        per-replica *ratios* would let zero-traffic replicas drag the
        fleet rate down)."""
        return self._hit_tokens

    @property
    def prefix_lookup_tokens(self) -> int:
        """Cumulative prompt tokens probed at admission (the hit-rate
        denominator)."""
        return self._lookup_tokens

    @property
    def prefix_hit_rate(self) -> float:
        """Cumulative fraction of probed prompt tokens served from the
        cache (the prefill compute the pool saved)."""
        return self._hit_tokens / max(self._lookup_tokens, 1)

    @property
    def shared_occupancy(self) -> float:
        """Fraction of the pool *actively* shared — blocks referenced by
        two or more live requests right now. Deliberately not "registered
        blocks": unique-prompt traffic registers (and retains) every full
        prompt block without ever sharing one, and a scale-hold keyed on
        retention would pin the cluster at peak size under 0% hit rate.
        Only concurrent sharing can push a refcount past 1, so this signal
        decays to 0 as sharing traffic drains and the autoscaler's shrink
        paths reopen."""
        return (int(np.count_nonzero(self._ref >= 2))
                / max(self.usable_blocks, 1))

    def metrics(self) -> Dict[str, float]:
        """Backend load signals merged into the engine snapshot: committed
        blocks are the signal that actually gates admission; the prefix
        signals feed the autoscaler's scale-hold (core/autoscaler.py).
        Swap counters are cumulative and per-backend (each replica reports
        its own traffic even when the host pool is shared), so the fleet
        rollup can sum them without double counting."""
        m = {"kv_block_occupancy": self.block_occupancy,
             "prefix_hit_rate": self.prefix_hit_rate,
             "kv_shared_occupancy": self.shared_occupancy}
        if self.swap_pool is not None:
            m.update(swapped_blocks=float(self._swapped_blocks),
                     swap_out_bytes=float(self._swap_out_bytes),
                     swap_in_bytes=float(self._swap_in_bytes))
        return m

    def describe(self) -> str:
        swap = ("" if self.swap_pool is None else
                ", host swap on")
        return (f"paged KV: {self.num_blocks} blocks x "
                f"{self.block_size} tokens, prefix cache "
                f"{'on' if self.prefix_cache else 'off'}{swap}")

    # -- introspection (tests) ----------------------------------------------
    def read_slot(self, slot: int) -> Pytree:
        """Gather `slot` back as a batch-1 cache pytree; unallocated table
        entries read as zeros (the null block may hold masked-row junk)."""
        s = self._slots[slot]
        tg, tl = self._tables_of(slot)
        ag = 0 if s is None else s.alloc_g
        al = 0 if s is None else s.alloc_l
        valid = (np.arange(max(self.mb_global, 1)) < ag)
        valid_l = (np.arange(max(self.mb_local, 1)) < al)
        return self._read(self.caches, jnp.asarray(slot, jnp.int32), tg, tl,
                          jnp.asarray(valid), jnp.asarray(valid_l))


class QuantBlockManager(BlockManager):
    """The third KV backend (`--kv quant`): BlockManager bookkeeping over
    an int8 block pool with per-row f32 dequant scales ([NB,Hkv,bs] — one
    scale per (block, head, token) across the head dim).

    Everything host-side (tables, refcounts, prefix hashing, reservation
    math, swap) is inherited unchanged; the deltas are device-side:
    the pool layout (Mo.init_paged_cache quant=True), quantize-on-insert
    (prefill caches expand through Mo.quantize_paged_request inside the
    insert jit; the fused decode step quantizes each new token's K/V row
    in models/model.py, dispatching on the "k_scale" cache leaf), and
    dequant fused into the read path (Pallas kernel with scalar-prefetched
    scales on TPU, gather+multiply XLA fallback on CPU).

    At ~(hd+4)/(2*hd) the bytes per token of the bf16 pool, an equal-byte
    budget holds ~2x the blocks — ~2x admitted concurrency — with
    bit-exactness relaxed to a bounded-divergence contract (see
    docs/serving.md): `kv_quant_divergence` below is the scheme's
    calibrated relative RMS quantization error."""

    kind = "quant"
    _quant = True

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        cfg, bs = self.cfg, self.block_size
        base_insert = Mo.make_paged_insert(cfg, bs)

        def quant_insert(pool, request, slot, tg, tl):
            return base_insert(pool, Mo.quantize_paged_request(cfg, request),
                               slot, tg, tl)

        self._insert = shared_jit(("quant_insert", cfg, bs),
                                  lambda: quant_insert, donate_argnums=(0,))
        # calibrated divergence: relative RMS error of the int8 scheme on a
        # unit-normal sample (the per-write measurement would sync the hot
        # path; the bounded-divergence test pins the end-to-end bound)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(1024, cfg.head_dim)), jnp.float32)
        from repro.kernels.paged_decode.ops import quantize_kv
        q, s = quantize_kv(x)
        deq = q.astype(jnp.float32) * s[..., None]
        self.quant_divergence = float(
            jnp.sqrt(jnp.mean((deq - x) ** 2) / jnp.mean(x ** 2)))

    def metrics(self) -> Dict[str, float]:
        m = super().metrics()
        m["kv_quant_divergence"] = self.quant_divergence
        return m

    def describe(self) -> str:
        return "int8 " + super().describe().replace("paged KV", "quant KV", 1)

    def read_slot(self, slot: int) -> Pytree:
        """Introspection reads dequantize (int8 * scales -> bf16) so the
        result is directly comparable to an fp pool's read."""
        return Mo.dequantize_paged_request(self.cfg, super().read_slot(slot))
