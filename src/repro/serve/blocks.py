"""BlockManager — a paged KV cache: global block pool + per-request tables.

The slot pool (serve/slots.py) reserves prompt_len + max_gen KV per slot for
a request's whole lifetime, so one long-tail gen length pins worst-case
memory for everyone. The BlockManager instead owns a global pool of
fixed-size KV blocks (Mo.init_paged_cache) and a host-side [num_slots, MB]
block table per request; blocks are allocated on demand as a request's
cur_len crosses block boundaries and returned to an O(1) free list at
retirement, so resident KV tracks what requests actually wrote — at a fixed
HBM budget the pool admits 2-4x the concurrent requests of slot reservation.

Admission is gated by *reservation*: a request reserves (but does not yet
allocate) the blocks its declared gen_len can ever need, so on-demand
allocation can never deadlock mid-decode and block exhaustion surfaces as
clean queue backpressure at admit time.

Physical block 0 is the null block: never allocated, it absorbs the writes
of masked rows (free slots / idle prefill lanes) in the fused decode step.

Sliding-window ('local') layers get their own window-sized tables: a ring
of ceil(w/bs) blocks written at pos % w — softmax over keys is permutation-
invariant and RoPE is applied at write time, so the ring never needs
unscrambling (this is what lets recurrentgemma-style archs serve here while
the slot pool still rejects them).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import steps as St
from repro.models import model as Mo
from repro.models.env import Env

Pytree = Any

FREE = -1

RECURRENT_KINDS = ("rglru", "rwkv")


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclass
class PagedSlot:
    rid: int
    cur_len: int  # next decode write position
    tokens_done: int
    gen_len: int
    prefilling: bool = False  # still consuming prompt chunks (lane rows)
    alloc_g: int = 0  # global-table blocks allocated so far
    alloc_l: int = 0  # local-table blocks allocated so far
    reserved: int = 0  # blocks reserved but not yet allocated


class BlockManager:
    kind = "paged"

    def __init__(self, cfg: ModelConfig, env: Env, *, num_slots: int,
                 prompt_len: int, max_gen: int, block_size: int = 16,
                 num_blocks: Optional[int] = None):
        if cfg.family == "vlm" or cfg.is_encdec:
            raise ValueError(
                f"{cfg.name}: continuous batching supports decoder-only "
                "archs (vlm/enc-dec prefill carries extra modalities)")
        kinds = set(cfg.block_pattern) | set(cfg.pattern_tail)
        if not kinds <= set(Mo.PAGEABLE_KINDS) | set(RECURRENT_KINDS):
            raise ValueError(f"{cfg.name}: kinds {sorted(kinds)} have no "
                             "paged-cache layout")
        self.cfg = cfg
        self.env = env
        self.num_slots = num_slots
        self.prompt_len = prompt_len
        self.max_gen = max_gen
        self.block_size = block_size
        self.window = cfg.local_window
        self.has_global = bool(kinds & {"attn", "moe"})
        self.has_local = "local" in kinds
        # recurrent state rows pin the decode batch to slot == row
        self.has_state = bool(kinds & set(RECURRENT_KINDS))
        # recurrent state can't parallelize a prompt chunk inside one step,
        # and window-ring writes would wrap onto each other within a chunk
        # (rows p and p+w share ring slot p%w); both admit via batch-1
        # prefill + paged insert instead
        self.chunk_prefill_ok = not self.has_state and not self.has_local
        max_kv = prompt_len + max_gen  # last written pos < prompt+gen-1
        bs = block_size
        self.mb_global = _ceil_div(max_kv, bs) if self.has_global else 0
        self.mb_local = (_ceil_div(min(self.window, max_kv), bs)
                         if self.has_local else 0)
        worst = num_slots * (self.mb_global + self.mb_local)
        self.num_blocks = (num_blocks if num_blocks is not None
                           else worst + 1)  # +1: the null block
        if self.num_blocks < 1 + self.mb_global + self.mb_local:
            raise ValueError(
                f"num_blocks={self.num_blocks} cannot hold even one request "
                f"({self.mb_global}+{self.mb_local} blocks + null)")
        self.caches: Pytree = Mo.init_paged_cache(
            cfg, env, num_slots, self.num_blocks, bs)
        # host-side tables: row per slot, 0 = unallocated (null block)
        self.table = np.zeros((num_slots, max(self.mb_global, 1)), np.int32)
        self.table_local = np.zeros((num_slots, max(self.mb_local, 1)),
                                    np.int32)
        self._slots: List[Optional[PagedSlot]] = [None] * num_slots
        self._free_slots: Deque[int] = deque(range(num_slots))
        self._free_blocks: Deque[int] = deque(range(1, self.num_blocks))
        # mirror of _free_blocks for the O(1) double-free guard: a block id
        # returned twice would sit in the free list twice and get handed to
        # two requests, silently cross-writing their KV
        self._free_block_set: Set[int] = set(self._free_blocks)
        self._reserved_total = 0  # blocks promised to admitted requests
        self._insert = jax.jit(Mo.make_paged_insert(cfg, bs),
                               donate_argnums=(0,))
        self._evict = jax.jit(Mo.make_paged_evict(cfg), donate_argnums=(0,))
        self._read = jax.jit(Mo.make_paged_read(cfg))
        # two fused-step variants: an all-greedy batch runs the pure-argmax
        # step (no mask/Gumbel work); any sampling row selects the sampler
        self._decode = {
            s: jax.jit(St.make_paged_decode_step(cfg, env,
                                                 prompt_len=prompt_len,
                                                 sample=s),
                       donate_argnums=(1,))
            for s in (False, True)}

    # -- sizing / admission math -------------------------------------------
    def blocks_for(self, gen_len: int) -> int:
        """Physical blocks a request with this gen_len can ever touch (its
        KV spans positions [0, prompt_len + gen_len - 1))."""
        kv = max(self.prompt_len + gen_len - 1, 1)
        n = _ceil_div(kv, self.block_size) if self.has_global else 0
        if self.has_local:
            n += _ceil_div(min(self.window, kv), self.block_size)
        return n

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1

    @property
    def free_unreserved(self) -> int:
        return len(self._free_blocks) - self._reserved_total

    def can_admit(self, gen_len: int) -> bool:
        return (bool(self._free_slots)
                and self.blocks_for(gen_len) <= self.free_unreserved)

    def preempt_frees(self, slot: int, gen_len: int) -> bool:
        """Evicting `slot` frees its full worst-case commitment (allocated
        + unspent reservation stay equal to blocks_for(its gen_len) by
        construction) plus the slot itself — admit iff that covers the
        candidate's reservation."""
        s = self._slots[slot]
        assert s is not None
        freed = s.alloc_g + s.alloc_l + s.reserved
        return self.blocks_for(gen_len) <= self.free_unreserved + freed

    # -- occupancy ----------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def active_slots(self) -> List[int]:
        """Slots in the decode batch (prefilling slots ride lane rows)."""
        return [i for i, s in enumerate(self._slots)
                if s is not None and not s.prefilling]

    def occupied_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free_slots) / max(self.num_slots, 1)

    @property
    def blocks_in_use(self) -> int:
        return self.usable_blocks - len(self._free_blocks)

    @property
    def block_occupancy(self) -> float:
        """Fraction of the pool committed (allocated + reserved) — the
        admission-honest load signal published to the autoscaler."""
        committed = self.blocks_in_use + self._reserved_total
        return committed / max(self.usable_blocks, 1)

    def info(self, slot: int) -> Optional[PagedSlot]:
        return self._slots[slot]

    def rid_of(self, slot: int) -> int:
        s = self._slots[slot]
        return FREE if s is None else s.rid

    # -- admission / allocation --------------------------------------------
    def admit(self, rid: int, gen_len: int, *,
              prefilling: bool = False) -> int:
        """Reserve a slot + the request's worst-case blocks; allocation
        itself happens on demand via ensure(). Returns the slot."""
        assert self.can_admit(gen_len)
        slot = self._free_slots.popleft()
        need = self.blocks_for(gen_len)
        self._slots[slot] = PagedSlot(rid=rid, cur_len=0, tokens_done=0,
                                      gen_len=gen_len, prefilling=prefilling,
                                      reserved=need)
        self._reserved_total += need
        return slot

    def _alloc(self, slot: int, local: bool) -> None:
        s = self._slots[slot]
        bid = self._free_blocks.popleft()
        self._free_block_set.discard(bid)
        tbl = self.table_local if local else self.table
        if local:
            tbl[slot, s.alloc_l] = bid
            s.alloc_l += 1
        else:
            tbl[slot, s.alloc_g] = bid
            s.alloc_g += 1
        s.reserved -= 1
        self._reserved_total -= 1
        assert s.reserved >= 0, "request outgrew its reservation"

    def ensure(self, slot: int, pos: int) -> None:
        """Allocate blocks so `slot` can write KV at logical position `pos`
        (and the matching window-ring position). On-demand growth: called
        right before every decode/prefill-chunk step."""
        s = self._slots[slot]
        assert s is not None
        bs = self.block_size
        if self.has_global:
            while s.alloc_g < pos // bs + 1:
                self._alloc(slot, local=False)
        if self.has_local:
            ring_hi = min(pos, self.window - 1)
            while s.alloc_l < ring_hi // bs + 1:
                self._alloc(slot, local=True)

    def _tables_of(self, slot: int):
        return (jnp.asarray(self.table[slot]),
                jnp.asarray(self.table_local[slot]))

    def insert(self, slot: int, rid: int, prefill_caches: Pytree,
               gen_len: int) -> None:
        """Classic admission (SlotPool-compatible): bind `rid` to `slot`
        (pre-acquired via admit) or acquire one, then scatter the batch-1
        prefill cache into the slot's blocks. Used for recurrent-state
        archs and as the non-chunked fallback."""
        if self._slots[slot] is None:
            # direct pool use (tests): take this specific slot
            assert self.can_admit(gen_len), "block pool exhausted"
            self._free_slots.remove(slot)
            need = self.blocks_for(gen_len)
            self._slots[slot] = PagedSlot(rid=rid, cur_len=0, tokens_done=0,
                                          gen_len=gen_len, reserved=need)
            self._reserved_total += need
        s = self._slots[slot]
        s.rid = rid
        self.ensure(slot, self.prompt_len - 1)
        tg, tl = self._tables_of(slot)
        self.caches = self._insert(self.caches, prefill_caches,
                                   jnp.asarray(slot, jnp.int32), tg, tl)
        s.cur_len = self.prompt_len
        s.tokens_done = 1
        s.prefilling = False

    def finish_prefill(self, slot: int) -> PagedSlot:
        """Chunked prefill consumed the whole prompt: the slot joins the
        decode batch (its first token was emitted by the last lane row)."""
        s = self._slots[slot]
        assert s is not None and s.prefilling
        s.prefilling = False
        s.cur_len = self.prompt_len
        s.tokens_done = 1
        return s

    # -- the fused step -------------------------------------------------------
    def decode(self, params, prev_tok, meta_i, meta_f, row_slots, *,
               sample: bool):
        """One fused step over the block pool. row_slots[t] names the slot
        whose tables row t addresses (decode rows: the slot itself; prefill
        lane rows: the admitting slot; -1: masked row -> null tables)."""
        rs = np.asarray(row_slots)
        safe = np.clip(rs, 0, self.num_slots - 1)
        live = (rs >= 0)[:, None]
        tables = {"global": jnp.asarray(np.where(live, self.table[safe], 0))}
        if self.has_local:
            tables["local"] = jnp.asarray(
                np.where(live, self.table_local[safe], 0))
        nxt, self.caches = self._decode[sample](
            params, self.caches, prev_tok, jnp.asarray(meta_i),
            jnp.asarray(meta_f), tables)
        return nxt

    # -- decode-batch views -------------------------------------------------
    def advance(self, slot: int) -> PagedSlot:
        s = self._slots[slot]
        assert s is not None and not s.prefilling
        s.cur_len += 1
        s.tokens_done += 1
        return s

    def finished(self, slot: int) -> bool:
        s = self._slots[slot]
        return (s is not None and not s.prefilling
                and s.tokens_done >= s.gen_len)

    # -- retirement ---------------------------------------------------------
    def evict(self, slot: int, *, zero: bool = False) -> None:
        """Free `slot`: return its blocks to the free list and drop any
        unspent reservation. Zeroing is hygiene only (tests).

        Double frees are hard errors, not silent corruption: evicting an
        already-free slot raises, and a block id that is somehow already in
        the free list (an aliased table — the failure mode prefix-sharing
        refcounts must never hit) raises before the list is poisoned."""
        s = self._slots[slot]
        if s is None:
            raise RuntimeError(
                f"double free: slot {slot} is already free (its block "
                "table was returned to the pool once)")
        if zero:
            tg, tl = self._tables_of(slot)
            self.caches = self._evict(self.caches,
                                      jnp.asarray(slot, jnp.int32), tg, tl)
        freeing = [int(self.table[slot, j]) for j in range(s.alloc_g)]
        freeing += [int(self.table_local[slot, j]) for j in range(s.alloc_l)]
        dup = [b for b in freeing if b in self._free_block_set]
        if len(set(freeing)) != len(freeing):  # within-table alias
            dup += [b for b in set(freeing) if freeing.count(b) > 1]
        if dup:
            raise RuntimeError(
                f"double free: slot {slot} block table names free block(s) "
                f"{sorted(set(dup))} — the free list would hand them to "
                "two requests")
        self._free_blocks.extend(freeing)
        self._free_block_set.update(freeing)
        self.table[slot, :] = 0
        self.table_local[slot, :] = 0
        self._reserved_total -= s.reserved
        self._slots[slot] = None
        self._free_slots.append(slot)

    # -- reporting ----------------------------------------------------------
    def metrics(self) -> Dict[str, float]:
        """Backend load signals merged into the engine snapshot: committed
        blocks are the signal that actually gates admission."""
        return {"kv_block_occupancy": self.block_occupancy}

    def describe(self) -> str:
        return (f"paged KV: {self.num_blocks} blocks x "
                f"{self.block_size} tokens")

    # -- introspection (tests) ----------------------------------------------
    def read_slot(self, slot: int) -> Pytree:
        """Gather `slot` back as a batch-1 cache pytree; unallocated table
        entries read as zeros (the null block may hold masked-row junk)."""
        s = self._slots[slot]
        tg, tl = self._tables_of(slot)
        ag = 0 if s is None else s.alloc_g
        al = 0 if s is None else s.alloc_l
        valid = (np.arange(max(self.mb_global, 1)) < ag)
        valid_l = (np.arange(max(self.mb_local, 1)) < al)
        return self._read(self.caches, jnp.asarray(slot, jnp.int32), tg, tl,
                          jnp.asarray(valid), jnp.asarray(valid_l))
